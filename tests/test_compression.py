"""Gradient compression: quantizer properties + multi-device collective
exactness (subprocess with 8 fake devices so the main test process keeps
seeing 1 CPU device)."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.optim.compression import BLOCK, quant_roundtrip


def test_quant_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(10_000,)).astype(np.float32) * 5)
    y = quant_roundtrip(x)
    # per-block symmetric int8: |err| <= max|block| / 127 / 2 (round)
    xb = np.pad(np.asarray(x), (0, (-x.size) % BLOCK)).reshape(-1, BLOCK)
    bound = np.repeat(np.abs(xb).max(1) / 127.0, BLOCK)[: x.size] * 0.5 + 1e-9
    assert (np.abs(np.asarray(y) - np.asarray(x)) <= bound).all()


def test_quant_roundtrip_preserves_zero_and_scale_outliers():
    x = jnp.zeros((512,), jnp.float32)
    assert (np.asarray(quant_roundtrip(x)) == 0).all()
    # an outlier block does not degrade other blocks
    x = jnp.asarray(
        np.concatenate([np.full(256, 1e-3, np.float32),
                        np.full(256, 1e3, np.float32)])
    )
    y = np.asarray(quant_roundtrip(x))
    np.testing.assert_allclose(y[:256], 1e-3, rtol=0.01)
    np.testing.assert_allclose(y[256:], 1e3, rtol=0.01)


_SUBPROCESS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import make_compressed_allreduce, BLOCK

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = BLOCK * 8 * 4
    g = jnp.asarray(rng.normal(size=(8, n)).astype(np.float32))
    tree = {"w": g}
    err0 = {"w": jnp.zeros_like(g)}
    ar = jax.jit(make_compressed_allreduce(mesh, "data"))  # jit ONCE
    with mesh:
        mean, err = ar(tree, err0)
    want = np.asarray(g).mean(0)
    got = np.asarray(mean["w" ])[0]
    # int8-compressed mean within quantization tolerance of the true mean
    tol = np.abs(np.asarray(g)).max() / 127.0 * 2.5
    assert np.abs(got - want).max() < tol, (np.abs(got - want).max(), tol)

    # error-feedback accumulation: averaged over steps, compressed means
    # converge to true means (bias ~ 0)
    errs = err0
    acc_c, acc_t = 0.0, 0.0
    for step in range(24):
        gs = jnp.asarray(rng.normal(size=(8, n)).astype(np.float32))
        with mesh:
            mean, errs = ar({"w": gs}, errs)
        acc_c = acc_c + np.asarray(mean["w"])[0]
        acc_t = acc_t + np.asarray(gs).mean(0)
    bias = np.abs(acc_c - acc_t).max() / 24
    raw = np.abs(np.asarray(gs)).max() / 127.0
    assert bias < raw, (bias, raw)  # EF keeps accumulated bias below 1-step q-error
    print("OK")
    """
)


def test_compressed_allreduce_multidevice():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS],
        capture_output=True, text=True, timeout=300,
        # JAX_PLATFORMS=cpu: skip the ~8-minute TPU-backend probe (the
        # container ships libtpu but has no TPU)
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
