"""Unit + property tests for basic linear quantization (paper eqs. 1-3)."""
try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:  # offline container: property tests skip, rest run
    from hypothesis_stub import hypothesis, hnp, st
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.quantize as qz

hypothesis.settings.register_profile(
    "repro", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck),
)
hypothesis.settings.load_profile("repro")


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_qparams_range_mapping(bits):
    # beta -> qmin, alpha -> qmax per eqs (2),(3)
    x = jnp.array([-3.0, 0.0, 5.0])
    qp = qz.compute_qparams(x, bits)
    q = qz.quantize(x, qp)
    assert int(q[0]) == qp.qmin
    assert int(q[-1]) == qp.qmax


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_roundtrip_error_bound(bits):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    qp = qz.compute_qparams(x, bits)
    xh = qz.dequantize(qz.quantize(x, qp), qp)
    # max error <= one quantization step (0.5/S rounding + clamp at edges)
    step = 1.0 / float(qp.scale)
    assert float(jnp.max(jnp.abs(x - xh))) <= step * 0.5001 + 1e-6


def test_zero_exact_when_in_range():
    # 0 in [beta, alpha] => dequant(quantize(0)) == 0 exactly
    for bits in (2, 4, 8):
        x = jnp.array([-1.5, 0.0, 2.5])
        qp = qz.compute_qparams(x, bits, include_zero=True)
        xh = qz.dequantize(qz.quantize(jnp.zeros(()), qp), qp)
        assert float(xh) == 0.0


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("last", [8, 64, 120])
def test_pack_unpack_roundtrip(bits, last):
    rng = np.random.default_rng(bits + last)
    q = rng.integers(-(2 ** (bits - 1)), 2 ** (bits - 1), size=(5, last)).astype(
        np.int8
    )
    p = qz.pack_codes(jnp.asarray(q), bits)
    u = qz.unpack_codes(p, bits, out_len=last)
    np.testing.assert_array_equal(np.asarray(u), q)
    if bits < 8:
        assert p.shape[-1] == last // (8 // bits)


@hypothesis.given(
    x=hnp.arrays(
        np.float32,
        st.integers(4, 300),
        elements=st.floats(-100, 100, width=32),
    ),
    bits=st.sampled_from([2, 4, 8]),
)
def test_property_quantize_monotone(x, bits):
    """Quantization is monotone non-decreasing (order preserved)."""
    hypothesis.assume(float(np.ptp(x)) > 1e-3)
    qp = qz.compute_qparams(jnp.asarray(x), bits)
    q = np.asarray(qz.quantize(jnp.asarray(x), qp)).astype(np.int32)
    order = np.argsort(x, kind="stable")
    assert (np.diff(q[order]) >= 0).all()


@hypothesis.given(
    x=hnp.arrays(np.float32, st.integers(8, 200),
                 elements=st.floats(-50, 50, width=32)),
    bits=st.sampled_from([4, 8]),
)
def test_property_codes_in_range(x, bits):
    hypothesis.assume(float(np.ptp(x)) > 1e-3)
    qp = qz.compute_qparams(jnp.asarray(x), bits)
    q = np.asarray(qz.quantize(jnp.asarray(x), qp))
    assert q.min() >= -(2 ** (bits - 1)) and q.max() <= 2 ** (bits - 1) - 1


def test_per_channel_and_group():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    qp_c = qz.compute_qparams(w, 4, channel_axis=0)
    assert qp_c.scale.shape == (16, 1)
    qp_g = qz.compute_qparams(w, 4, group_size=16)
    assert qp_g.scale.shape == (16, 64)
    # finer granularity must not be worse than per-tensor
    qp_t = qz.compute_qparams(w, 4)
    e_t = float(jnp.mean((qz.dequantize(qz.quantize(w, qp_t), qp_t) - w) ** 2))
    e_c = float(jnp.mean((qz.dequantize(qz.quantize(w, qp_c), qp_c) - w) ** 2))
    e_g = float(jnp.mean((qz.dequantize(qz.quantize(w, qp_g), qp_g) - w) ** 2))
    assert e_c <= e_t * 1.05 and e_g <= e_c * 1.05


def test_quantize_tensor_storage():
    w = jnp.asarray(np.random.default_rng(2).normal(size=(32, 100)).astype(np.float32))
    qt = qz.quantize_tensor(w, 4)
    assert qt.packed.shape == (32, 50)  # 2 codes per byte
    err = float(jnp.max(jnp.abs(qt.dequantize() - w)))
    assert err < 1.0  # coarse sanity; exact bound tested above
