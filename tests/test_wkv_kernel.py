"""WKV Pallas kernel vs the chunked-JAX implementation (itself tested
against the naive recurrence in test_ssm.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv import wkv_pallas
from repro.models.ssm import _wkv_chunked


def _data(b, h, s, n, p, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh).astype(np.float32))
    r, k = mk((b, s, h, n)), mk((b, s, h, n))
    v = mk((b, s, h, p))
    lw = -jnp.abs(mk((b, s, h, n))) * 0.4
    u = mk((h, n))
    return r, k, v, lw, u


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (64, 64)])
@pytest.mark.parametrize("n,p", [(8, 8), (16, 32)])
def test_wkv_kernel_vs_chunked_jax(s, chunk, n, p):
    b, h = 2, 3
    r, k, v, lw, u = _data(b, h, s, n, p, seed=s + n)
    y_ref, st_ref = _wkv_chunked(r, k, v, lw, u, chunk)

    def bh(t):  # (B,S,H,X) -> (B*H, S, X)
        return t.swapaxes(1, 2).reshape(b * h, s, t.shape[-1])

    u_bh = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, 1, n)
    y, st = wkv_pallas(bh(r), bh(k), bh(v), bh(lw), u_bh, chunk=chunk,
                       interpret=True)
    y = y.reshape(b, h, s, p).swapaxes(1, 2)
    st = st.reshape(b, h, n, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-5, atol=2e-5)


def test_wkv_kernel_strong_decay_stable():
    b, h, s, n, p = 1, 1, 32, 8, 8
    r, k, v, lw, u = _data(b, h, s, n, p, seed=9)
    lw = jnp.full_like(lw, -15.0)
    u_bh = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, 1, n)

    def bh_(t):
        return t.swapaxes(1, 2).reshape(b * h, s, t.shape[-1])

    y, st = wkv_pallas(bh_(r), bh_(k), bh_(v), bh_(lw), u_bh, chunk=8,
                       interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(st)).all()
