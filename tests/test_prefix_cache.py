"""PrefixIndex + copy-on-write unit tests: chain-hash matching, LRU
eviction with chain descendants, reference accounting against the
PageAllocator, and the device-side page copy.

The serving-level contract (prefix-shared serving == isolated decoding,
token for token) lives in tests/test_paged_kv.py; this file pins the
host-side cache machinery in isolation.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    from hypothesis_stub import hypothesis, st

from repro.kvcache import PageAllocator, PrefixIndex, copy_page, pages_for


def _prompt(*chunks):
    return np.concatenate([np.asarray(c, np.int32) for c in chunks])


def test_match_walks_full_pages_only():
    alloc = PageAllocator(16)
    idx = PrefixIndex(4, alloc)
    prompt = np.arange(10, dtype=np.int32)  # pages [0:4], [4:8]; tail 8:10
    pages = alloc.alloc(3)
    idx.insert(prompt, pages)
    assert idx.pages_held == 2          # only the two FULL pages are cached
    assert alloc.refcount(pages[0]) == 2
    assert alloc.refcount(pages[2]) == 1  # partial page: never indexed

    n, shared, state = idx.match(_prompt(np.arange(10), [99, 98]))
    assert (n, shared, state) == (8, pages[:2], None)
    # divergence INSIDE a page truncates the match to the boundary before
    n, shared, _ = idx.match(_prompt(np.arange(6), [77, 76, 75, 74]))
    assert (n, shared) == (4, pages[:1])
    # divergence in the first page: no match
    n, shared, _ = idx.match(_prompt([55], np.arange(9)))
    assert (n, shared) == (0, [])


def test_chain_keys_disambiguate_same_page_tokens():
    """Two prompts sharing page 1's TOKENS but not page 0 must not match —
    the chain key hashes the whole history, not the page in isolation."""
    alloc = PageAllocator(8)
    idx = PrefixIndex(2, alloc)
    a = _prompt([1, 2], [3, 4])
    idx.insert(a, alloc.alloc(2))
    n, shared, _ = idx.match(_prompt([9, 9], [3, 4]))
    assert (n, shared) == (0, [])


def test_insert_rehit_takes_no_second_reference():
    alloc = PageAllocator(8)
    idx = PrefixIndex(4, alloc)
    prompt = np.arange(8, dtype=np.int32)
    pages = alloc.alloc(2)
    idx.insert(prompt, pages)
    # a second request with the same prompt re-inserts its (shared) pages
    assert idx.insert(prompt, pages) == 0
    assert alloc.refcount(pages[0]) == 2  # still exactly one index ref
    idx.release_all()
    assert alloc.refcount(pages[0]) == 1


def test_eviction_takes_lru_chain_bottom_up_never_orphans():
    alloc = PageAllocator(6)
    idx = PrefixIndex(2, alloc)
    a = _prompt([1, 2], [3, 4])     # 2-page chain
    b = _prompt([7, 8])             # unrelated 1-page chain
    pa = alloc.alloc(2)
    idx.insert(a, pa)
    alloc.free(pa)                  # request retires; only the cache holds on
    pb = alloc.alloc(1)
    idx.insert(b, pb)
    alloc.free(pb)
    idx.match(b)                    # touch b: a's chain is now LRU
    alloc.alloc(3)                  # pool exhausted
    assert idx.evict_for(2)         # needs 2: a's chain goes, leaf first
    assert idx.pages_held == 1      # b survives (more recently used)
    assert idx.match(b)[0] == 2
    assert idx.match(a)[0] == 0     # both of a's entries gone: no orphan
    assert alloc.can_alloc(2)
    idx.release_all()


def test_evict_for_spares_retained_ancestors_frees_leaf_only():
    """A chain whose ROOT page a live request still reads must not be
    collateral damage of freeing its refcount-1 leaf: eviction is
    leaf-first among freeable pages, and a retained entry never goes."""
    alloc = PageAllocator(4)
    idx = PrefixIndex(2, alloc)
    p = _prompt([1, 2], [3, 4])
    pages = alloc.alloc(2)
    idx.insert(p, pages)
    alloc.free(pages)               # inserter retires
    root = idx.match(_prompt([1, 2]), record=False)[1]
    alloc.retain(root)              # a live request shares the root page
    alloc.alloc(2)                  # pool exhausted
    assert idx.evict_for(1)         # the leaf's page frees...
    assert idx.pages_held == 1      # ...but the retained root SURVIVES
    assert idx.match(_prompt([1, 2]), record=False)[0] == 2
    alloc.alloc(1)                  # exhaust again
    assert not idx.evict_for(1)     # root unevictable while retained
    assert idx.pages_held == 1


def test_evict_for_keeps_entries_whose_pages_cannot_be_freed():
    """Entries whose pages a live request retains free NOTHING when
    evicted — evict_for must report failure WITHOUT destroying them (the
    pressure resolves at the request's retirement; the cache must still
    be there for the fleet behind it)."""
    alloc = PageAllocator(2)
    idx = PrefixIndex(2, alloc)
    prompt = _prompt([1, 2], [3, 4])
    pages = alloc.alloc(2)          # the "live request" keeps its refs
    idx.insert(prompt, pages)
    assert not idx.evict_for(1)     # unsatisfiable: no page would free
    assert idx.pages_held == 2      # ... and the cache survives intact
    assert idx.match(prompt)[0] == 4
    alloc.free(pages)               # the request retires
    assert idx.evict_for(1)         # now evictable (and only as needed)
    idx.release_all()
    assert alloc.in_use == 0


def test_dry_run_match_counts_and_touches_nothing_until_recorded():
    """The admission path probes with record=False on every blocked retry:
    a request stalled K steps must still count ONE hit, and the probes
    must not churn the LRU order."""
    alloc = PageAllocator(8)
    idx = PrefixIndex(2, alloc)
    a, b = _prompt([1, 2]), _prompt([5, 6])
    for p in (a, b):                # serve-and-retire: cache keeps refs
        pages = alloc.alloc(1)
        idx.insert(p, pages)
        alloc.free(pages)           # b is now most recently used
    for _ in range(5):              # blocked-admission retries
        n, pages, _ = idx.match(a, record=False)
        assert n == 2 and len(pages) == 1
    assert idx.stats()["hits"] == 0 and idx.stats()["misses"] == 0
    # LRU untouched by the probes: a is still the eviction victim
    alloc.alloc(6)
    assert idx.evict_for(1)
    assert idx.match(a, record=False)[0] == 0   # a evicted
    assert idx.match(b, record=False)[0] == 2   # b survived
    idx.record(b, 2)                # the admission that finally commits
    idx.record(_prompt([9, 9]), 0)
    s = idx.stats()
    assert s["hits"] == 1 and s["hit_tokens"] == 2 and s["misses"] == 1
    idx.release_all()


def test_match_need_state_requires_snapshot_strictly_inside_prompt():
    alloc = PageAllocator(8)
    idx = PrefixIndex(2, alloc)
    prompt = np.arange(6, dtype=np.int32)  # 3 full pages
    pages = alloc.alloc(3)
    snap = {"ssm": np.ones((2, 3))}
    idx.insert(prompt, pages, states={2: snap, 4: snap})  # boundary 6: none
    # KV-only matching takes all three pages (full-prompt match allowed)
    assert idx.match(prompt)[0] == 6
    # state-matching walks back to the deepest SNAPSHOTTED boundary that
    # leaves at least one token to prefill
    n, shared, state = idx.match(prompt, need_state=True)
    assert (n, shared) == (4, pages[:2])
    assert state is snap
    # a longer prompt sharing the prefix can use boundary 4 too
    n2, _, state2 = idx.match(_prompt(np.arange(6), [9, 9]),
                              need_state=True)
    assert n2 == 4 and state2 is snap
    idx.release_all()
    alloc.free(pages)
    assert alloc.in_use == 0


def test_release_all_returns_every_reference():
    alloc = PageAllocator(12)
    idx = PrefixIndex(3, alloc)
    for seed in range(3):
        rng = np.random.default_rng(seed)
        prompt = rng.integers(0, 50, 9).astype(np.int32)
        pages = alloc.alloc(3)
        idx.insert(prompt, pages)
        alloc.free(pages)           # the request retires; cache holds on
    assert alloc.in_use == idx.pages_held > 0
    idx.release_all()
    assert alloc.in_use == 0 and idx.pages_held == 0
    assert idx.stats()["evicted"] == idx.stats()["inserted"]


def _prefix_walk(seed: int, page_size: int, num_pages: int, ops: int):
    """Random insert / match / evict walk; every retiring owner frees its
    refs immediately, so at every step the allocator's pages in use must
    equal exactly what the index holds — and any match must be a true
    token-prefix of some inserted prompt."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages)
    idx = PrefixIndex(page_size, alloc)
    inserted: list[np.ndarray] = []

    def new_prompt():
        n = int(rng.integers(1, 3 * page_size + 2))
        return rng.integers(0, 40, n).astype(np.int32)

    def query():
        if inserted and rng.integers(0, 2):  # overlapping-prefix query
            base = inserted[int(rng.integers(0, len(inserted)))]
            cut = int(rng.integers(1, len(base) + 1))
            tail = rng.integers(0, 40, int(rng.integers(0, 5)))
            return np.concatenate([base[:cut], tail.astype(np.int32)])
        return new_prompt()

    for _ in range(ops):
        op = int(rng.integers(0, 3))
        if op == 0:  # serve-and-retire a request: cache keeps the prefix
            p = query()
            need = pages_for(len(p), page_size)
            if alloc.can_alloc(need):
                n, shared, _ = idx.match(p)
                pages = shared + alloc.alloc(need - len(shared))
                if shared:
                    alloc.retain(shared)
                idx.insert(p, pages)
                alloc.free(pages)
                inserted.append(p)
        elif op == 1:  # pure lookup
            q = query()
            n, pages, _ = idx.match(q)
            assert n % page_size == 0 and n <= len(q)
            assert len(pages) == n // page_size
            if n:
                assert any(len(p) >= n and np.array_equal(p[:n], q[:n])
                           for p in inserted), "match is not a real prefix"
        else:  # pool-pressure eviction
            idx.evict_for(int(rng.integers(0, num_pages + 1)))
        assert alloc.in_use == idx.pages_held
    idx.release_all()
    assert alloc.in_use == 0, "prefix cache leaked pages"


def test_prefix_walk_deterministic():
    for seed in range(4):
        _prefix_walk(seed, page_size=4, num_pages=11, ops=60)


@hypothesis.given(st.integers(min_value=0, max_value=10_000),
                  st.integers(min_value=1, max_value=8),
                  st.integers(min_value=2, max_value=32),
                  st.integers(min_value=1, max_value=80))
@hypothesis.settings(max_examples=25, deadline=None)
def test_prefix_walk_property(seed, page_size, num_pages, ops):
    _prefix_walk(seed, page_size, num_pages, ops)


def test_state_budget_evicts_snapshots_lru_keeps_page_entries():
    """Snapshots are a sidecar under ``state_budget``: over budget, LRU
    entries lose their state but KEEP their page entry (KV-only matching
    still works), and ``match(need_state=True)`` degrades to a shallower
    boundary instead of breaking."""
    alloc = PageAllocator(16)
    snap = {"ssm": np.ones((4, 8), np.float32)}   # 128 bytes
    idx = PrefixIndex(2, alloc, state_budget=2 * 128)
    prompts = [np.asarray([10 * i, 10 * i + 1], np.int32) for i in range(4)]
    pages = []
    for p in prompts:                # 4 snapshots, budget holds 2
        pg = alloc.alloc(1)
        idx.insert(p, pg, states={2: snap})
        alloc.free(pg)
        pages.append(pg[0])
    s = idx.stats()
    assert s["entries"] == 4                      # no page entry lost
    assert s["states_held"] == 2, s               # budget: 2 snapshots
    assert s["state_bytes"] == 2 * 128, s
    assert s["states_evicted"] == 2, s
    # the LRU entries (earliest inserts) lost their snapshot first
    longer = [np.concatenate([p, np.asarray([7], np.int32)])
              for p in prompts]
    assert idx.match(longer[0], need_state=True, record=False)[0] == 0
    assert idx.match(longer[3], need_state=True, record=False)[0] == 2
    # KV-only matching is untouched by snapshot eviction
    assert idx.match(longer[0], record=False)[0] == 2
    idx.release_all()
    assert alloc.in_use == 0 and idx.state_bytes == 0


def test_state_budget_walks_back_to_surviving_boundary():
    """A chain whose DEEP boundary lost its snapshot must fall back to the
    deepest boundary that still has one."""
    alloc = PageAllocator(16)
    small = {"s": np.zeros(16, np.uint8)}         # 16 bytes
    idx = PrefixIndex(2, alloc, state_budget=100)
    prompt = np.arange(8, dtype=np.int32)         # 4 full pages
    pg = alloc.alloc(4)
    idx.insert(prompt, pg, states={2: small, 4: small, 6: small})
    alloc.free(pg)
    n0, _, _ = idx.match(prompt, need_state=True, record=False)
    assert n0 == 6
    # shrink the budget by inserting a big snapshot elsewhere: the LRU
    # snapshots (the first-stored boundaries) drop first
    big = {"s": np.zeros(80, np.uint8)}
    other = np.asarray([90, 91], np.int32)
    pg2 = alloc.alloc(1)
    idx.insert(other, pg2, states={2: big})
    alloc.free(pg2)
    assert idx.stats()["state_bytes"] <= 100
    n1, _, state = idx.match(prompt, need_state=True, record=False)
    assert n1 < 6 or state is not None  # degraded depth, never corrupt
    idx.release_all()
    assert alloc.in_use == 0


def test_state_budget_refuses_oversized_snapshot():
    alloc = PageAllocator(4)
    idx = PrefixIndex(2, alloc, state_budget=8)
    huge = {"s": np.zeros(64, np.uint8)}
    pg = alloc.alloc(1)
    idx.insert(np.asarray([1, 2], np.int32), pg, states={2: huge})
    alloc.free(pg)
    s = idx.stats()
    assert s["entries"] == 1 and s["states_held"] == 0, s
    assert s["state_bytes"] == 0 and s["states_evicted"] == 1, s
    idx.release_all()


def test_copy_page_moves_contents_across_all_layers():
    pool = jnp.arange(2 * 2 * 4 * 3 * 2 * 2, dtype=jnp.float32).reshape(
        2, 2, 4, 3, 2, 2
    )  # (L, 2, P=4, page, KV, hd)
    out = copy_page(pool, 1, 3)
    np.testing.assert_array_equal(np.asarray(out[:, :, 3]),
                                  np.asarray(pool[:, :, 1]))
    # every other page untouched
    for p in (0, 1, 2):
        np.testing.assert_array_equal(np.asarray(out[:, :, p]),
                                      np.asarray(pool[:, :, p]))


def test_stats_shape():
    alloc = PageAllocator(4)
    idx = PrefixIndex(2, alloc)
    idx.match(np.arange(4, dtype=np.int32))
    s = idx.stats()
    assert s["misses"] == 1 and s["hits"] == 0 and s["entries"] == 0
    with pytest.raises(KeyError):
        # inserting pages the allocator never handed out must blow up
        idx.insert(np.arange(2, dtype=np.int32), [99])
