"""Tests for histogram-accelerated 1-D k-means."""
try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:  # offline container: property tests skip, rest run
    from hypothesis_stub import hypothesis, hnp, st
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans


def test_three_well_separated_clusters():
    rng = np.random.default_rng(0)
    x = np.concatenate(
        [rng.normal(-10, 0.1, 500), rng.normal(0, 0.1, 2000), rng.normal(9, 0.1, 300)]
    ).astype(np.float32)
    res = kmeans.kmeans1d(jnp.asarray(x), k=3)
    c = np.asarray(res.centroids)
    assert abs(c[0] + 10) < 0.5 and abs(c[1]) < 0.5 and abs(c[2] - 9) < 0.5
    ids = np.asarray(kmeans.cluster_masks(jnp.asarray(x), res.boundaries))
    assert (ids[:500] == 0).mean() > 0.99
    assert (ids[500:2500] == 1).mean() > 0.99
    assert (ids[2500:] == 2).mean() > 0.99


def test_centroids_sorted_and_boundaries_between():
    x = jnp.asarray(np.random.default_rng(1).normal(size=4096).astype(np.float32))
    res = kmeans.kmeans1d(x, k=3)
    c = np.asarray(res.centroids)
    b = np.asarray(res.boundaries)
    assert (np.diff(c) >= 0).all()
    assert (b >= c[:-1]).all() and (b <= c[1:]).all()


def test_constant_tensor_degenerate():
    x = jnp.full((1000,), 2.5, jnp.float32)
    res = kmeans.kmeans1d(x, k=3)
    assert np.isfinite(np.asarray(res.centroids)).all()
    ids = kmeans.cluster_masks(x, res.boundaries)
    assert np.isfinite(np.asarray(ids)).all()


def test_np_twin_matches_jax():
    x = np.random.default_rng(3).normal(size=8192).astype(np.float32)
    res = kmeans.kmeans1d(jnp.asarray(x), k=3)
    c_np, b_np = kmeans.kmeans1d_np(x, k=3)
    np.testing.assert_allclose(np.asarray(res.centroids), c_np, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.boundaries), b_np, rtol=1e-4, atol=1e-4)


@hypothesis.given(
    x=hnp.arrays(np.float32, st.integers(16, 2000),
                 elements=st.floats(-1000, 1000, width=32)),
    k=st.sampled_from([2, 3]),
)
@hypothesis.settings(deadline=None, max_examples=20)
def test_property_partition_covers_everything(x, k):
    """Every element lands in exactly one cluster; masks partition."""
    ids = np.asarray(
        kmeans.cluster_masks(
            jnp.asarray(x), kmeans.kmeans1d(jnp.asarray(x), k=k).boundaries
        )
    )
    assert ids.min() >= 0 and ids.max() <= k - 1


@hypothesis.given(
    x=hnp.arrays(np.float32, st.integers(64, 1000),
                 elements=st.floats(-100, 100, width=32)),
)
@hypothesis.settings(deadline=None, max_examples=20)
def test_property_clusters_are_intervals(x):
    """1-D k-means clusters must be contiguous in value."""
    hypothesis.assume(float(np.ptp(x)) > 1e-2)
    res = kmeans.kmeans1d(jnp.asarray(x), k=3)
    ids = np.asarray(kmeans.cluster_masks(jnp.asarray(x), res.boundaries))
    order = np.argsort(x, kind="stable")
    assert (np.diff(ids[order]) >= 0).all()


def test_split_range_reduction():
    """The point of the paper: per-cluster ranges are much narrower than the
    full tensor range for outlier-heavy distributions."""
    rng = np.random.default_rng(7)
    x = rng.normal(0, 0.05, 100_000).astype(np.float32)
    x[:50] = rng.uniform(2, 3, 50)  # positive outliers
    x[50:100] = rng.uniform(-3, -2, 50)
    res = kmeans.kmeans1d(jnp.asarray(x), k=3)
    ids = np.asarray(kmeans.cluster_masks(jnp.asarray(x), res.boundaries))
    full = np.ptp(x)
    mid = x[ids == 1]
    assert np.ptp(mid) < full / 5  # middle cluster >=5x narrower
