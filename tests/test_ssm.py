"""SSM correctness: chunked scans vs naive recurrences, continuation
equivalence (prefill-in-parts == one-shot), numerical stability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import _ssd_chunked, _wkv_chunked


def _rand(shape, seed, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32) * scale
    )


def _naive_ssd(u, dA, Bm, Cm):
    B, S, H, P = u.shape
    N = Bm.shape[-1]
    y = np.zeros((B, S, H, P), np.float32)
    st = np.zeros((B, H, N, P), np.float32)
    for t in range(S):
        a = np.exp(np.asarray(dA[:, t]))
        st = st * a[:, :, None, None] + np.einsum(
            "bgn,bhp->bhnp", np.asarray(Bm[:, t]), np.asarray(u[:, t]))
        y[:, t] = np.einsum("bgn,bhnp->bhp", np.asarray(Cm[:, t]), st)
    return y, st


def test_ssd_chunked_matches_recurrence():
    B, S, H, P, N = 2, 40, 3, 5, 4  # S=40 not divisible by chunk 16: pads
    u = _rand((B, S, H, P), 0)
    dA = -jnp.abs(_rand((B, S, H), 1, 0.3))
    Bm = _rand((B, S, 1, N), 2)
    Cm = _rand((B, S, 1, N), 3)
    y, st = _ssd_chunked(u, dA, Bm, Cm, 16)
    y_ref, st_ref = _naive_ssd(u, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=2e-5)


def test_ssd_continuation_equivalence():
    B, S, H, P, N = 1, 32, 2, 4, 4
    u = _rand((B, S, H, P), 4)
    dA = -jnp.abs(_rand((B, S, H), 5, 0.2))
    Bm = _rand((B, S, 1, N), 6)
    Cm = _rand((B, S, 1, N), 7)
    y_full, st_full = _ssd_chunked(u, dA, Bm, Cm, 8)
    y1, st1 = _ssd_chunked(u[:, :16], dA[:, :16], Bm[:, :16], Cm[:, :16], 8)
    y2, st2 = _ssd_chunked(u[:, 16:], dA[:, 16:], Bm[:, 16:], Cm[:, 16:], 8,
                           init_state=st1)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(y1), np.asarray(y2)], 1),
        np.asarray(y_full), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), atol=2e-5)


def _naive_wkv(r, k, v, lw, u):
    B, S, H, N = k.shape
    P = v.shape[-1]
    y = np.zeros((B, S, H, P), np.float32)
    st = np.zeros((B, H, N, P), np.float32)
    for t in range(S):
        kv = np.einsum("bhn,bhp->bhnp", np.asarray(k[:, t]), np.asarray(v[:, t]))
        acc = st + np.asarray(u)[None, :, :, None] * kv
        y[:, t] = np.einsum("bhn,bhnp->bhp", np.asarray(r[:, t]), acc)
        st = st * np.exp(np.asarray(lw[:, t]))[..., None] + kv
    return y, st


def test_wkv_chunked_matches_recurrence():
    B, S, H, N, P = 2, 24, 2, 4, 4
    r, k, v = _rand((B, S, H, N), 0), _rand((B, S, H, N), 1), _rand((B, S, H, P), 2)
    lw = -jnp.abs(_rand((B, S, H, N), 3, 0.4))
    u = _rand((H, N), 4)
    y, st = _wkv_chunked(r, k, v, lw, u, 8)
    y_ref, st_ref = _naive_wkv(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=2e-5)


def test_wkv_extreme_decay_no_overflow():
    """Strong decays (log w = -20) must not produce inf/nan — the pairwise-
    difference formulation keeps every exponent <= 0."""
    B, S, H, N, P = 1, 16, 1, 4, 4
    r, k, v = _rand((B, S, H, N), 0), _rand((B, S, H, N), 1), _rand((B, S, H, P), 2)
    lw = jnp.full((B, S, H, N), -20.0)
    y, st = _wkv_chunked(r, k, v, lw, jnp.zeros((H, N)), 8)
    assert np.isfinite(np.asarray(y)).all() and np.isfinite(np.asarray(st)).all()


def test_ssd_gradients_finite():
    B, S, H, P, N = 1, 16, 2, 4, 4
    u = _rand((B, S, H, P), 0)
    dA = -jnp.abs(_rand((B, S, H), 1, 0.3))
    Bm = _rand((B, S, 1, N), 2)
    Cm = _rand((B, S, 1, N), 3)

    def f(u, dA, Bm, Cm):
        y, st = _ssd_chunked(u, dA, Bm, Cm, 8)
        return jnp.sum(y ** 2)

    grads = jax.grad(f, argnums=(0, 1, 2, 3))(u, dA, Bm, Cm)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
