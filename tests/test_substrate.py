"""Substrate tests: checkpoint manager (async/atomic/reshard), fault
tolerance (stragglers, elastic re-mesh, retries), data pipeline
(determinism, resume), optimizer, schedules."""
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import ByteCorpus, DataLoader, Prefetcher, SyntheticLM
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.runtime.fault import (
    Heartbeat,
    PreemptionGuard,
    detect_stragglers,
    elastic_mesh_shape,
    run_with_retries,
)


# -- checkpoint ---------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))},
        "opt": {"step": jnp.asarray(3, jnp.int32),
                "m": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(10, t, blocking=True)
    step, back = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert mgr.list_steps() == [3, 4]  # keep=2


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree(), blocking=True)
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))
    assert (pathlib.Path(tmp_path) / "step_00000005" / "manifest.json").exists()


def test_checkpoint_restore_missing_leaf_fails(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.ones(3)}, blocking=True)
    with pytest.raises(KeyError):
        mgr.restore(1, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_checkpoint_dtype_cast_on_restore(tmp_path):
    """Elastic restore casts to the target dtype (bf16 <-> fp32 configs)."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones((4,), jnp.float32)}, blocking=True)
    _, back = mgr.restore(1, {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert back["w"].dtype == jnp.bfloat16


# -- fault tolerance ----------------------------------------------------------


def test_straggler_detection():
    now = 1000.0
    recs = [
        {"host": 0, "step": 5, "step_time_s": 1.0, "time": now - 1},
        {"host": 1, "step": 5, "step_time_s": 1.1, "time": now - 2},
        {"host": 2, "step": 5, "step_time_s": 5.0, "time": now - 1},   # slow
        {"host": 3, "step": 2, "step_time_s": 1.0, "time": now - 500}, # dead
    ]
    rep = detect_stragglers(recs, now=now, slow_factor=2.0, dead_after_s=120)
    assert rep.stragglers == [2]
    assert rep.dead == [3]
    assert rep.median_step_time == pytest.approx(1.1)


def test_heartbeat_files(tmp_path):
    hb = Heartbeat(tmp_path, host_id=7)
    hb.beat(step=42, step_time_s=0.5, now=123.0)
    recs = Heartbeat.read_all(tmp_path)
    assert recs == [{"host": 7, "step": 42, "step_time_s": 0.5, "time": 123.0}]


def test_elastic_mesh_shapes():
    # full fleet
    assert elastic_mesh_shape(512, model_parallel=16, prefer_pods=2) == (
        (2, 16, 16), ("pod", "data", "model"))
    # lost one pod -> single-pod mesh
    assert elastic_mesh_shape(256, model_parallel=16) == (
        (16, 16), ("data", "model"))
    # lost 3 hosts of 8 chips: 488 // 16 = 30 data rows
    shape, axes = elastic_mesh_shape(488, model_parallel=16)
    assert shape == (30, 16)
    with pytest.raises(ValueError):
        elastic_mesh_shape(8, model_parallel=16)


def test_run_with_retries_recovers():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert run_with_retries(flaky, max_retries=3) == "ok"
    assert len(calls) == 3


def test_run_with_retries_gives_up():
    def always():
        raise RuntimeError("hard")

    with pytest.raises(RuntimeError):
        run_with_retries(always, max_retries=1)


def test_preemption_guard_install_uninstall():
    g = PreemptionGuard().install()
    assert g.requested is False
    g.uninstall()


# -- data pipeline ------------------------------------------------------------


def test_loader_deterministic_and_resumable():
    dl = DataLoader(SyntheticLM(100, seed=1), global_batch=4, seq_len=16, seed=2)
    b1 = dl.batch_at(7)
    b2 = dl.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(
        dl.batch_at(0)["tokens"][:, 1:], dl.batch_at(0)["labels"][:, :-1]
    )


def test_loader_host_sharding_partitions():
    full = DataLoader(SyntheticLM(100), 8, 16, seed=0).batch_at(0)["tokens"]
    parts = [
        DataLoader(SyntheticLM(100), 8, 16, seed=0, host_id=h, n_hosts=2)
        .batch_at(0)["tokens"]
        for h in (0, 1)
    ]
    merged = np.empty_like(full)
    merged[0::2] = parts[0]
    merged[1::2] = parts[1]
    np.testing.assert_array_equal(merged, full)


def test_byte_corpus_windows():
    c = ByteCorpus("hello world, this is a tiny corpus for the byte lm. " * 4)
    w = c.windows(np.random.default_rng(0), 3, 10)
    assert w.shape == (3, 11)
    assert (w >= 0).all() and (w < 259).all()


def test_prefetcher_passthrough():
    items = [{"x": np.array([i])} for i in range(5)]
    out = list(Prefetcher(iter(items)))
    assert [int(o["x"][0]) for o in out] == [0, 1, 2, 3, 4]


def test_synthetic_lm_is_learnable_structure():
    """The synthetic stream must be predictable (else Table-1 accuracies
    are all chance and the reproduction is vacuous)."""
    src = SyntheticLM(64, seed=0)
    x = src.sample(np.random.default_rng(0), 5000)
    # bigram predictability: most frequent successor of each token beats 1/64
    from collections import Counter, defaultdict

    succ = defaultdict(Counter)
    for a, b in zip(x[:-1], x[1:]):
        succ[int(a)][int(b)] += 1
    top_mass = np.mean([
        max(c.values()) / sum(c.values()) for c in succ.values()
        if sum(c.values()) >= 20
    ])
    # the generator conditions on a hashed-history state, so raw bigram
    # predictability understates it; 4x over the 1/64 chance floor is the
    # learnability signal we need
    assert top_mass > 4.0 / 64


# -- optimizer ----------------------------------------------------------------


def test_adamw_reduces_quadratic():
    cfg = adamw.AdamWConfig(peak_lr=0.1, warmup=5, total_steps=100,
                            weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw.init_opt_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt, m = adamw.apply_updates(cfg, params, g, opt)
    assert float(loss(params)) < 0.2


def test_adamw_mixed_precision_master():
    cfg = adamw.AdamWConfig(peak_lr=0.01, warmup=1, total_steps=10)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw.init_opt_state(params)
    g = {"w": jnp.full((4,), 0.001, jnp.bfloat16)}
    params, opt, _ = adamw.apply_updates(cfg, params, g, opt)
    assert params["w"].dtype == jnp.bfloat16
    assert opt["master"]["w"].dtype == jnp.float32
    # master moved even though the bf16 delta may round away
    assert float(jnp.abs(opt["master"]["w"] - 1.0).max()) > 0


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=1e-3)
    assert lrs[9] < lrs[10] >= lrs[11] >= lrs[50] >= lrs[99]
    assert lrs[99] >= 0.1 - 1e-6  # floor


def test_grad_clip_bounds_update():
    cfg = adamw.AdamWConfig(peak_lr=1.0, warmup=1, total_steps=2, clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    opt = adamw.init_opt_state(params)
    g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    _, _, m = adamw.apply_updates(cfg, params, g, opt)
    assert float(m["grad_norm"]) == pytest.approx(1e6)
