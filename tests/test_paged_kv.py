"""Paged KV-cache subsystem: paged == contiguous, token for token.

The contract under test (the paged-serving tentpole):
* every request served through a PAGED ``BatchedServer`` — shuffled
  physical pages, shared pool, per-request reservations — produces
  token-for-token the same output as a fresh isolated single-request
  decode on a contiguous cache (attention and hybrid cache families),
* chunked prefill (prompt fed in page-sized waves) produces identical
  tokens to whole-prompt prefill while interleaving decode steps for
  ongoing requests between waves,
* the Pallas paged-attention kernel (interpret mode) matches the pure-jnp
  reference, including sliding-window / chunked masks and page-table
  indirection,
* fully-masked rows (``len == 0``) produce EXACT zeros from attention —
  the regression for the old ``k_len = max(k_len, 1)`` clamp that silently
  attended one garbage key.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_attention import (
    paged_attention_pallas,
    paged_attention_reference,
)
from repro.kvcache import pages_for
from repro.launch.serve import BatchedServer, Request
from repro.models import build_model
from repro.models.attention import attention_block, init_attention


def _tiny_model(arch="llama32-1b", n_layers=2, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _isolated_decode(model, params, prompt: np.ndarray, gen: int,
                     max_len: int) -> list[int]:
    """Greedy decode of one request alone in a fresh contiguous cache."""
    cache = model.init_cache(1, max_len)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cache
    )
    out = [int(jnp.argmax(logits[0, -1]))]
    while len(out) < gen:
        logits, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache
        )
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def _requests(cfg, lens, gen, seed0=100):
    return [
        Request(i, np.random.default_rng(seed0 + i).integers(
            0, cfg.vocab_size, ln, dtype=np.int32), gen)
        for i, ln in enumerate(lens)
    ]


# ---------------------------------------------------------------------------
# Paged serving == contiguous serving == isolated decode
# ---------------------------------------------------------------------------


def test_paged_slot_swap_matches_isolated():
    """Acceptance: heterogeneous prompts (incl. exact page multiples and
    generations crossing page boundaries) through a paged server with a
    pool SMALLER than slots x max_len — every request token-for-token
    equals its isolated contiguous decode."""
    cfg, model, params = _tiny_model()
    gen, max_len, page = 3, 48, 8
    lens = [4, 16, 23, 8, 17, 9]  # 8 = exact page; 23+2 crosses a boundary
    reqs = _requests(cfg, lens, gen)
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len,
                           paged=True, page_size=page, num_pages=8)
    assert server.num_pages < 2 * (max_len // page), "pool must undercut dense"
    stats = server.run(reqs)
    assert stats["requests"] == len(lens)
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, len(r.prompt), r.out, want)
    assert stats["decode_compiles"] == 1, stats
    assert stats["pages"]["leaked"] == 0, stats
    assert stats["pages"]["peak_in_use"] <= 8, stats
    # per-request reservation is by need, not by global max_len
    assert stats["kv_bytes_reserved_per_request"]["max"] < (
        server._page_bytes * (max_len // page)
    ), stats


@pytest.mark.parametrize("arch", ["zamba2-1.2b"])
def test_paged_slot_swap_hybrid_family(arch):
    """Hybrid (mamba2 + shared attention): only the shared-attention KV is
    paged; recurrent ssm/conv rows stay dense. Slot swaps must still match
    isolated decoding exactly."""
    cfg, model, params = _tiny_model(arch, n_layers=4, seed=1)
    gen, max_len = 3, 32
    reqs = _requests(cfg, [4, 9, 5], gen)
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len,
                           paged=True, page_size=4, num_pages=10)
    stats = server.run(reqs)
    assert stats["requests"] == 3
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (arch, r.rid, r.out, want)
    assert stats["pages"]["leaked"] == 0, stats
    assert stats["decode_compiles"] == 1, stats


def test_paged_composes_with_packed_engine():
    """The paged gather/scatter must compose with the packed quantized
    kernel path (fused QKV/gate+up launches feed the paged writes)."""
    from repro.core import QuantPolicy, restructure

    cfg, model, params = _tiny_model()
    qm = restructure(params, QuantPolicy(bits=4, packed=True))
    ex = qm.as_executable(group=True)
    gen, max_len = 3, 32
    reqs = _requests(cfg, [4, 11, 6], gen)
    server = BatchedServer(model, ex, batch_slots=2, max_len=max_len,
                           paged=True, page_size=8, num_pages=6,
                           prefill_chunk=8)
    stats = server.run(reqs)
    assert stats["requests"] == 3
    for r in reqs:
        want = _isolated_decode(model, ex, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, r.out, want)
    assert stats["pages"]["leaked"] == 0
    assert stats["decode_compiles"] == 1


def test_paged_pool_backpressure_defers_admission():
    """When the free-page budget can't host another request, admission
    waits for a retirement instead of failing — and every request still
    completes correctly."""
    cfg, model, params = _tiny_model()
    gen, page = 2, 4
    lens = [14, 13, 12, 5]
    reqs = _requests(cfg, lens, gen)
    # each request needs ceil((len+1)/4) pages: 4,4,4,2 — pool of 6 forces
    # strictly serial admission even though 2 slots are free
    server = BatchedServer(model, params, batch_slots=2, max_len=24,
                           paged=True, page_size=page, num_pages=6)
    stats = server.run(reqs)
    assert stats["requests"] == len(lens)
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, 24)
        assert r.out == want, (r.rid, r.out, want)
    assert stats["pages"]["leaked"] == 0
    assert stats["pages"]["peak_in_use"] <= 6


def test_paged_request_larger_than_pool_rejected():
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=1, max_len=40,
                           paged=True, page_size=4, num_pages=3)
    [big] = _requests(cfg, [20], gen=4)  # needs 6 pages > pool of 3
    with pytest.raises(ValueError, match="pool size"):
        server._fill_slots([big])


def test_zero_gen_request_rejected():
    """max_new == 0 under-reserves pages (prompt - 1 rows) while prefill
    writes the full prompt — the tail would scatter into a live
    neighbour's page. Rejected up front, dense and paged alike."""
    cfg, model, params = _tiny_model()
    for kw in ({}, {"paged": True, "page_size": 8, "num_pages": 6}):
        server = BatchedServer(model, params, batch_slots=1, max_len=24,
                               **kw)
        [zero] = _requests(cfg, [9], gen=1)
        zero.max_new = 0
        with pytest.raises(ValueError, match="max_new"):
            server._fill_slots([zero])


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_whole_prompt_and_interleaves():
    """Acceptance: a prompt longer than the decode bucket is fed in
    page-sized waves; tokens are identical to whole-prompt prefill AND at
    least one decode step runs between prefill waves (the long prompt must
    not stall the short request's decode)."""
    cfg, model, params = _tiny_model()
    gen, max_len = 6, 64
    lens = [5, 33, 6]  # 33 >> chunk of 8 -> 5 waves
    reqs = _requests(cfg, lens, gen)
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len,
                           paged=True, page_size=8, num_pages=12,
                           prefill_chunk=8)
    stats = server.run(reqs)
    assert stats["requests"] == len(lens)
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, len(r.prompt), r.out, want)
    assert stats["decode_compiles"] == 1, stats
    assert stats["pages"]["leaked"] == 0, stats
    # interleave proof: some decode step ran BETWEEN two prefill waves
    ev = server.events
    first_p, last_p = ev.index("prefill"), len(ev) - 1 - ev[::-1].index("prefill")
    assert "decode" in ev[first_p:last_p], ev
    # chunking bounds the prefill bucket: never the whole 33-token prompt
    assert max(stats["prefill_buckets"]) <= 8, stats


def test_chunked_prefill_final_wave_at_buffer_edge_dense():
    """Regression: a late chunk wave whose PADDED bucket tile overruns the
    cache buffer (starts + bucket > max_len) must not corrupt live KV. A
    dynamic_update_slice would clamp its start and shift the tile onto
    positions 1..7; the per-position scatter drops the padding instead."""
    cfg, model, params = _tiny_model()
    gen, max_len = 1, 9
    reqs = _requests(cfg, [9], gen)  # 9 + 1 - 1 == max_len: admissible
    server = BatchedServer(model, params, batch_slots=1, max_len=max_len,
                           prefill_chunk=8)  # final wave: starts=8, lb=8
    server.run(reqs)
    want = _isolated_decode(model, params, reqs[0].prompt, gen, max_len)
    assert reqs[0].out == want, (reqs[0].out, want)


def test_chunked_prefill_dense_cache():
    """Chunked prefill is orthogonal to paging: the contiguous cache path
    must produce identical tokens too."""
    cfg, model, params = _tiny_model(seed=2)
    gen, max_len = 4, 48
    lens = [21, 4]
    reqs = _requests(cfg, lens, gen, seed0=40)
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len,
                           prefill_chunk=8)
    stats = server.run(reqs)
    assert stats["requests"] == 2
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, r.out, want)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b"])
def test_chunked_prefill_recurrent_families(arch):
    """Recurrent state (wkv/ssm/conv/shift carries) must continue exactly
    across prefill waves — chunked prefill is a state-carry stress test."""
    cfg, model, params = _tiny_model(arch, n_layers=2, seed=1)
    gen, max_len = 3, 32
    reqs = _requests(cfg, [13, 4], gen)
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len,
                           prefill_chunk=4)
    stats = server.run(reqs)
    assert stats["requests"] == 2
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (arch, r.rid, r.out, want)


# ---------------------------------------------------------------------------
# Prefix sharing: copy-on-write paged serving == isolated decoding
# ---------------------------------------------------------------------------


def test_prefix_shared_serving_matches_isolated_and_saves_work():
    """Acceptance: a common-system-prompt workload served with the prefix
    cache produces token-for-token the isolated decodes, actually SHARES
    (hits, retained pages, fewer prefill tokens than the prompts sum) and
    leaks nothing — including after the cache itself is dropped."""
    cfg, model, params = _tiny_model()
    gen, max_len, page = 3, 64, 8
    rng = np.random.default_rng(17)
    common = rng.integers(0, cfg.vocab_size, 19, dtype=np.int32)
    tails = [4, 9, 1, 6, 13]
    reqs = [
        Request(i, np.concatenate(
            [common, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)]
        ), gen)
        for i, t in enumerate(tails)
    ]
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len,
                           paged=True, page_size=page, num_pages=24,
                           prefix_cache=True)
    stats = server.run(reqs)
    assert stats["requests"] == len(tails)
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, len(r.prompt), r.out, want)
    # sharing really happened: 19 common tokens = 2 full pages of 8
    assert stats["prefix"]["hits"] > 0, stats["prefix"]
    assert stats["prefix"]["hit_tokens"] > 0
    assert stats["pages"]["peak_shared"] > 0, stats["pages"]
    # the matched prefix was NOT recomputed
    assert stats["prefill_tokens"] < sum(len(r.prompt) for r in reqs)
    # reservation accounting is net of shared pages
    assert stats["kv_bytes_reserved_per_request"]["mean"] < (
        server._page_bytes * pages_for(len(reqs[0].prompt) + gen - 1, page)
    )
    assert stats["pages"]["leaked"] == 0, stats["pages"]
    assert stats["decode_compiles"] == 1, stats
    server.drop_prefix_cache()
    assert server.alloc.in_use == 0


def test_prefix_full_page_aligned_hit_copy_on_writes():
    """A prompt matched IN FULL on a page boundary rolls back one token to
    recompute its logits; that write would land in a shared page — the
    scheduler must copy-on-write it, never scatter into refcount > 1."""
    cfg, model, params = _tiny_model()
    gen, page = 3, 8
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, 2 * page, dtype=np.int32)
    reqs = [Request(i, prompt.copy(), gen) for i in range(2)]
    server = BatchedServer(model, params, batch_slots=1, max_len=32,
                           paged=True, page_size=page, num_pages=10,
                           prefix_cache=True)
    stats = server.run(reqs)
    want = _isolated_decode(model, params, prompt, gen, 32)
    for r in reqs:
        assert r.out == want, (r.rid, r.out, want)
    assert stats["pages"]["cow_copies"] == 1, stats["pages"]
    assert stats["prefix"]["hits"] == 1
    # second request re-ran exactly ONE prompt token (the rollback)
    assert stats["prefill_tokens"] == len(prompt) + 1
    assert stats["pages"]["leaked"] == 0
    server.drop_prefix_cache()
    assert server.alloc.in_use == 0


def test_prefix_cache_eviction_under_pool_pressure():
    """When the pool cannot host a new request, cached prefixes are
    evicted LRU-first instead of stalling admission forever."""
    cfg, model, params = _tiny_model()
    gen, page = 2, 4
    # distinct prompts: each fills the index; a pool of 6 cannot hold the
    # accumulated cache AND admit the next request
    reqs = _requests(cfg, [11, 10, 12, 9], gen)
    server = BatchedServer(model, params, batch_slots=1, max_len=20,
                           paged=True, page_size=page, num_pages=6,
                           prefix_cache=True)
    stats = server.run(reqs)
    assert stats["requests"] == 4
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, 20)
        assert r.out == want, (r.rid, r.out, want)
    assert stats["prefix"]["evicted"] > 0, stats["prefix"]
    assert stats["pages"]["leaked"] == 0
    server.drop_prefix_cache()
    assert server.alloc.in_use == 0


def test_cross_wave_identical_prefix_dedup():
    """Requests with identical prefixes arriving in the SAME wave used to
    all prefill in full (the index only learns a prompt once it is fully
    prefilled). Admission now detects the pending overlap and serializes
    just their prefill: the first request admits alone, the rest admit one
    wave later as ordinary cache hits."""
    cfg, model, params = _tiny_model()
    gen, max_len, page = 3, 32, 8
    rng = np.random.default_rng(29)
    common = rng.integers(0, cfg.vocab_size, 2 * page, dtype=np.int32)
    tails = [3, 5, 2]
    prompts = [np.concatenate(
        [common, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)]
    ) for t in tails]

    def serve(slots):
        reqs = [Request(i, p.copy(), gen) for i, p in enumerate(prompts)]
        server = BatchedServer(model, params, batch_slots=slots,
                               max_len=max_len, paged=True, page_size=page,
                               num_pages=24, prefix_cache=True)
        stats = server.run(reqs)
        server.drop_prefix_cache()
        assert server.alloc.in_use == 0
        return reqs, stats

    # 3 slots, 3 requests: without dedup they would all admit in wave 1
    # and share NOTHING; with it, every later request hits the cache
    reqs, stats = serve(3)
    assert stats["prefix"]["hits"] == len(tails) - 1, stats["prefix"]
    assert stats["prefix"]["admission_deferrals"] > 0, stats["prefix"]
    # the shared prefix prefilled ONCE, not three times
    assert stats["prefill_tokens"] < sum(len(p) for p in prompts)
    assert stats["pages"]["leaked"] == 0
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, r.out, want)
    # sharing behaviour must be slot-count independent in outcome
    reqs1, stats1 = serve(1)
    assert [r.out for r in reqs1] == [r.out for r in reqs]
    assert stats1["prefix"]["hits"] == len(tails) - 1


def test_prefix_state_budget_degrades_depth_not_correctness():
    """zamba2 with a snapshot budget too small for ANY boundary state:
    recurrent prefix hits disappear (match walks back to nothing) but
    every request still decodes exactly — the budget trades hit depth for
    memory, never correctness."""
    cfg, model, params = _tiny_model("zamba2-1.2b", n_layers=2, seed=1)
    gen, max_len, page = 2, 32, 4
    rng = np.random.default_rng(13)
    common = rng.integers(0, cfg.vocab_size, 2 * page, dtype=np.int32)
    prompts = [np.concatenate(
        [common, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)]
    ) for t in (3, 5)]
    reqs = [Request(i, p.copy(), gen) for i, p in enumerate(prompts)]
    server = BatchedServer(model, params, batch_slots=1, max_len=max_len,
                           paged=True, page_size=page, num_pages=24,
                           prefix_cache=True, prefix_state_budget=1)
    stats = server.run(reqs)
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, r.out, want)
    assert stats["prefix"]["states_held"] == 0, stats["prefix"]
    assert stats["prefix"]["states_evicted"] > 0, stats["prefix"]
    assert stats["pages"]["leaked"] == 0
    server.drop_prefix_cache()
    assert server.alloc.in_use == 0


def test_cross_wave_dedup_no_deadlock_on_distinct_prompts():
    """Distinct prompts must never defer — admission proceeds exactly as
    before when there is nothing to share."""
    cfg, model, params = _tiny_model()
    reqs = _requests(cfg, [9, 11, 6], gen=2)
    server = BatchedServer(model, params, batch_slots=3, max_len=24,
                           paged=True, page_size=4, num_pages=24,
                           prefix_cache=True)
    stats = server.run(reqs)
    assert stats["requests"] == 3
    assert stats["prefix"]["admission_deferrals"] == 0, stats["prefix"]
    assert stats["pages"]["leaked"] == 0
    server.drop_prefix_cache()
    assert server.alloc.in_use == 0


@pytest.mark.parametrize("arch", ["llama32-1b", "zamba2-1.2b"])
def test_prefix_shared_differential_fuzz(arch):
    """Differential fuzz: randomized prompt sets with overlapping prefixes
    served through prefix-shared paged serving must be token-for-token
    identical to isolated per-request decoding — attention (llama) and
    hybrid recurrent (zamba2, boundary-state snapshots) cache families."""
    cfg, model, params = _tiny_model(arch, n_layers=2, seed=1)
    gen, max_len, page = 2, 40, 4
    total_hits = 0
    for trial in range(3):
        rng = np.random.default_rng(1000 * trial + 7)
        bases = [rng.integers(0, cfg.vocab_size, int(n), dtype=np.int32)
                 for n in rng.integers(5, 14, size=2)]
        prompts = []
        for _ in range(5):
            base = bases[int(rng.integers(0, 2))]
            cut = int(rng.integers(1, len(base) + 1))
            tail = rng.integers(0, cfg.vocab_size, int(rng.integers(0, 6)),
                                dtype=np.int32)
            p = np.concatenate([base[:cut], tail])
            prompts.append(p[: max_len - gen - 1])
        reqs = [Request(i, p, gen) for i, p in enumerate(prompts)]
        server = BatchedServer(model, params, batch_slots=2,
                               max_len=max_len, paged=True, page_size=page,
                               num_pages=40, prefix_cache=True,
                               prefill_chunk=int(rng.integers(0, 2)) * 8)
        stats = server.run(reqs)
        assert stats["requests"] == len(reqs)
        for r in reqs:
            want = _isolated_decode(model, params, r.prompt, gen, max_len)
            assert r.out == want, (arch, trial, r.rid, list(r.prompt),
                                   r.out, want)
        assert stats["pages"]["leaked"] == 0, (arch, trial, stats["pages"])
        total_hits += stats["prefix"]["hits"]
        server.drop_prefix_cache()
        assert server.alloc.in_use == 0, (arch, trial)
    # across trials the overlapping prefixes must actually share
    assert total_hits > 0, (arch, total_hits)


# ---------------------------------------------------------------------------
# Pallas paged-attention kernel vs reference
# ---------------------------------------------------------------------------


def test_paged_attention_kernel_matches_reference():
    rng = np.random.default_rng(0)
    b, kvh, g, hd, p_total, page, n_pages = 3, 2, 4, 32, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(p_total, page, kvh, hd)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(p_total, page, kvh, hd)).astype(np.float32))
    # shuffled, non-overlapping physical pages per row
    pt = jnp.asarray(rng.permutation(p_total)[: b * n_pages]
                     .reshape(b, n_pages).astype(np.int32))
    lens = jnp.asarray([17, 1, 31], jnp.int32)
    for kw in ({}, {"window": 9}, {"chunk": 16}):
        ref = paged_attention_reference(q, kp, vp, pt, lens, **kw)
        out = paged_attention_pallas(q, kp, vp, pt, lens, interpret=True,
                                     **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5, err_msg=str(kw))


def test_attention_block_kernel_dispatch_glue(monkeypatch):
    """CPU CI never takes the TPU kernel branch of attention_block — force
    it (interpret mode) and pin that the dispatch glue (kv-major q reshape,
    post-write k_len, window/chunk passthrough) matches the gather path."""
    import repro.kernels.paged_attention as pa_mod
    import repro.models.attention as attn_mod

    cfg, _, _ = _tiny_model()
    p = init_attention(jax.random.PRNGKey(5), cfg, jnp.float32)
    rng = np.random.default_rng(6)
    b, smax, page, pool = 2, 16, 4, 10
    n_pages = smax // page
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    lens = jnp.asarray([7, 3], jnp.int32)
    pos = lens[:, None]
    pages = jnp.asarray(rng.normal(
        size=(2, pool, page, cfg.n_kv_heads, cfg.hd)).astype(np.float32))
    table = jnp.asarray(rng.permutation(pool)[: b * n_pages]
                        .reshape(b, n_pages).astype(np.int32))

    def paged(window=0):
        return attention_block(
            p, cfg, x, pos, kv_pages=pages, page_table=table,
            cache_len=lens, seq_lens=jnp.asarray([1, 1], jnp.int32),
            layer_window=window,
        )

    out_ref, cache_ref = paged()
    calls = {"n": 0}
    real = pa_mod.paged_attention_pallas

    def counting(*a, **k):
        calls["n"] += 1
        assert k.get("interpret"), "CPU dispatch must use interpret mode"
        return real(*a, **k)

    monkeypatch.setattr(pa_mod, "paged_attention_pallas", counting)
    monkeypatch.setattr(attn_mod, "_use_paged_kernel", lambda: True)
    out_k, cache_k = paged()
    assert calls["n"] == 1, "kernel branch was not taken"
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(cache_k), np.asarray(cache_ref))
    out_w, _ = paged(window=4)  # window plumb-through, still via kernel
    assert calls["n"] == 2
    monkeypatch.setattr(attn_mod, "_use_paged_kernel", lambda: False)
    out_w_ref, _ = paged(window=4)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(out_w_ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_kernel_empty_row_exact_zeros():
    """len == 0 rows must come out EXACTLY zero (not a garbage average —
    the online-softmax p-masking guard)."""
    rng = np.random.default_rng(1)
    b, kvh, g, hd, p_total, page, n_pages = 2, 1, 2, 32, 8, 8, 3
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(p_total, page, kvh, hd)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(p_total, page, kvh, hd)).astype(np.float32))
    pt = jnp.zeros((b, n_pages), jnp.int32)
    lens = jnp.asarray([0, 5], jnp.int32)
    out = paged_attention_pallas(q, kp, vp, pt, lens, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    assert np.abs(np.asarray(out[1])).max() > 0


# ---------------------------------------------------------------------------
# Fully-masked softmax guard (replaces the k_len >= 1 clamp)
# ---------------------------------------------------------------------------


def test_empty_row_attention_is_exact_zero_not_garbage_key():
    """Regression: rows with NO valid key (empty/frozen slot, k_len == 0)
    used to clamp in one garbage key; they must now produce exact zeros
    with no NaN — for both the dense and the paged cache layouts."""
    cfg, _, _ = _tiny_model()
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s, smax = 2, 1, 16
    x = jnp.asarray(np.random.default_rng(2).normal(
        size=(b, s, cfg.d_model)).astype(np.float32))
    pos = jnp.zeros((b, s), jnp.int32)
    # row 0 empty (len 0, frozen), row 1 has 3 cached keys and writes one
    kv = jnp.asarray(np.random.default_rng(3).normal(
        size=(2, b, smax, cfg.n_kv_heads, cfg.hd)).astype(np.float32))
    out, _ = attention_block(
        p, cfg, x, pos, kv_cache=kv,
        cache_len=jnp.asarray([0, 3], jnp.int32),
        seq_lens=jnp.asarray([0, 1], jnp.int32),
    )
    out = np.asarray(out)
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[0], 0.0)
    assert np.abs(out[1]).max() > 0
    # paged layout, same contract
    pages = jnp.asarray(np.random.default_rng(4).normal(
        size=(2, 6, 4, cfg.n_kv_heads, cfg.hd)).astype(np.float32))
    table = jnp.asarray([[5, 2, 0, 1], [3, 4, 1, 0]], jnp.int32)
    out_p, _ = attention_block(
        p, cfg, x, pos, kv_pages=pages, page_table=table,
        cache_len=jnp.asarray([0, 3], jnp.int32),
        seq_lens=jnp.asarray([0, 1], jnp.int32),
    )
    out_p = np.asarray(out_p)
    assert np.isfinite(out_p).all()
    np.testing.assert_array_equal(out_p[0], 0.0)


def test_decode_all_slots_empty_no_nan():
    """A decode step where EVERY slot is empty/inactive must stay finite
    end-to-end (the old clamp hid this; the guard must too — by design,
    not by accident)."""
    cfg, model, params = _tiny_model()
    cache = model.init_cache(2, 16)
    logits, cache2 = model.decode_step(
        params, jnp.zeros((2, 1), jnp.int32), cache,
        active=jnp.asarray([False, False]),
    )
    assert np.isfinite(np.asarray(logits)).all()
    np.testing.assert_array_equal(np.asarray(cache2["len"]),
                                  np.asarray(cache["len"]))
