"""Serving under pressure: the resilience contract.

The robustness tentpole adds on-demand page growth, victim preemption
with replay-restore, graceful spec-decode degradation, and a
deterministic fault-injection harness. What these tests pin:

* CHAOS EXACTNESS: with ``oop`` faults injected at every decode tick of
  a mixed plain+speculative workload, every preempted-and-restored
  greedy stream is BIT-IDENTICAL to the uninterrupted run, and both the
  target and draft pools return to zero pages in use — for attention
  (llama) and hybrid recurrent (zamba2) families,
* page growth admits strictly more concurrency than full reservation on
  the same pool, and the extra concurrency is paid for with preemptions,
  never with wrong tokens or leaks,
* the victim policy (priority, then fewest-emitted, then
  latest-admitted; oldest live always exempt) and the replay sequence
  (prompt + out[:-1]) are unit-pinned,
* ``run_with_retries`` never retries ``OutOfPages`` (real pool
  exhaustion must surface to the preemption path, not burn retries),
* exhausting a growth pool with preemption disabled raises a
  diagnostic ``SchedulerStall`` naming every live slot's progress and
  page holdings — not a bare RuntimeError,
* acceptance below ``spec_floor`` degrades rounds to plain decode and
  later re-probes (the drafter's backlog drain makes resumed drafting
  exact), with unchanged output,
* SIGTERM (via PreemptionGuard) and ``max_wall_s`` drain the server:
  partial streams retire with ``status="preempted"`` and nothing leaks.
"""
import os
import signal

import jax
import numpy as np
import pytest
from serve_helpers import make_requests as _requests
from serve_helpers import serve_once as _serve
from serve_helpers import tiny_model as _tiny_model

from repro.kvcache.allocator import OutOfPages, PageAllocator
from repro.launch.serve import BatchedServer, Request
from repro.runtime.fault import PreemptionGuard, run_with_retries
from repro.runtime.faultinject import FaultInjector, TransientFault
from repro.runtime.resilience import (
    AcceptanceWindow,
    SchedulerStall,
    pick_victim,
    replay_sequence,
)


# ---------------------------------------------------------------------------
# Unit pins: victim policy, replay sequence, acceptance window, injector
# ---------------------------------------------------------------------------


def _req(rid, *, priority=0, emitted=0, seq_no=0):
    r = Request(rid, np.zeros(4, np.int32), 8, priority=priority)
    r.out = list(range(emitted))
    r.seq_no = seq_no
    return r


def test_pick_victim_policy():
    """Lowest priority first, then fewest emitted (cheapest replay),
    then latest admitted; the exempt seq_no is never picked."""
    live = [(0, _req(0, priority=1, emitted=0, seq_no=0)),
            (1, _req(1, priority=0, emitted=9, seq_no=1)),
            (2, _req(2, priority=0, emitted=2, seq_no=2))]
    assert pick_victim(live, exempt_seq=0)[1].rid == 2  # prio 0, fewest out
    # tie on priority and emitted -> latest admitted loses
    live = [(0, _req(0, emitted=3, seq_no=0)),
            (1, _req(1, emitted=3, seq_no=1)),
            (2, _req(2, emitted=3, seq_no=2))]
    assert pick_victim(live, exempt_seq=0)[1].rid == 2
    # the oldest (exempt) is untouchable even when it sorts first
    live = [(0, _req(0, priority=-5, seq_no=0))]
    assert pick_victim(live, exempt_seq=0) is None


def test_replay_sequence():
    prompt = np.arange(5, dtype=np.int32)
    assert np.array_equal(replay_sequence(prompt, []), prompt)
    seq = replay_sequence(prompt, [10, 11, 12])
    # all emitted tokens except the last: the final one is re-fed by the
    # next decode step, never re-sampled
    assert seq.tolist() == [0, 1, 2, 3, 4, 10, 11]
    assert seq.dtype == np.int32


def test_acceptance_window():
    w = AcceptanceWindow(floor=0.5, window=4)
    assert not w.degraded()          # under-filled windows never degrade
    w.record(drafted=2, accepted=2)  # two hits
    assert not w.degraded() and w.rate == 1.0
    w.record(drafted=2, accepted=0)  # two misses -> rate 0.5, not < floor
    assert not w.degraded()
    w.record(drafted=2, accepted=0)  # slides to [0, 0, 0, 0]
    assert w.degraded() and w.rate == 0.0
    w.age()                          # degraded rounds age the window out
    assert not w.degraded()          # under-filled again: drafting re-probes
    with pytest.raises(ValueError):
        AcceptanceWindow(0.5, 0)


def test_fault_plan_parse_and_determinism():
    inj = FaultInjector("oop@tick2, fail.decode@tick0, slow@tick1", seed=7)
    inj.set_tick(0)
    assert not inj.take("oop")
    assert not inj.take("fail", "prefill")   # seam-scoped: decode only
    assert inj.take("fail", "decode")
    assert not inj.take("fail", "decode")    # tick entries are single-shot
    inj.set_tick(2)
    assert inj.take("oop") and not inj.take("oop")
    assert inj.summary()["pending"] == 1     # slow@tick1 was skipped over
    # probabilistic entries replay exactly under the same seed
    fires = []
    for seed in (3, 3, 4):
        inj = FaultInjector("fail@p0.5", seed=seed)
        inj.set_tick(0)
        fires.append([inj.take("fail") for _ in range(32)])
    assert fires[0] == fires[1]
    assert fires[0] != fires[2]
    assert any(fires[0]) and not all(fires[0])
    for bad in ("oom@tick1", "fail@p1.5", "fail@soon", "fail.draft@tick1"):
        with pytest.raises(ValueError):
            FaultInjector(bad)


def test_injector_on_step_raises_transient():
    inj = FaultInjector("fail@tick3", slow_s=0.0)
    inj.set_tick(3)
    with pytest.raises(TransientFault):
        inj.on_step("decode")
    inj.on_step("decode")  # spent: no-op afterwards


def test_run_with_retries_excludes_out_of_pages():
    """Pool exhaustion is NOT transient: retrying it burns the retry
    budget without freeing a page. It must surface immediately to the
    caller (the serve path answers it with preemption instead)."""
    calls = []

    def exhausted():
        calls.append(1)
        raise OutOfPages("need 2 pages, 0 free")

    with pytest.raises(OutOfPages):
        run_with_retries(exhausted, max_retries=3, base_delay_s=0.0)
    assert len(calls) == 1  # never retried, even though it IS a RuntimeError

    # injected transient faults DO retry (they subclass RuntimeError)
    flaky = iter([TransientFault("boom"), "ok"])

    def step():
        v = next(flaky)
        if isinstance(v, Exception):
            raise v
        return v

    assert run_with_retries(step, max_retries=2, base_delay_s=0.0) == "ok"

    # the exclusion list is overridable
    with pytest.raises(ValueError):
        run_with_retries(lambda: (_ for _ in ()).throw(ValueError("x")),
                         max_retries=2, base_delay_s=0.0,
                         retriable=(Exception,), non_retriable=(ValueError,))


def test_allocator_audit_catches_corruption():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2)
    alloc.audit()  # healthy
    alloc._free.append(pages[0])  # corrupt: page both live and free
    with pytest.raises(AssertionError):
        alloc.audit()


# ---------------------------------------------------------------------------
# Growth admits more concurrency than full reservation (same pool)
# ---------------------------------------------------------------------------


def test_growth_admits_more_concurrency():
    """The tentpole's economic claim: reserving prompt-only pages and
    growing per decode tick admits strictly more concurrent requests
    than reserving the full high-water mark — on the SAME pool — and the
    pressure is absorbed by preemption + exact replay, not wrong
    tokens."""
    cfg, model, params = _tiny_model()
    kw = dict(batch_slots=4, max_len=16, paged=True, page_size=8,
              num_pages=6)
    lens, gen = [8, 8, 8, 8], 8
    full, fstats = _serve(model, params, _requests(cfg, lens, gen), **kw)
    grow, gstats = _serve(model, params, _requests(cfg, lens, gen),
                          page_growth=True, **kw)
    assert grow == full, (grow, full)
    f, g = fstats["resilience"], gstats["resilience"]
    # full reservation: 2 pages/request -> only 3 of 4 slots admit on a
    # 6-page pool; growth: 1 page/request -> all 4 run at once
    assert f["peak_concurrency"] == 3, f
    assert g["peak_concurrency"] == 4, g
    assert f["preemptions"] == 0, f
    assert g["preemptions"] > 0 and g["replays"] > 0, g  # growth's price
    assert g["replay_tokens"] > 0, g
    assert any(e.startswith("preempt:") for e in gstats["_events"])
    assert any(e.startswith("replay:") for e in gstats["_events"])
    for stats in (fstats, gstats):
        assert stats["pages"]["leaked"] == 0, stats["pages"]


def test_priority_steers_victim_choice():
    """A low-priority request is preempted before a younger neutral
    one."""
    cfg, model, params = _tiny_model()
    kw = dict(batch_slots=3, max_len=16, paged=True, page_size=8,
              num_pages=4, page_growth=True)
    lens, gen = [8, 8, 8], 6
    reqs = _requests(cfg, lens, gen, priorities=[0, -1, 0])
    base, _ = _serve(model, params, _requests(cfg, lens, gen), batch_slots=3,
                     max_len=16, paged=True, page_size=8, num_pages=6)
    out, stats = _serve(model, params, reqs, **kw)
    assert out == base
    assert stats["resilience"]["preemptions"] > 0
    victim = next(r for r in reqs if r.rid == 1)
    assert victim.preemptions > 0  # the low-priority request paid
    assert stats["pages"]["leaked"] == 0


# ---------------------------------------------------------------------------
# Chaos: injected pool exhaustion at every tick, streams must not move
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,n_layers,ticks", [
    ("llama32-1b", 2, (0, 1, 2, 3)),
    ("zamba2-1.2b", 4, (0, 1, 2)),
])
def test_chaos_oop_streams_bit_identical(arch, n_layers, ticks):
    """The headline robustness pin: a mixed plain+speculative greedy
    workload with an ``oop`` fault injected at each decode tick in turn.
    Every run must emit streams bit-identical to the uninterrupted
    baseline, preempt at least once per effective injection, and drain
    both pools to zero — attention AND hybrid recurrent caches."""
    cfg, model, params = _tiny_model(arch, n_layers=n_layers)
    bad_draft = model.init(jax.random.PRNGKey(99))  # rollback-heavy
    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=8, page_growth=True, speculate=2,
              draft_params=bad_draft)
    lens, gens = [6, 9, 5], [8, 2, 8]  # gen 2 rides plainly (no drafting)
    base, bstats = _serve(model, params, _requests(cfg, lens, gens), **kw)
    assert bstats["resilience"]["preemptions"] == 0, (
        "baseline must be pressure-free so preemptions are injected only",
        bstats["resilience"])
    total_preempts = 0
    for tick in ticks:
        out, stats = _serve(model, params, _requests(cfg, lens, gens),
                            inject=f"oop@tick{tick}", **kw)
        res = stats["resilience"]
        assert out == base, (arch, tick, out, base)
        if res["injected"]["fired"]:
            assert res["preemptions"] >= 1, (tick, res)
            assert res["replays"] >= 1, (tick, res)
            total_preempts += res["preemptions"]
        assert stats["pages"]["leaked"] == 0, (tick, stats["pages"])
        assert stats["spec"]["draft_pages_leaked"] == 0, (tick, stats["spec"])
    assert total_preempts >= 3, total_preempts


def test_transient_faults_retry_exactly():
    """Injected step failures and latency are absorbed by
    ``run_with_retries`` around the pure jitted steps: streams are
    unchanged and no preemption is needed."""
    cfg, model, params = _tiny_model()
    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=24)
    lens, gen = [6, 9], 6
    base, _ = _serve(model, params, _requests(cfg, lens, gen), **kw)
    out, stats = _serve(model, params, _requests(cfg, lens, gen),
                        inject="fail@tick1,slow@tick0,fail.prefill@tick0",
                        **kw)
    assert out == base
    res = stats["resilience"]
    assert res["injected"]["fired"], res  # the faults really fired
    assert res["preemptions"] == 0, res
    assert stats["pages"]["leaked"] == 0


def test_chaos_composes_with_prefix_cache():
    """Preempting a request that retains shared prefix pages must not
    free them out from under the index (use-after-free): the per-
    preemption ``prefix.audit()`` guards it, streams stay exact, and
    dropping the cache at the end returns the pool to zero."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(17)
    common = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    prompts = [np.concatenate(
        [common, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)]
    ) for t in (3, 5)]
    gen = 6

    def reqs():
        return [Request(i, p.copy(), gen) for i, p in enumerate(prompts)]

    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=24, prefix_cache=True, page_growth=True)
    server = BatchedServer(model, params, **kw)
    server.run(reqs())  # warm the index
    warm = reqs()
    server.run(warm)
    base = {r.rid: r.out for r in warm}

    chaos = BatchedServer(model, params, **kw)
    chaos.run(reqs())  # warm this server's index fault-free
    chaos.inject = FaultInjector("oop@tick1", seed=0)  # arm the hot run only
    hot = reqs()
    stats = chaos.run(hot)
    assert {r.rid: r.out for r in hot} == base
    assert chaos.prefix.hits >= 1
    assert stats["resilience"]["preemptions"] >= 1, stats["resilience"]
    assert stats["pages"]["leaked"] == 0, stats["pages"]
    chaos.drop_prefix_cache()
    assert chaos.alloc.in_use == 0


# ---------------------------------------------------------------------------
# Stall diagnostics
# ---------------------------------------------------------------------------


def test_scheduler_stall_is_diagnostic():
    """Growth with preemption disabled on an exhausted pool must raise a
    SchedulerStall that names every live slot's request, progress and
    page holdings — the debuggable replacement for the old bare
    RuntimeError."""
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=2, max_len=16,
                           paged=True, page_size=4, num_pages=4,
                           page_growth=True, preemption=False)
    with pytest.raises(SchedulerStall) as ei:
        server.run(_requests(cfg, [4, 4], 8))
    e = ei.value
    assert len(e.slots) == 2
    assert e.free_pages == 0
    for d in e.slots:
        assert d.pages_held == 2 and d.pages_pending > 0, d
        assert f"rid={d.rid}" in str(e)
    assert "pages free" in str(e)


# ---------------------------------------------------------------------------
# Spec-decode degradation under a bad acceptance window
# ---------------------------------------------------------------------------


def test_spec_floor_degrades_and_recovers():
    """An adversarial drafter pushes trailing acceptance below the
    floor: the server stops paying draft forwards for those rounds
    (``degraded_rounds``), keeps emitting the exact greedy stream, and
    re-probes once the window ages out — which forces the drafter's
    catch-up backlog drain and pins ITS exactness too."""
    cfg, model, params = _tiny_model()
    bad_draft = model.init(jax.random.PRNGKey(99))
    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=24, speculate=2, draft_params=bad_draft)
    lens, gen = [6, 9], 12
    base, bstats = _serve(model, params, _requests(cfg, lens, gen), **kw)
    out, stats = _serve(model, params, _requests(cfg, lens, gen),
                        spec_floor=0.9, spec_window=4, **kw)
    assert out == base, (out, base)
    sp = stats["spec"]
    assert sp["degraded_rounds"] >= 2, sp
    # drafting resumed after degradation: more tokens drafted than one
    # window's worth, so the re-probe (and the backlog drain) really ran
    assert sp["drafted"] > 4, sp
    assert sp["degraded_rounds"] > bstats["spec"]["degraded_rounds"], (
        sp, bstats["spec"])
    assert stats["pages"]["leaked"] == 0
    assert sp["draft_pages_leaked"] == 0


# ---------------------------------------------------------------------------
# Graceful drain: SIGTERM and wall-clock
# ---------------------------------------------------------------------------


def test_sigterm_drains_with_partial_streams():
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=2, max_len=32,
                           paged=True, page_size=4, num_pages=24,
                           guard=PreemptionGuard().install())
    reqs = _requests(cfg, [6, 9], 8)
    seen = []

    def on_token(r, tok):
        seen.append((r.rid, tok))
        if len(seen) == 3:
            os.kill(os.getpid(), signal.SIGTERM)  # real signal, real guard

    try:
        stats = server.run(reqs, on_token=on_token)
    finally:
        server.guard.uninstall()
    res = stats["resilience"]
    assert res["drained"], res
    assert res["preempted_requests"] == 2, res
    assert all(r.status == "preempted" and 0 < len(r.out) < 8 for r in reqs)
    # every token the caller saw IS the partial stream, in order
    for r in reqs:
        assert [t for rid, t in seen if rid == r.rid] == r.out
    assert "drain" in server.events
    assert stats["pages"]["leaked"] == 0
    assert server.alloc.in_use == 0


def test_max_wall_clock_drains_before_admission():
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=2, max_len=32,
                           paged=True, page_size=4, num_pages=24,
                           max_wall_s=1e-9)
    reqs = _requests(cfg, [6, 9], 8)
    stats = server.run(reqs)
    res = stats["resilience"]
    assert res["drained"] and stats["requests"] == 0, (res, stats)
    assert res["unserved"] == 2, res
    assert all(r.status == "preempted" and r.out == [] for r in reqs)
    assert server.alloc.in_use == 0
