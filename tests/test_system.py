"""End-to-end system behaviour: train -> SplitQuantV2 -> serve, and the
paper's quantization-quality ordering on a real (small) trained model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import quantize_model, sqnr_db
from repro.data.pipeline import DataLoader, SyntheticLM
from repro.models import build_model
from repro.optim import adamw


def _train_tiny(steps=40):
    cfg = get_config("llama32-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params)
    ocfg = adamw.AdamWConfig(peak_lr=2e-3, warmup=5, total_steps=steps)
    loader = DataLoader(SyntheticLM(cfg.vocab_size, seed=7), 8, 48, seed=0)

    @jax.jit
    def step(p, o, b):
        (l, _), g = jax.value_and_grad(model.train_loss, has_aux=True)(p, b)
        p, o, _ = adamw.apply_updates(ocfg, p, g, o)
        return p, o, l

    first = last = None
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in loader.batch_at(s).items()}
        params, opt, loss = step(params, opt, b)
        if s == 0:
            first = float(loss)
        last = float(loss)
    return cfg, model, params, first, last


def test_train_quantize_serve_pipeline():
    cfg, model, params, first, last = _train_tiny()
    assert last < first, "training must reduce loss"

    # quantization-quality ordering on the trained weights (paper §4.2 at
    # the logit level): INT8 ~ FP; INT4 split strictly better than INT4
    # baseline; INT2 far worse.
    batch_tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 16))
        .astype(np.int32)
    )

    def logits_of(p):
        cache = model.init_cache(2, 24)
        lg, _ = model.prefill(p, {"tokens": batch_tokens}, cache)
        return lg

    ref = logits_of(params)
    errs = {}
    for tag, p in {
        "int8_base": quantize_model(params, 8, split=False),
        "int4_base": quantize_model(params, 4, split=False),
        "int4_split": quantize_model(params, 4, split=True),
        "int2_split": quantize_model(params, 2, split=True),
    }.items():
        errs[tag] = -float(sqnr_db(ref, logits_of(p)))  # lower = better
    assert errs["int8_base"] < errs["int4_base"]
    assert errs["int4_split"] < errs["int4_base"], errs
    assert errs["int2_split"] > errs["int4_split"]

    # serving with quantized weights produces tokens
    from repro.launch.serve import BatchedServer, Request

    qp = quantize_model(params, 4, split=True)
    server = BatchedServer(model, qp, batch_slots=2, max_len=32)
    reqs = [
        Request(i, np.random.default_rng(i).integers(
            0, cfg.vocab_size, 8, dtype=np.int32), 4)
        for i in range(3)
    ]
    stats = server.run(reqs)
    assert stats["requests"] == 3
    assert stats["tokens"] >= 3 * 4
