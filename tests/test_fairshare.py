"""Tenant fair-share (DRR) and SLO-controller properties.

These are the pure-function halves of the service front-end — no engine,
no clock (a fake injectable counter stands in), no threads. What they
pin:

* NO STARVATION: a backlogged tenant's head-of-line request is released
  within ``ceil(cost / (quantum * weight))`` drain rounds regardless of
  the competing load,
* WEIGHTED SHARES: over a persistent backlog, released work tracks
  ``weight`` to within one deficit quantum (+ one max request cost),
* DETERMINISM: the release order is a pure function of the submission
  sequence — same submissions, same order, every time,
* the submit clock stamps ``queued_t`` (TTFT starts at submission, not
  admission) and drives per-tenant wait stats,
* ``tune_chunk`` is clamped to ``[lo, hi]``, weakly monotone
  non-decreasing in the TTFT ratio at fixed TPOT, and TPOT-dominant
  (a violated inter-token target shrinks the chunk even when TTFT is
  also violated); ``tune_spec_floor`` raises only under TPOT violation,
  caps below 1.0, and never touches a disabled (<= 0) floor,
* ``SLOController.tick`` moves the budget in the documented direction
  from real observation streams and records history only on change.

The real-hypothesis variants ride the property-tests CI job; offline
containers fall back to the deterministic stub.
"""
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    from hypothesis_stub import hypothesis, st

from repro.serve import FairScheduler, SLOController, default_cost
from repro.serve.slo import tune_chunk, tune_spec_floor


class _Item:
    """Stand-in for a serve Request: just a cost and a queued_t slot."""

    def __init__(self, cost):
        self.prompt = [0] * int(cost)
        self.max_new = 0
        self.queued_t = None


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _drr(quantum=8.0):
    clock = _FakeClock()
    return FairScheduler(quantum=quantum, clock=clock), clock


# ---------------------------------------------------------------------------
# DRR: starvation freedom, weighted shares, determinism
# ---------------------------------------------------------------------------


def test_submit_stamps_queued_t_and_orders_fifo():
    fair, clock = _drr()
    a, b = _Item(4), _Item(4)
    clock.t = 1.0
    fair.submit("t", a)
    clock.t = 2.0
    fair.submit("t", b)
    assert a.queued_t == 1.0 and b.queued_t == 2.0
    assert fair.backlog == 2
    assert fair.drain(rounds=10) == [a, b]  # FIFO within a tenant
    assert fair.backlog == 0
    st_ = fair.stats()["tenants"]["t"]
    assert st_["released"] == 2 and st_["backlog"] == 0


def test_invalid_args_rejected():
    with pytest.raises(ValueError):
        FairScheduler(quantum=0)
    fair, _ = _drr()
    with pytest.raises(ValueError):
        fair.submit("t", _Item(1), weight=0.0)


def test_no_starvation_bound():
    """A weight-1 tenant behind a firehose tenant still releases its
    head request within ceil(cost / quantum) rounds."""
    fair, _ = _drr(quantum=8.0)
    slow = _Item(24)  # needs ceil(24/8) = 3 rounds of deficit
    fair.submit("meek", slow, weight=1.0)
    for i in range(100):
        fair.submit("firehose", _Item(8), weight=10.0)
    released = []
    rounds = 0
    while slow not in released:
        released += fair.drain(rounds=1)
        rounds += 1
        assert rounds <= 3, "meek tenant starved past its DRR bound"
    assert rounds == 3


def test_weighted_shares_track_weights():
    """Persistent backlog: released cost per tenant tracks weight to
    within one quantum*weight + one max request cost."""
    fair, _ = _drr(quantum=8.0)
    costs = {"a": 1.0, "b": 3.0}
    for name, w in costs.items():
        for _ in range(200):
            fair.submit(name, _Item(4), weight=w)
    rounds = 20
    fair.drain(rounds=rounds)
    stats = fair.stats()["tenants"]
    for name, w in costs.items():
        assert stats[name]["backlog"] > 0, "backlog must persist for shares"
        ideal = rounds * 8.0 * w
        slack = max(4.0, 8.0 * w)
        assert abs(stats[name]["released_cost"] - ideal) <= slack, (
            name, stats[name]["released_cost"], ideal)


def test_deterministic_release_order():
    def run():
        fair, clock = _drr(quantum=6.0)
        rng = np.random.default_rng(7)
        items = []
        for i in range(60):
            it = _Item(int(rng.integers(1, 12)))
            it.rid = i
            clock.t = float(i)
            fair.submit(f"t{int(rng.integers(0, 4))}", it,
                        weight=float(rng.integers(1, 4)))
            items.append(it)
        order = []
        while fair.backlog:
            order += [it.rid for it in fair.drain(rounds=1)]
        return order

    first = run()
    assert first == run() == run()
    assert sorted(first) == list(range(60))  # everyone released exactly once


def test_default_cost_is_prompt_plus_generation():
    it = _Item(5)
    it.max_new = 7
    assert default_cost(it) == 12.0


@hypothesis.given(st.integers(min_value=0, max_value=10_000),
                  st.integers(min_value=1, max_value=16),
                  st.integers(min_value=1, max_value=120))
@hypothesis.settings(max_examples=25, deadline=None)
def test_drr_property_no_loss_no_duplicates(seed, tenants, submissions):
    """Random tenants/weights/costs: every submitted item is released
    exactly once, in an order that replays identically, and no tenant
    exceeds the starvation bound for its head-of-line item."""
    def run():
        fair, clock = _drr(quantum=5.0)
        rng = np.random.default_rng(seed)
        items = []
        for i in range(submissions):
            it = _Item(int(rng.integers(1, 20)))
            it.rid = i
            clock.t = float(i)
            fair.submit(f"t{int(rng.integers(0, tenants))}", it,
                        weight=float(rng.integers(1, 5)))
            items.append(it)
        order = []
        guard = 0
        while fair.backlog:
            got = fair.drain(rounds=1)
            assert got or fair.backlog == 0 or guard < 10_000
            order += [it.rid for it in got]
            guard += 1
        return order

    a = run()
    assert a == run()
    assert sorted(a) == list(range(submissions))


# ---------------------------------------------------------------------------
# SLO controller: pure control-step pins
# ---------------------------------------------------------------------------


def test_tune_chunk_directions():
    # TPOT violated -> shrink (dominates a TTFT violation)
    assert tune_chunk(64, 0.0, 2.0, 8, 128) == 32
    assert tune_chunk(64, 3.0, 2.0, 8, 128) == 32
    # TTFT violated, TPOT healthy -> grow
    assert tune_chunk(16, 2.0, 0.5, 8, 128) == 32
    # both healthy -> hold
    assert tune_chunk(64, 0.9, 0.9, 8, 128) == 64
    # steps clamp at 4x per tick and at the range edges
    assert tune_chunk(64, 0.0, 100.0, 8, 128) == 16
    assert tune_chunk(8, 0.0, 100.0, 8, 128) == 8
    assert tune_chunk(64, 100.0, 0.0, 8, 128) == 128
    with pytest.raises(ValueError):
        tune_chunk(64, 0.0, 0.0, 100, 8)


def test_tune_spec_floor_directions():
    assert tune_spec_floor(0.5, 2.0) == pytest.approx(0.95)  # 1.0, capped
    assert tune_spec_floor(0.4, 1.5) == pytest.approx(0.6)
    assert tune_spec_floor(0.5, 0.5) == 0.5      # healthy: unchanged here
    assert tune_spec_floor(0.0, 10.0) == 0.0     # disabled floor stays off


@hypothesis.given(st.integers(min_value=8, max_value=256),
                  st.floats(min_value=0.0, max_value=10.0),
                  st.floats(min_value=0.0, max_value=10.0),
                  st.floats(min_value=0.0, max_value=10.0))
@hypothesis.settings(max_examples=60, deadline=None)
def test_tune_chunk_clamped_and_monotone(chunk, ttft_a, ttft_b, tpot):
    """Output always lands in [lo, hi]; at fixed TPOT ratio the result
    is weakly monotone non-decreasing in the TTFT ratio."""
    lo, hi = 8, 256
    a = tune_chunk(chunk, min(ttft_a, ttft_b), tpot, lo, hi)
    b = tune_chunk(chunk, max(ttft_a, ttft_b), tpot, lo, hi)
    assert lo <= a <= hi and lo <= b <= hi
    assert a <= b, (a, b)


def test_controller_shrinks_under_tpot_pressure_and_recovers():
    c = SLOController(ttft_ms=0.0, tpot_ms=10.0, chunk=64,
                      chunk_min=8, chunk_max=64)
    for _ in range(8):
        c.observe("tpot", 0.050)  # 5x the target
    chunk, _ = c.tick()
    assert chunk == 16  # 64 / 4 (max step)
    chunk, _ = c.tick()
    assert chunk == 8   # clamped at chunk_min
    # history recorded only the two moves
    assert [h["chunk"] for h in c.history] == [16, 8]
    # recovery: healthy observations displace the bad window
    for _ in range(64):
        c.observe("tpot", 0.001)
    chunk, _ = c.tick()
    assert chunk == 8  # healthy TPOT alone never grows the chunk back
    c.observe("ttft", 1.0)  # ... but a TTFT violation now does
    c.ttft_ms = 100.0
    chunk, _ = c.tick()
    assert chunk > 8


def test_controller_grows_chunk_under_ttft_pressure():
    c = SLOController(ttft_ms=100.0, tpot_ms=0.0, chunk=16,
                      chunk_min=8, chunk_max=128)
    for _ in range(4):
        c.observe("ttft", 0.300)  # 3x target
    chunk, _ = c.tick()
    assert chunk == 48
    assert c.history and c.history[-1]["ttft_ratio"] == pytest.approx(3.0)


def test_controller_floor_raises_then_relaxes():
    c = SLOController(tpot_ms=10.0, chunk=32, spec_floor=0.2)
    for _ in range(8):
        c.observe("tpot", 0.030)
    _, floor = c.tick()
    assert floor == pytest.approx(0.6)  # 0.2 * 3x ratio
    for _ in range(64):
        c.observe("tpot", 0.005)  # healthy again
    _, floor = c.tick()
    assert floor == pytest.approx(0.4)  # halfway back toward base
    _, floor = c.tick()
    assert floor == pytest.approx(0.3)


def test_controller_no_targets_never_moves():
    c = SLOController(chunk=32, chunk_min=8, chunk_max=128)
    for _ in range(16):
        c.observe("ttft", 9.9)
        c.observe("tpot", 9.9)
        assert c.tick() == (32, 0.0)
    assert c.history == []
    with pytest.raises(ValueError):
        SLOController(chunk=0)
