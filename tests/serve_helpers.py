"""Shared serving-test helpers.

One home for the fixtures the serving suites kept re-growing locally:
tiny reduced models, deterministic request workloads, one-shot
``BatchedServer`` runs, and the hermetic subprocess environment the
mesh/CLI smokes launch under. Imported by ``test_resilience.py``,
``test_sharded_serving.py``, ``test_service.py`` and ``test_spill.py`` —
change a knob here and every suite sees the same workload.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import BatchedServer, Request
from repro.models import build_model


def tiny_model(arch="llama32-1b", n_layers=2, seed=0):
    """A reduced config shrunk to ``n_layers`` with seeded fp weights —
    small enough that CPU suites stay in seconds."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def make_requests(cfg, lens, gens, seed0=100, priorities=None):
    """Deterministic per-request prompts: request ``i`` draws its tokens
    from ``default_rng(seed0 + i)``, so workloads rebuild identically."""
    if isinstance(gens, int):
        gens = [gens] * len(lens)
    return [
        Request(i, np.random.default_rng(seed0 + i).integers(
            0, cfg.vocab_size, ln, dtype=np.int32), g,
            priority=(priorities[i] if priorities else 0))
        for i, (ln, g) in enumerate(zip(lens, gens))
    ]


def serve_once(model, params, reqs, **kw):
    """Run one fresh ``BatchedServer`` over ``reqs``; returns
    ``({rid: out}, stats)`` with the legacy event strings attached as
    ``stats["_events"]``."""
    server = BatchedServer(model, params, **kw)
    stats = server.run(reqs)
    stats["_events"] = server.events
    return {r.rid: r.out for r in reqs}, stats


def subprocess_env(devices=8):
    """The hermetic environment the subprocess smokes run under: repo
    sources on the path, fake host devices for mesh runs, nothing
    inherited that could vary between CI and a dev shell."""
    return {
        "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
    }


def run_python(code, timeout=600, devices=8):
    """``python -c code`` in the hermetic env (inline mesh smokes)."""
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, cwd="/root/repo", env=subprocess_env(devices),
    )


def run_module(module, args, timeout=600, devices=8):
    """``python -m module *args`` in the hermetic env (CLI smokes)."""
    return subprocess.run(
        [sys.executable, "-m", module, *args], capture_output=True,
        text=True, timeout=timeout, cwd="/root/repo",
        env=subprocess_env(devices),
    )
