"""Mesh-sharded serving: exact-TP serve specs on the real packed executable
tree, per-replica page-pool routing, and subprocess runs (8 fake devices)
pinning greedy streams bit-identical across {unsharded, 1x1 mesh, 2x2 mesh}
for both served architectures with paged KV + prefix cache + speculation.

The contract under test is the one ``runtime.sharding`` documents: serve
mode shards every hot matmul on its OUTPUT dim only (value-exact
all-gathers, never partial-sum all-reduces), so a sharded greedy stream is
the single-device stream bit-for-bit — not approximately, exactly. Data
parallelism splits the batch slots and the page pool into replica-local
ranges; each replica's admission, prefix index, COW traffic, and
preemption victims stay inside its own range.
"""
import textwrap

import pytest
from serve_helpers import run_module, run_python

from repro.kvcache.allocator import OutOfPages, PagePoolGroup


# ---------------------------------------------------------------------------
# host-side: per-replica page pool routing
# ---------------------------------------------------------------------------

def test_pool_group_replica_id_ranges():
    g = PagePoolGroup(24, 2)
    a = g.alloc(3, replica=0)
    b = g.alloc(3, replica=1)
    assert all(0 <= p < 12 for p in a)
    assert all(12 <= p < 24 for p in b)
    # id-taking ops route by the page id itself
    g.free(a + b)
    g.audit()
    assert g.in_use == 0


def test_pool_group_replica_isolation():
    """A replica exhausting ITS range must not borrow from the other —
    the pool's PAGE dim is batch-sharded over data, so a borrowed page
    would live on the wrong replica's devices."""
    g = PagePoolGroup(8, 2)
    g.alloc(4, replica=0)
    assert not g.can_alloc(1, replica=0)
    assert g.can_alloc(4, replica=1)
    with pytest.raises(OutOfPages):
        g.alloc(1, replica=0)


def test_pool_group_cow_stays_in_replica():
    g = PagePoolGroup(8, 2)
    [p] = g.alloc(1, replica=1)
    g.retain([p])
    fresh, copied = g.cow(p)  # caller's claim moves onto the fresh page
    assert copied and 4 <= fresh < 8
    g.free([p, fresh])
    g.audit()
    assert g.in_use == 0


def test_pool_group_divisibility_and_stats():
    with pytest.raises(ValueError):
        PagePoolGroup(10, 3)
    g = PagePoolGroup(12, 3)
    g.alloc(2, replica=2)
    s = g.stats()
    assert s["in_use"] == 2 and len(s["per_replica"]) == 3
    assert s["per_replica"][2]["in_use"] == 2
    # single-replica groups keep the flat single-pool stats shape
    assert "per_replica" not in PagePoolGroup(12, 1).stats()


# ---------------------------------------------------------------------------
# serve-mode specs on the real packed executable tree
# ---------------------------------------------------------------------------

def test_serve_specs_on_packed_executable_tree():
    """Every PackedSplitQTensor leaf of the real llama executable tree:
    codes/cids shard the output dim, scales/zeros/meta replicate, and no
    spec references the data axis (weights replicate across DP)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.models import build_model
    from repro.runtime import sharding as shd

    cfg = get_config("llama32-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tree = restructure(params, QuantPolicy(bits=4, split=True, packed=True)
                       ).as_executable(group=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    assert leaves, "executable tree has no leaves"
    checked = sharded = 0
    for path, leaf in leaves:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                        for k in path)
        spec = shd.serve_param_spec(pstr, leaf.shape, n_model=2)
        assert "data" not in jax.tree_util.tree_leaves(tuple(spec)), pstr
        name = pstr.rsplit("/", 1)[-1]
        if name in ("scales", "zeros", "info", "meta"):
            assert spec == P(), f"{pstr} must replicate, got {spec}"
            checked += 1
        elif name in ("codes", "cids") and any(
                s in pstr for s in ("wqkv", "w_gateup", "wq", "wk", "wv",
                                    "w_up", "w_gate", "lm_head")):
            if leaf.shape[-1] % 2 == 0:
                assert spec[-1] == "model", f"{pstr} got {spec}"
                sharded += 1
    assert checked > 0 and sharded > 0, (checked, sharded)


def test_serve_specs_scale_with_mesh_instance():
    """Rules answer per mesh instance — a dim not divisible by one mesh's
    TP degree replicates there while still sharding on another."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime import sharding as shd

    shape = (16, 64, 258)  # 258 % 4 != 0, % 2 == 0
    assert shd.serve_param_spec("layers/attn/wqkv/codes", shape,
                                n_model=2)[-1] == "model"
    assert shd.serve_param_spec("layers/attn/wqkv/codes", shape,
                                n_model=4) == P()


# ---------------------------------------------------------------------------
# subprocess: bit-identical streams across mesh shapes
# ---------------------------------------------------------------------------

_STREAMS = """
    import os
    assert "--xla_force_host_platform_device_count=8" in os.environ["XLA_FLAGS"]
    import jax, numpy as np
    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import BatchedServer, Request
    from repro.models import build_model

    ARCH = %(arch)r
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    fp = model.init(jax.random.PRNGKey(0))
    pol = QuantPolicy(bits=4, split=True, packed=True)
    params = restructure(fp, pol).as_executable(group=True)
    draft = restructure(fp, pol).as_executable(group=True)

    def make_reqs():
        rng = np.random.default_rng(0)
        common = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
        return [Request(i, np.concatenate([
            common, rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)]), 6)
            for i in range(6)]

    def serve(mesh_shape, speculate):
        mesh = (make_mesh(mesh_shape, ("data", "model"))
                if mesh_shape else None)
        reqs = make_reqs()
        srv = BatchedServer(
            model, params, 4, 16 + 12 + 6 + 8, paged=True, page_size=8,
            prefix_cache=True, prefill_chunk=16, speculate=speculate,
            draft_params=draft if speculate else None, mesh=mesh)
        stats = srv.run(reqs)
        assert stats["requests"] == 6, stats
        assert stats["pages"]["leaked"] == 0, stats
        assert stats["decode_compiles"] <= 1, stats
        if speculate:
            assert stats["spec"]["draft_pages_leaked"] == 0, stats
            assert stats["spec"]["verify_compiles"] == 1, stats
        if mesh_shape == (2, 2):
            # DP really split the pool: both replica ranges saw traffic
            per = stats["pages"]["per_replica"]
            assert len(per) == 2 and all(p["peak_in_use"] > 0 for p in per)
            srv.alloc.audit()
            for p in srv.prefixes:
                p.audit()
        srv.drop_prefix_cache()
        assert srv.alloc.in_use == 0, "pages held after prefix drop"
        return {r.rid: list(r.out) for r in reqs}, stats

    ref, _ = serve(None, speculate=0)
    assert all(len(v) == 6 for v in ref.values())
    for shape in [(1, 1), (2, 2)]:
        got, stats = serve(shape, speculate=0)
        assert got == ref, (shape, "plain", got, ref)
        assert stats["decode_compiles"] == 1, stats
        got, _ = serve(shape, speculate=3)
        assert got == ref, (shape, "speculate", got, ref)
    spec_ref, _ = serve(None, speculate=3)
    assert spec_ref == ref
    print("OK", ARCH)
"""


def test_streams_bit_identical_llama():
    """Greedy llama streams: unsharded == 1x1 == 2x2, plain and
    speculative, with paged KV + prefix cache; decode compiles once on
    the mesh path; zero leaks in target and draft pools."""
    r = run_python(textwrap.dedent(_STREAMS % {"arch": "llama32-1b"}))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK llama32-1b" in r.stdout


def test_streams_bit_identical_zamba():
    """Same contract for the recurrent hybrid (ssm/conv rows ride the
    cache through verify rollback's restore + re-verify on the mesh)."""
    r = run_python(textwrap.dedent(_STREAMS % {"arch": "zamba2-1.2b"}))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK zamba2-1.2b" in r.stdout


def test_chaos_on_mesh_cli():
    """The serve CLI's own chaos self-check on a 2x2 mesh: page growth +
    speculation + an injected mid-decode pool fault must still reproduce
    the clean meshed streams bit-exactly and leak nothing (exit 0 covers
    every gate in serve.main)."""
    r = run_module("repro.launch.serve", [
        "--arch", "llama32-1b", "--reduced", "--bits", "4",
        "--engine", "packed", "--batch", "4", "--requests", "8",
        "--prompt-len", "12", "--gen", "8", "--paged", "--page-size", "8",
        "--prefix-cache", "--shared-prefix", "16", "--speculate", "4",
        "--page-growth", "--inject", "oop@tick2", "--mesh", "2x2"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "chaos OK" in r.stdout
