"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; prefill+decode consistency for serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model


def _batch(cfg, b=2, s=16, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    v = cfg.vocab_size
    if cfg.encdec:
        return {
            "enc_embeds": jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
            ),
            "tokens": jnp.asarray(rng.integers(0, v, (b, s)).astype(np.int32)),
            "labels": jnp.asarray(rng.integers(0, v, (b, s)).astype(np.int32)),
        }
    if cfg.family == "vlm":
        s_vis = s // 4
        s_txt = s - s_vis
        pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None, :, None], (b, s, 3))
        return {
            "vis_embeds": jnp.asarray(
                rng.normal(size=(b, s_vis, cfg.d_model)).astype(np.float32)
            ),
            "tokens": jnp.asarray(rng.integers(0, v, (b, s_txt)).astype(np.int32)),
            "labels": jnp.asarray(rng.integers(0, v, (b, s_txt)).astype(np.int32)),
            "pos3": jnp.asarray(pos.copy()),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, v, (b, s)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, v, (b, s)).astype(np.int32)),
    }


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_decreases_loss(arch):
    """A couple of SGD steps on a fixed batch must reduce the loss."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    @jax.jit
    def step(p):
        (loss, _), g = jax.value_and_grad(model.train_loss, has_aux=True)(p, batch)
        # clipped SGD — the test is "gradients flow and reduce loss", not
        # lr robustness
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                for x in jax.tree.leaves(g))
        )
        scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        p = jax.tree.map(
            lambda w, gw: w - 0.1 * scale * gw.astype(w.dtype), p, g
        )
        return p, loss

    losses = []
    for _ in range(6):
        params, loss = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{arch}: {losses}"
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode after prefill must match the parallel forward
    (the KV-cache / recurrent-state correctness test)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch(cfg, b=b, s=s, rng_seed=3)

    if cfg.family == "vlm":
        pytest.skip("vlm decode positions exercised in test_vlm_decode")

    # full forward logits at every position
    from repro.models import transformer as tfm

    if cfg.encdec:
        enc = tfm.encoder_forward(cfg, params, batch["enc_embeds"])
        cross = tfm.build_cross_kv(cfg, params, enc)
        x = tfm.embed_tokens(cfg, params, batch["tokens"])
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        hidden, _, _ = tfm.decoder_forward(cfg, params, x, pos, cross_kv=cross)
    else:
        x = tfm.embed_tokens(cfg, params, batch["tokens"])
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        hidden, _, _ = tfm.decoder_forward(cfg, params, x, pos)
    full_logits = tfm.logits_fn(cfg, params, hidden)  # (B, S, V)

    # prefill on the first half, decode the rest one token at a time
    half = s // 2
    cache = model.init_cache(b, max_len=s + 4)
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :half]
    if cfg.encdec:
        pre_batch["enc_embeds"] = batch["enc_embeds"]
    logits, cache = model.prefill(params, pre_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, half - 1]),
        rtol=2e-3, atol=2e-3,
    )
    for t in range(half, s):
        logits, cache = model.decode_step(params, batch["tokens"][:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode step {t} diverges from parallel forward",
        )


def test_vlm_decode():
    cfg = get_config("qwen2-vl-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b=b, s=s)
    cache = model.init_cache(b, max_len=s + 4)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (b, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    pos3 = jnp.full((b, 1, 3), s, jnp.int32)
    logits2, cache = model.decode_step(params, tok, cache, pos3=pos3)
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()


def test_mrope_text_equals_rope():
    """For text tokens (t==h==w) M-RoPE must reduce to standard RoPE."""
    from repro.models.layers import apply_mrope, apply_rope

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 8, 3))
    a = apply_rope(x, pos, 1e4)
    bb = apply_mrope(x, pos3, 1e4, (2, 1, 1))
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-5, atol=1e-5)


def test_gemma_local_window_masks_context():
    """A local layer must not attend beyond its window."""
    from repro.models.attention import attend

    b, s, h, hd = 1, 8, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    yw = attend(q, k, v, pos, pos, causal=True, window=2)
    # windowed output at position s-1 must equal attention over just the
    # last 2 keys
    y2 = attend(q[:, -1:], k[:, -2:], v[:, -2:], pos[:, -1:], pos[:, -2:],
                causal=True, window=0)
    np.testing.assert_allclose(
        np.asarray(yw[:, -1:]), np.asarray(y2), rtol=1e-5, atol=1e-5
    )


def test_moe_routes_and_balances():
    cfg = get_config("deepseek-moe-16b").reduced()
    from repro.models.moe import init_moe, moe_block

    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)).astype(np.float32)
    )
    y, aux = moe_block(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0
