"""SplitQuantV2 invariants: exact FP function preservation (paper §4.1),
resolution improvement, storage accounting, and equivalence of the three
execution paths (paper 3-pass vs fused vs beyond-paper packed)."""
try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:  # offline container: property tests skip, rest run
    from hypothesis_stub import hypothesis, hnp, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantPolicy,
    quantize_model,
    restructure,
    split_error_stats,
    split_fp,
    split_quantize,
    split_quantize_packed,
    splitq_linear_3pass,
    splitq_linear_fused,
    splitq_linear_packed,
    sqnr_db,
)
import repro.core.quantize as qz


def _w(shape, seed=0, outliers=True):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.02, size=shape).astype(np.float32)
    if outliers:
        flat = w.reshape(-1)
        n_out = max(2, flat.size // 500)
        idx = rng.choice(flat.size, n_out, replace=False)
        flat[idx] = rng.uniform(0.3, 0.5, n_out) * rng.choice([-1, 1], n_out)
    return jnp.asarray(w)


def test_fp_split_exact_sum():
    """paper §4.1 — the FP split is *exactly* function preserving."""
    w = _w((64, 128))
    planes, info = split_fp(w, k=3)
    np.testing.assert_array_equal(np.asarray(planes.sum(0)), np.asarray(w))
    assert int(np.asarray(info.counts).sum()) == w.size


def test_fp_split_exact_output():
    w = _w((32, 48), seed=1)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(4, 32)).astype(np.float32))
    planes, _ = split_fp(w, k=3)
    y_split = sum(jnp.dot(x, planes[c]) for c in range(3))
    y_orig = jnp.dot(x, w)
    np.testing.assert_allclose(np.asarray(y_split), np.asarray(y_orig), atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_masked_zeros_stay_exact_zero(bits):
    """Plane dequantization must return *exactly* 0 off-support — the
    include-zero range extension at work."""
    w = _w((16, 64), seed=3)
    sq = split_quantize(w, bits)
    from repro.core.quantize import unpack_codes, dequantize
    from repro.core.kmeans import cluster_masks

    ids = np.asarray(cluster_masks(w, sq.info.boundaries))
    for c in range(3):
        q = unpack_codes(sq.planes[c], bits, out_len=64).reshape(16, 64)
        wc = np.asarray(dequantize(q, sq.plane_qparams(c)))
        off = wc[ids != c]
        assert (off == 0.0).all(), f"plane {c} leaks off-support"


@pytest.mark.parametrize("bits", [4, 8])
def test_split_beats_baseline_resolution(bits):
    stats = split_error_stats(_w((256, 256), seed=4), bits)
    assert float(stats["sqnr_split_db"]) > float(stats["sqnr_base_db"]) + 3.0
    assert float(stats["mse_split"]) < float(stats["mse_base"])


def test_int8_baseline_already_fine_int4_gap_int2_dead():
    """The paper's Table-1 signature at the weight-error level."""
    w = _w((512, 512), seed=5)
    s8 = split_error_stats(w, 8)
    s4 = split_error_stats(w, 4)
    s2 = split_error_stats(w, 2)
    # INT8: baseline already high fidelity (>20 dB; ~25 dB for this dist)
    assert float(s8["sqnr_base_db"]) > 20
    # INT4: baseline poor, split recovers a big chunk
    assert float(s4["sqnr_split_db"]) - float(s4["sqnr_base_db"]) > 5
    # INT2: both very low fidelity (<10 dB)
    assert float(s2["sqnr_base_db"]) < 10


def test_packed_bit_identical_to_planes():
    """Beyond-paper 6-bit layout dequantizes to the same values."""
    w = _w((48, 96), seed=6)
    for bits in (2, 4, 8):
        sq = split_quantize(w, bits)
        ps = split_quantize_packed(w, bits)
        np.testing.assert_array_equal(
            np.asarray(sq.dequantize()), np.asarray(ps.dequantize())
        )


def test_execution_paths_agree():
    w = _w((64, 80), seed=7)
    x = jnp.asarray(np.random.default_rng(8).normal(size=(8, 64)).astype(np.float32))
    sq = split_quantize(w, 4)
    ps = split_quantize_packed(w, 4)
    y3 = splitq_linear_3pass(x, sq)
    yf = splitq_linear_fused(x, sq)
    yp = splitq_linear_packed(x, ps)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(yf), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yp), rtol=1e-6, atol=1e-6)


@hypothesis.given(
    rows=st.integers(2, 24), cols=st.sampled_from([8, 16, 40, 64]),
    bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 100),
)
@hypothesis.settings(deadline=None, max_examples=15)
def test_property_split_never_worse(rows, cols, bits, seed):
    """SplitQuantV2 MSE <= baseline per-tensor MSE (it refines the ranges)."""
    w = _w((rows, cols), seed=seed)
    stats = split_error_stats(w, bits)
    assert float(stats["mse_split"]) <= float(stats["mse_base"]) * 1.25 + 1e-12


def test_restructure_policy_and_size():
    """Whole-model pass: exclusions honored + the paper's 3/8 size claim."""
    params = {
        "embed": {"table": jnp.ones((1000, 64))},
        "layers": {
            "attn_wq": _w((8, 64, 64), seed=9),   # stacked (L=8)
            "norm_scale": jnp.ones((8, 64)),
        },
        "head": {"w": _w((64, 1000), seed=10), "bias": jnp.zeros((1000,))},
    }
    qm = restructure(params, QuantPolicy(bits=4, min_size=1024))
    assert "layers/attn_wq" in qm.qleaves and "head/w" in qm.qleaves
    assert "embed/table" in qm.passthrough
    assert "layers/norm_scale" in qm.passthrough
    assert qm.stacked["layers/attn_wq"] is True
    eff = qm.materialize()
    assert eff["layers"]["attn_wq"].shape == (8, 64, 64)
    # size: 3 planes x int4 = 12 bits/wt = 3/8 of fp32 (+ eps of metadata)
    n_wq = 8 * 64 * 64 + 64 * 1000
    sz = qm.size_bytes()["quantized"]
    assert sz < n_wq * 4 * 3 / 8 * 1.1
    assert sz > n_wq * 4 * 3 / 8 * 0.9


def test_quantize_model_shapes_and_improvement():
    params = {"w1": _w((128, 256), seed=11), "w2": _w((256, 128), seed=12)}
    eff4_split = quantize_model(params, 4, split=True)
    eff4_base = quantize_model(params, 4, split=False)
    for k in params:
        assert eff4_split[k].shape == params[k].shape
        gain = float(sqnr_db(params[k], eff4_split[k])) - float(
            sqnr_db(params[k], eff4_base[k])
        )
        assert gain > 3.0


def test_k2_tradeoff():
    """paper §5: k=2 is between baseline and k=3."""
    w = _w((256, 256), seed=13)
    base = split_error_stats(w, 4)
    k2 = split_quantize(w, 4, k=2).dequantize()
    s_k2 = float(sqnr_db(w, k2))
    assert float(base["sqnr_base_db"]) - 1.0 <= s_k2 <= float(base["sqnr_split_db"]) + 1.0
