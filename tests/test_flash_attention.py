"""Flash-attention Pallas kernel vs plain-softmax oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref


def _qkv(bh, sq, sk, hd, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    return mk((bh, sq, hd)), mk((bh, sk, hd)), mk((bh, sk, hd))


@pytest.mark.parametrize("sq,sk,bq,bk", [
    (64, 64, 32, 32),
    (128, 256, 64, 64),
    (96, 96, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(sq, sk, bq, bk, causal):
    if causal and sq != sk:
        pytest.skip("causal assumes aligned positions")
    q, k, v = _qkv(2, sq, sk, 64, seed=sq + sk)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_sliding_window():
    q, k, v = _qkv(1, 128, 128, 32, seed=7)
    got = flash_attention_pallas(q, k, v, causal=True, window=32,
                                 bq=32, bk=32, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q, k, v = _qkv(2, 64, 64, 32, seed=3)
    q, k, v = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    got = flash_attention_pallas(q, k, v, bq=32, bk=32, interpret=True)
    want = flash_attention_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_extreme_logits_stable():
    """Online softmax must survive large logit magnitudes."""
    q, k, v = _qkv(1, 64, 64, 32, seed=9)
    got = flash_attention_pallas(q * 100, k * 100, v, bq=32, bk=32,
                                 interpret=True)
    assert np.isfinite(np.asarray(got)).all()
