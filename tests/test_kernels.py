"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import compute_qparams, quantize, pack_codes
from repro.core.split import split_quantize, split_quantize_packed
from repro.kernels import ops, ref


def _w(shape, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.05, size=shape).astype(np.float32)
    flat = w.reshape(-1)
    idx = rng.choice(flat.size, max(2, flat.size // 200), replace=False)
    flat[idx] *= 10  # outliers
    return jnp.asarray(w)


def _x(shape, dtype, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


# interpret-mode Pallas is slow on CPU: the bit/dtype sweeps run on small
# shapes only; FULL_SHAPE keeps one multi-block case per kernel.
MM_SHAPES = [
    (8, 64, 32),      # tiny, all dims below one block
    (130, 200, 520),  # ragged -> exercises padding
]
FULL_SHAPE = (256, 384, 1024)  # multi-block (full-size case per kernel)


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_quant_matmul_vs_ref(m, k, n, bits, dtype):
    per = 8 // bits
    w = _w((k, n), seed=m + bits)
    qp = compute_qparams(w, bits)
    q = quantize(w, qp)
    pad = (-n) % per
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    wp = pack_codes(q, bits)
    x = _x((m, k), dtype)
    y_ker = ops.quant_matmul(x, wp, qp.scale, qp.zero, bits)
    y_ref = ref.quant_matmul_ref(x, wp, qp.scale, qp.zero, bits)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(y_ker[:, :n], np.float32),
        np.asarray(y_ref[:, :n], np.float32),
        rtol=tol, atol=tol * max(1.0, float(jnp.abs(y_ref).max())),
    )


def test_quant_matmul_full_size_and_bf16():
    m, k, n = FULL_SHAPE
    test_quant_matmul_vs_ref(m, k, n, 4, jnp.float32)
    test_quant_matmul_vs_ref(128, 128, 512, 4, jnp.bfloat16)


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
def test_splitq_matmul_vs_ref(m, k, n, bits):
    w = _w((k, n), seed=m * 7 + bits)
    sq = split_quantize(w, bits)
    x = _x((m, k), jnp.float32)
    y_ker = ops.splitq_matmul(x, sq)
    y_ref = ref.splitq_matmul_ref(x, sq.planes, sq.scales, sq.zeros, bits)
    np.testing.assert_allclose(
        np.asarray(y_ker), np.asarray(y_ref[:, :n]), rtol=2e-5, atol=1e-3
    )


def test_splitq_matmul_full_size():
    test_splitq_matmul_vs_ref(*FULL_SHAPE, 4)


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_splitq_packed_matmul_vs_ref(m, k, n, bits):
    w = _w((k, n), seed=m * 3 + bits)
    psq = split_quantize_packed(w, bits)
    x = _x((m, k), jnp.float32)
    y_ker = ops.splitq_packed_matmul(x, psq)
    y_ref = ref.splitq_packed_matmul_ref(
        x, psq.codes, psq.cids, psq.scales, psq.zeros, bits
    )
    np.testing.assert_allclose(
        np.asarray(y_ker), np.asarray(y_ref[:, :n]), rtol=2e-5, atol=1e-3
    )


def test_splitq_packed_matmul_full_size():
    test_splitq_packed_matmul_vs_ref(*FULL_SHAPE, 4)


def test_splitq_kernels_match_dense_dequant():
    """Kernel output == x @ sq.dequantize() — ties kernels to the core."""
    k, n, m = 96, 160, 24
    w = _w((k, n), seed=11)
    x = _x((m, k), jnp.float32)
    sq = split_quantize(w, 4)
    psq = split_quantize_packed(w, 4)
    y_dense = jnp.dot(x, sq.dequantize())
    np.testing.assert_allclose(
        np.asarray(ops.splitq_matmul(x, sq)), np.asarray(y_dense),
        rtol=1e-4, atol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(ops.splitq_packed_matmul(x, psq)), np.asarray(y_dense),
        rtol=1e-4, atol=1e-3,
    )


@pytest.mark.parametrize("r,c", [(4, 16), (300, 1000)])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_pack_vs_ref(r, c, bits):
    per = 8 // bits
    w = _w((r, c), seed=r + c + bits)
    qp = compute_qparams(w, bits)
    got = ops.quantize_pack(w, qp.scale, qp.zero, bits)
    cc = c - c % per  # ref needs divisible cols; compare the common region
    want = ref.quantize_pack_ref(w[:, :cc], qp.scale, qp.zero, bits)
    np.testing.assert_array_equal(
        np.asarray(got)[:, : cc // per], np.asarray(want)
    )


@pytest.mark.parametrize("n", [100, 4096, 100_000])
@pytest.mark.parametrize("k", [2, 3])
def test_kmeans_assign_reduce_vs_ref(n, k):
    rng = np.random.default_rng(n + k)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    cents = jnp.asarray(np.sort(rng.normal(size=(k,)).astype(np.float32)))
    sums, counts = ops.kmeans_assign_reduce(x, cents)
    rs, rc = ref.kmeans_assign_reduce_ref(x, cents)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rs), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(rc), rtol=0, atol=0.5)
    assert float(counts.sum()) == n  # padding must not count


def test_kmeans_kernel_drives_lloyd_to_same_fixpoint():
    """Full Lloyd loop on the kernel == core.kmeans1d centroids."""
    from repro.core.kmeans import kmeans1d, quantile_init

    rng = np.random.default_rng(5)
    x = np.concatenate(
        [rng.normal(-4, 0.2, 3000), rng.normal(0, 0.2, 5000), rng.normal(5, 0.2, 2000)]
    ).astype(np.float32)
    xj = jnp.asarray(x)
    cents = quantile_init(xj, 3)
    for _ in range(16):
        sums, counts = ops.kmeans_assign_reduce(xj, cents)
        cents = jnp.sort(jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cents))
    want = np.asarray(kmeans1d(xj, k=3).centroids)
    np.testing.assert_allclose(np.asarray(cents), want, atol=0.05)
