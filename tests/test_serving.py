"""Slot-swap serving correctness: per-slot KV cache lengths end-to-end.

The contract under test (the continuous-batching tentpole):
* every request served through ``BatchedServer`` — any slot, any wave,
  any neighbour — produces token-for-token the same output as a fresh
  isolated single-request decode,
* batch == 1 slot swap works (regression: the old single-slot
  prefill-then-merge silently dropped the prefill when ``batch == 1``),
* finished/empty slots are masked: no KV write, no length advance,
* prompt-length bucketing bounds recompiles: decode compiles once total,
  prefill compiles once per power-of-two bucket (not per prompt length).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy, restructure
from repro.launch.serve import (
    BatchedServer,
    Request,
    build_parser,
    sample_token,
)
from repro.models import build_model


def _tiny_model(arch="llama32-1b", n_layers=2, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _isolated_decode(model, params, prompt: np.ndarray, gen: int,
                     max_len: int) -> list[int]:
    """Greedy decode of one request alone in a fresh batch-1 cache."""
    cache = model.init_cache(1, max_len)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cache
    )
    out = [int(jnp.argmax(logits[0, -1]))]
    while len(out) < gen:
        logits, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache
        )
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def _requests(cfg, lens, gen, seed0=100):
    return [
        Request(i, np.random.default_rng(seed0 + i).integers(
            0, cfg.vocab_size, ln, dtype=np.int32), gen)
        for i, ln in enumerate(lens)
    ]


def test_batch1_slot_swap_matches_isolated():
    """Regression: batch==1 serving must NOT serve from an empty cache
    (the old merge no-op'ed when full.shape == one.shape)."""
    cfg, model, params = _tiny_model()
    gen, max_len = 4, 32
    reqs = _requests(cfg, [6, 9], gen)
    server = BatchedServer(model, params, batch_slots=1, max_len=max_len)
    stats = server.run(reqs)
    assert stats["requests"] == 2
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, r.out, want)


def test_mixed_lengths_multiwave_packed_engine_matches_isolated():
    """Acceptance: requests > batch, heterogeneous prompt lengths, packed
    engine — every request token-for-token equals its isolated decode, and
    compiles are bounded by buckets, not by distinct prompt lengths."""
    cfg, model, params = _tiny_model()
    qm = restructure(params, QuantPolicy(bits=4, packed=True))
    ex = qm.as_executable(group=True)
    gen, max_len = 3, 48
    lens = [4, 16, 23, 5, 17, 9]  # 6 distinct lengths, 2 slots -> 3 waves
    reqs = _requests(cfg, lens, gen)
    server = BatchedServer(model, ex, batch_slots=2, max_len=max_len)
    stats = server.run(reqs)
    assert stats["requests"] == len(lens)
    for r in reqs:
        want = _isolated_decode(model, ex, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, len(r.prompt), r.out, want)
    # decode never recompiles: its shapes don't depend on prompt length
    assert stats["decode_compiles"] == 1, stats
    # prefill compiles once per power-of-two bucket, NOT per prompt length
    assert stats["prefill_compiles"] == len(stats["prefill_buckets"]), stats
    assert stats["prefill_compiles"] < len(set(lens)), stats


def test_slot_recycling_does_not_leak_previous_request():
    """A slot that served a LONG request is reused by a SHORT one: the
    stale KV tail beyond the new per-slot length must be unreachable."""
    cfg, model, params = _tiny_model(seed=3)
    gen, max_len = 3, 40
    # slot 0 serves a 23-token prompt first, then is recycled for a
    # 4-token prompt whose positions land far below the stale tail
    reqs = _requests(cfg, [23, 22, 4], gen)
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len)
    server.run(reqs)
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (r.rid, r.out, want)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b"])
def test_slot_swap_recurrent_state_families(arch):
    """SSM / hybrid caches: recycled slots must reset recurrent state and
    padded prefill must leave it decay-neutral beyond each row's length."""
    cfg, model, params = _tiny_model(arch, n_layers=2, seed=1)
    gen, max_len = 3, 32
    reqs = _requests(cfg, [4, 7, 5], gen)
    server = BatchedServer(model, params, batch_slots=2, max_len=max_len)
    stats = server.run(reqs)
    assert stats["requests"] == 3
    for r in reqs:
        want = _isolated_decode(model, params, r.prompt, gen, max_len)
        assert r.out == want, (arch, r.rid, r.out, want)


def test_inactive_slots_no_cache_writes():
    """A decode step must not write KV or advance ``len`` for empty or
    finished slots (the old server fed token 0 and wrote its KV)."""
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=2, max_len=32)
    [req] = _requests(cfg, [6], 4)
    server._fill_slots([req])
    assert server.active[1] is None
    before = jax.tree.map(np.asarray, server.cache)
    for _ in range(3):
        server.step()
    after = jax.tree.map(np.asarray, server.cache)
    # slot 1 was never admitted: its rows are bit-identical (still zero)
    np.testing.assert_array_equal(after["kv"][:, :, 1], before["kv"][:, :, 1])
    assert (after["kv"][:, :, 1] == 0).all()
    assert after["len"][1] == 0
    # slot 0 decoded 3 tokens on top of its 6-token prompt
    assert after["len"][0] == 9
    # finished slot: freeze it and step again — nothing may change
    req.done = True
    frozen = jax.tree.map(np.asarray, server.cache)
    server.step()
    final = jax.tree.map(np.asarray, server.cache)
    for k in frozen:
        np.testing.assert_array_equal(final[k], frozen[k], err_msg=k)


def test_prefill_wave_freezes_ongoing_slot():
    """Batched in-place prefill of a new request must not disturb the
    cache rows of a slot that is mid-decode."""
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=2, max_len=32)
    [r0] = _requests(cfg, [6], 8)
    server._fill_slots([r0])
    server.step()
    before = jax.tree.map(np.asarray, server.cache)
    [r1] = _requests(cfg, [5], 8, seed0=300)
    server._fill_slots([r1])  # admits into slot 1; slot 0 has lengths==0
    after = jax.tree.map(np.asarray, server.cache)
    np.testing.assert_array_equal(after["kv"][:, :, 0], before["kv"][:, :, 0])
    assert after["len"][0] == before["len"][0] == 7
    assert after["len"][1] == 5


def test_gen1_requests_all_retired():
    """Requests that finish at prefill (max_new == 1) in the FINAL wave
    must still be collected into the stats and their slots freed."""
    cfg, model, params = _tiny_model()
    reqs = _requests(cfg, [4, 6, 5, 7], gen=1)
    server = BatchedServer(model, params, batch_slots=2, max_len=16)
    stats = server.run(reqs)
    assert stats["requests"] == 4, stats
    assert stats["tokens"] == 4, stats
    assert server.active == [None, None]
    for r in reqs:
        assert r.out == _isolated_decode(model, params, r.prompt, 1, 16)


def test_rejected_request_does_not_strand_wave_mates():
    """Admission validates the whole wave BEFORE mutating server state: a
    rejected request must leave pending and slots untouched."""
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=2, max_len=12)
    [good] = _requests(cfg, [4], gen=2)
    [too_long] = _requests(cfg, [8], gen=6, seed0=200)  # needs 13 > 12
    pending = [good, too_long]
    with pytest.raises(ValueError, match="cache rows"):
        server._fill_slots(pending)
    assert pending == [good, too_long]       # nothing popped
    assert server.active == [None, None]     # nothing admitted
    assert good.out == []                    # nothing prefilled
    # dropping the bad request lets the good one serve normally
    stats = server.run([good])
    assert stats["requests"] == 1
    assert good.out == _isolated_decode(model, params, good.prompt, 2, 12)


def test_encdec_padded_prefill_honors_lengths():
    """Whisper-style enc-dec: batched right-padded prefill with per-row
    lengths must match isolated batch-1 decoding (the encdec branch of
    prefill must pass seq_lens through)."""
    cfg, model, params = _tiny_model("whisper-medium", n_layers=2, seed=5)
    rng = np.random.default_rng(11)
    s_enc, gen, max_len = 8, 3, 24
    enc = rng.normal(size=(2, s_enc, cfg.d_model)).astype(np.float32)
    prompts = [rng.integers(0, cfg.vocab_size, ln, dtype=np.int32)
               for ln in (4, 6)]

    def isolated(i):
        cache = model.init_cache(1, max_len)
        logits, cache = model.prefill(params, {
            "enc_embeds": jnp.asarray(enc[i : i + 1]),
            "tokens": jnp.asarray(prompts[i][None]),
        }, cache)
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(gen - 1):
            logits, cache = model.decode_step(
                params, jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(logits[0, 0])))
        return out

    refs = [isolated(0), isolated(1)]
    lb = 8
    toks = np.zeros((2, lb), np.int32)
    lens = np.zeros((2,), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
        lens[i] = len(p)
    cache = model.init_cache(2, max_len)
    logits, cache = model.prefill(params, {
        "enc_embeds": jnp.asarray(enc), "tokens": jnp.asarray(toks),
        "lengths": jnp.asarray(lens),
    }, cache)
    assert np.asarray(cache["len"]).tolist() == [4, 6]
    outs = [[int(jnp.argmax(logits[i, 0]))] for i in range(2)]
    for _ in range(gen - 1):
        t = jnp.asarray([[o[-1]] for o in outs], jnp.int32)
        logits, cache = model.decode_step(
            params, t, cache, active=jnp.asarray([True, True]))
        for i in range(2):
            outs[i].append(int(jnp.argmax(logits[i, 0])))
    assert outs == refs, (outs, refs)


def test_admission_rejects_requests_that_cannot_fit():
    """dynamic_update_slice clamps out-of-range writes, so a request whose
    prompt+gen exceeds max_len must be rejected up front, not silently
    corrupt live KV rows; empty prompts would alias the frozen-slot
    encoding (lengths == 0) and decode the previous occupant."""
    cfg, model, params = _tiny_model()
    server = BatchedServer(model, params, batch_slots=1, max_len=12)
    [too_long] = _requests(cfg, [8], gen=6)  # needs 8 + 6 - 1 = 13 rows
    with pytest.raises(ValueError, match="cache rows"):
        server._fill_slots([too_long])
    server2 = BatchedServer(model, params, batch_slots=1, max_len=12)
    empty = Request(0, np.zeros((0,), np.int32), 2)
    with pytest.raises(ValueError, match="empty prompt"):
        server2._fill_slots([empty])
    # the boundary case fits exactly: 8 + 5 - 1 = 12 rows
    server3 = BatchedServer(model, params, batch_slots=1, max_len=12)
    [fits] = _requests(cfg, [8], gen=5)
    stats = server3.run([fits])
    assert stats["requests"] == 1 and len(fits.out) == 5


def test_streaming_callback_receives_every_token_in_order():
    """``run(requests, on_token=...)`` must stream each sampled token as it
    is produced; per request, the streamed sequence equals ``out``."""
    cfg, model, params = _tiny_model()
    reqs = _requests(cfg, [6, 9, 4], gen=3)
    streamed: dict[int, list[int]] = {}
    server = BatchedServer(model, params, batch_slots=2, max_len=32)
    stats = server.run(
        reqs, on_token=lambda r, t: streamed.setdefault(r.rid, []).append(t)
    )
    assert stats["requests"] == 3
    for r in reqs:
        assert streamed[r.rid] == r.out, (r.rid, streamed[r.rid], r.out)


def test_sampling_greedy_default_and_seeded_reproducibility():
    """Greedy (temperature 0) is the default and exactly argmax; stochastic
    sampling is reproducible per seed and respects top-k support."""
    logits = np.array([0.5, 3.0, 2.5, -1.0, 2.9])
    assert sample_token(logits) == 1
    # top-k=1 degenerates to greedy regardless of temperature
    assert sample_token(logits, temperature=5.0, top_k=1,
                        rng=np.random.default_rng(0)) == 1
    draws = [
        [sample_token(logits, temperature=1.0, top_k=3,
                      rng=np.random.default_rng(s)) for _ in range(8)]
        for s in (7, 7, 8)
    ]
    assert draws[0] == draws[1]          # same seed, same stream
    assert set(draws[0] + draws[2]) <= {1, 2, 4}  # top-3 support only
    # top-p keeps the minimal nucleus: mass of token 1 alone exceeds 0.45
    # at low temperature, so every draw is the argmax
    nucleus = [sample_token(logits, temperature=0.5, top_p=0.45,
                            rng=np.random.default_rng(s)) for s in range(6)]
    assert set(nucleus) == {1}


def test_stochastic_serving_reproducible_per_seed():
    """Two servers with the same sampling seed produce identical streams;
    sampled tokens still come from the model's own distribution support."""
    cfg, model, params = _tiny_model()

    def serve(seed):
        reqs = _requests(cfg, [5, 7], gen=4)
        server = BatchedServer(model, params, batch_slots=2, max_len=24,
                               temperature=0.8, top_k=8, seed=seed)
        server.run(reqs)
        return [r.out for r in reqs]

    assert serve(3) == serve(3)
    assert serve(3) != serve(4)  # the seed actually reaches the streams


def test_sampled_streams_deterministic_across_batch_slots():
    """Each request draws from its OWN (seed, rid) stream: the sampled
    tokens must not depend on how many slots the server packs requests
    into (a neighbour's draws must not perturb mine)."""
    cfg, model, params = _tiny_model()

    def serve(slots):
        reqs = _requests(cfg, [5, 7, 4], gen=4)
        server = BatchedServer(model, params, batch_slots=slots, max_len=24,
                               temperature=0.9, top_k=6, seed=11)
        server.run(reqs)
        return {r.rid: r.out for r in reqs}

    assert serve(1) == serve(2) == serve(3)


def test_sampled_streams_independent_of_admission_order():
    """Reordering the request queue must not change any request's sampled
    tokens — the per-request streams make sampling a function of
    (seed, rid, model), not of scheduler interleaving."""
    cfg, model, params = _tiny_model()

    def serve(order):
        reqs = _requests(cfg, [5, 7, 4], gen=3)
        server = BatchedServer(model, params, batch_slots=2, max_len=24,
                               temperature=0.9, top_p=0.9, seed=5)
        server.run([reqs[i] for i in order])
        return {r.rid: r.out for r in reqs}

    assert serve([0, 1, 2]) == serve([2, 0, 1]) == serve([1, 2, 0])


def test_sampled_streams_stable_under_prefix_sharing():
    """Prefix-cache hits change the PREFILL work, not the logits — the
    seeded sampled streams must be identical with and without sharing."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(31)
    common = rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
    prompts = [np.concatenate(
        [common, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)]
    ) for t in (3, 5, 2)]

    def serve(prefix_cache):
        reqs = [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]
        server = BatchedServer(model, params, batch_slots=1, max_len=32,
                               paged=True, page_size=4, num_pages=24,
                               prefix_cache=prefix_cache,
                               temperature=0.8, top_k=8, seed=9)
        stats = server.run(reqs)
        return {r.rid: r.out for r in reqs}, stats

    base, _ = serve(False)
    shared, stats = serve(True)
    assert base == shared
    assert stats["prefix"]["hits"] > 0  # the shared run really shared


def test_serve_cli_boolean_flags():
    """--reduced/--split were action=store_true with default=True: the old
    parser could never turn them off."""
    ap = build_parser()
    d = ap.parse_args([])
    assert d.reduced is True and d.split is True
    off = ap.parse_args(["--no-reduced", "--no-split"])
    assert off.reduced is False and off.split is False
    on = ap.parse_args(["--reduced", "--split"])
    assert on.reduced is True and on.split is True


def test_serve_main_no_reduced_smoke(monkeypatch):
    """--no-reduced must reach the config un-reduced (smoke: monkeypatch
    the registry to a tiny config so the full-size path stays cheap)."""
    import repro.launch.serve as serve_mod

    tiny = get_config("llama32-1b").reduced()
    tiny = dataclasses.replace(tiny, n_layers=2)
    seen = {}

    class _Proxy:
        """Tiny config that records whether .reduced() was called."""

        def reduced(self):
            seen["reduced_called"] = True
            return tiny

        def __getattr__(self, item):
            return getattr(tiny, item)

    monkeypatch.setattr("repro.configs.get_config", lambda name: _Proxy())
    rc = serve_mod.main([
        "--no-reduced", "--no-split", "--bits", "4", "--engine", "fake",
        "--batch", "1", "--requests", "1", "--prompt-len", "4", "--gen", "2",
    ])
    assert rc == 0
    assert "reduced_called" not in seen  # --no-reduced honored
