"""Quantized execution engine: as_executable() + packed kernels end-to-end.

Covers the acceptance contract of the engine PR:
* executable forward matches materialize() fake-quant logits within float
  tolerance, and the packed/grouped containers dequantize BIT-exactly to
  the per-tensor quantized weights,
* serving decode through the packed kernels emits the same tokens as the
  fake-quant path,
* grouped QKV + gate/up dispatch cuts quantized kernel launches per
  transformer block from 7 to 4,
* the autotuner returns valid MXU-aligned blocks for odd shapes and honors
  the measured JSON cache.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy, restructure
from repro.core.split import group_packed, split_quantize_packed
from repro.engine import autotune
from repro.engine.executable import supports_kernel_path, weight_bytes
from repro.kernels import ops
from repro.models import build_model


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama32-1b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qm = restructure(params, QuantPolicy(bits=4, packed=True))
    return cfg, model, params, qm


def test_executable_matches_fake_quant_logits(tiny):
    cfg, model, _, qm = tiny
    ex = qm.as_executable(group=True)
    fk = qm.materialize()
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8), dtype=np.int32))
    l_ex, _ = model.prefill(ex, {"tokens": toks}, model.init_cache(2, 16))
    l_fk, _ = model.prefill(fk, {"tokens": toks}, model.init_cache(2, 16))
    np.testing.assert_allclose(
        np.asarray(l_ex), np.asarray(l_fk), rtol=1e-4, atol=1e-4,
    )


def test_grouped_weights_dequantize_bit_exact(tiny):
    _, _, _, qm = tiny
    ex = qm.as_executable(group=True)
    attn = ex["layers"]["attn"]
    mlp_p = ex["layers"]["mlp"]
    assert "wqkv" in attn and "w_gateup" in mlp_p
    # grouped dequant == member dequant, bit for bit (stacked layer axis)
    members = [qm.qleaves[f"layers/attn/{n}"] for n in ("wq", "wk", "wv")]
    got = attn["wqkv"].dequantize()
    for g, m in zip(got, members):
        want = jax.vmap(lambda t: t.dequantize())(m)
        assert (np.asarray(g) == np.asarray(want)).all()
    got = mlp_p["w_gateup"].dequantize()
    for g, name in zip(got, ("w_gate", "w_up")):
        want = jax.vmap(lambda t: t.dequantize())(
            qm.qleaves[f"layers/mlp/{name}"])
        assert (np.asarray(g) == np.asarray(want)).all()


def test_grouped_launch_count_per_block(tiny):
    cfg, model, _, qm = tiny
    toks = jnp.zeros((2, 1), jnp.int32)
    cache = model.init_cache(2, 8)

    def launches(tree):
        with ops.count_launches() as counts:
            jax.eval_shape(lambda p, t, c: model.decode_step(p, t, c)[0],
                           tree, toks, cache)
        return counts

    grouped = launches(qm.as_executable(group=True))
    ungrouped = launches(qm.as_executable(group=False))
    # scan traces the block body once: counts are per transformer block.
    # 7 separate quantized matmuls (q,k,v,o,gate,up,down) collapse to 4
    # launches (fused qkv, o, fused gate+up, down).
    assert ungrouped["total"] == 7, ungrouped
    assert grouped["total"] == 4, grouped
    assert grouped["splitq_packed_group_matmul"] == 2


def test_serve_same_tokens_as_fake_quant(tiny):
    from repro.launch.serve import BatchedServer, Request

    cfg, model, _, qm = tiny

    def run(tree):
        server = BatchedServer(model, tree, batch_slots=2, max_len=16)
        reqs = [
            Request(i, np.random.default_rng(100 + i).integers(
                0, cfg.vocab_size, 6, dtype=np.int32), 3)
            for i in range(2)
        ]
        server.run(reqs)
        return [r.out for r in reqs]

    assert run(qm.as_executable(group=True)) == run(qm.materialize())


def test_packed_halves_weight_bytes_vs_planes(tiny):
    _, _, params, qm = tiny
    planes = restructure(params, QuantPolicy(bits=4, packed=False))
    b_packed = qm.size_bytes()["quantized"]
    b_planes = planes.size_bytes()["quantized"]
    assert b_planes / b_packed >= 1.9  # 12 vs 6 bits/weight
    # executable tree bytes < dense fp32 bytes
    assert weight_bytes(qm.as_executable()) < weight_bytes(params) / 2


def test_unsupported_leaves_fall_back_dense():
    """MoE expert stacks are dequantized once (== materialize) and the
    executable forward still runs end-to-end."""
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    qm = restructure(params, QuantPolicy(bits=4, packed=True))
    ex = qm.as_executable(group=True)
    experts = ex["layers"]["moe"]["experts"]["w_up"]
    assert isinstance(experts, jax.Array)  # densified, not a container
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (1, 6), dtype=np.int32))
    l_ex, _ = model.prefill(ex, {"tokens": toks}, model.init_cache(1, 8))
    l_fk, _ = model.prefill(qm.materialize(), {"tokens": toks},
                            model.init_cache(1, 8))
    np.testing.assert_allclose(np.asarray(l_ex), np.asarray(l_fk),
                               rtol=1e-4, atol=1e-4)


def test_supports_kernel_path_paths():
    assert supports_kernel_path("layers/attn/wq")
    assert supports_kernel_path("layers/mlp/w_down")
    assert supports_kernel_path("shared_attn/mlp/w_up")
    assert supports_kernel_path("lm_head/w")
    assert not supports_kernel_path("layers/tmix/wk")      # rwkv mixer
    assert not supports_kernel_path("layers/moe/experts/w_up")
    assert not supports_kernel_path("embed/table")


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [
    (1, 100, 130), (4, 128, 64), (13, 777, 333), (128, 4096, 11008),
    (260, 5120, 13824),
])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_autotuner_blocks_valid_for_odd_shapes(m, k, n, bits):
    bm, bn, bk = autotune.heuristic_block(m, k, n, bits)
    assert bm % 8 == 0 and bm >= 8          # fp32 sublane
    assert bn % 128 == 0                    # lane
    assert bk % 128 == 0
    assert bn % 4 == 0                      # cid packing contract
    assert autotune._vmem_bytes(bm, bn, bk, bits) <= autotune.VMEM_BUDGET
    # bf16 activations need 16-row sublane alignment
    bm16, _, _ = autotune.heuristic_block(m, k, n, bits, bf16_acts=True)
    assert bm16 % 16 == 0


def test_autotuner_grouped_bn_divides_align():
    for align in (128, 512):
        _, bn, _ = autotune.choose_block(4, 1024, 3 * align, 4, max_bn=align)
        assert align % bn == 0


def test_tune_cache_roundtrip_and_dispatch(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    cache = autotune.TuneCache(path)
    cache.put(16, 1024, 1024, 4, (128, 256, 128))
    cache.save()
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    autotune.reset_cache()
    try:
        assert autotune.choose_block(16, 1024, 1024, 4) == (128, 256, 128)
        # invalid cached entries are rejected, falling back to heuristic
        autotune.get_cache().put(8, 256, 256, 4, (100, 100, 100))
        assert autotune.choose_block(8, 256, 256, 4) == \
            autotune.heuristic_block(8, 256, 256, 4)
        raw = json.loads(path.read_text())
        assert raw["blocks"]["16x1024x1024@4/d1"] == [128, 256, 128]
        # per-shard entries live in their own /dS namespace: a block tuned
        # for the 2-way-sharded width must not answer the global lookup
        autotune.get_cache().put(16, 1024, 512, 4, (128, 128, 128),
                                 n_shards=2)
        assert autotune.choose_block(16, 1024, 512, 4, n_shards=2) == \
            (128, 128, 128)
        assert autotune.choose_block(16, 1024, 512, 4) == \
            autotune.heuristic_block(16, 1024, 512, 4)
    finally:
        monkeypatch.delenv(autotune.ENV_CACHE)
        autotune.reset_cache()


def test_tune_cache_rejects_malformed_entries_at_load(tmp_path, monkeypatch):
    """A hand-edited 2-element (or non-int) entry must degrade to the
    heuristic at LOAD time, not raise inside choose_block on the hot
    path."""
    path = tmp_path / "tune.json"
    path.write_text(json.dumps({"schema": 2, "blocks": {
        "16x1024x1024@4/d1": [128, 256],         # truncated by hand-edit
        "8x256x256@4/d1": ["128", 128, 128],     # non-int member
        "8x512x512@4/d1": None,                  # nulled entry
        "8x768x768@4": [8, 128, 128],            # schema-1 GLOBAL-shape key
        "4x128x128@4/d1": [8, 128, 128],         # the one valid entry
    }}))
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    autotune.reset_cache()
    try:
        assert autotune.choose_block(16, 1024, 1024, 4) == \
            autotune.heuristic_block(16, 1024, 1024, 4)
        assert autotune.choose_block(8, 256, 256, 4) == \
            autotune.heuristic_block(8, 256, 256, 4)
        assert autotune.choose_block(8, 512, 512, 4) == \
            autotune.heuristic_block(8, 512, 512, 4)
        # the stale schema-1 key (no /dS shard suffix) is dropped at load:
        # it was tuned on a global shape and is ambiguous under sharding
        assert "8x768x768@4" not in autotune.get_cache().table
        assert autotune.choose_block(4, 128, 128, 4) == (8, 128, 128)
    finally:
        monkeypatch.delenv(autotune.ENV_CACHE)
        autotune.reset_cache()


def test_tune_cache_hit_rechecks_vmem_budget():
    """A stale entry tuned on a bigger-VMEM machine must not be dispatched
    past this build's budget."""
    autotune.reset_cache()
    try:
        huge = (8, 4096, 4096)  # aligned, but ~67 MB unpacked tile
        assert autotune._vmem_bytes(*huge, 4) > autotune.VMEM_BUDGET
        autotune.get_cache().put(8, 4096, 4096, 4, huge)
        got = autotune.choose_block(8, 4096, 4096, 4)
        assert got == autotune.heuristic_block(8, 4096, 4096, 4)
        assert autotune._vmem_bytes(*got, 4) <= autotune.VMEM_BUDGET
    finally:
        autotune.reset_cache()


def test_autotune_measured_picks_and_records(monkeypatch):
    autotune.reset_cache()
    w = jnp.asarray(np.random.default_rng(0).normal(
        0, 0.05, (256, 256)).astype(np.float32))
    psq = split_quantize_packed(w, 4)
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(8, 256)).astype(np.float32))

    def run(block):
        return ops.splitq_packed_matmul(x, psq, block=block)

    best, timings = autotune.autotune(
        run, 8, 256, 256, 4, iters=1,
        candidates=[(8, 128, 128), (8, 256, 128)],
    )
    assert best in [(8, 128, 128), (8, 256, 128)]
    assert timings
    assert autotune.get_cache().get(8, 256, 256, 4) == best
    autotune.reset_cache()


def test_bf16_activations_through_packed_kernel():
    w = jnp.asarray(np.random.default_rng(3).normal(
        0, 0.05, (96, 160)).astype(np.float32))
    psq = split_quantize_packed(w, 4)
    x32 = jnp.asarray(np.random.default_rng(4).normal(
        size=(4, 96)).astype(np.float32))
    y16 = ops.splitq_packed_matmul(x32.astype(jnp.bfloat16), psq)
    assert y16.dtype == jnp.bfloat16
    y32 = ops.splitq_packed_matmul(x32, psq)
    np.testing.assert_allclose(np.asarray(y16, np.float32), np.asarray(y32),
                               rtol=2e-2, atol=2e-2)


def test_group_packed_single_kernel_launch():
    rng = np.random.default_rng(5)
    members = [
        split_quantize_packed(jnp.asarray(
            rng.normal(0, 0.05, (64, n)).astype(np.float32)), 4)
        for n in (128, 64, 64)
    ]
    grp = group_packed(members)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    with ops.count_launches() as counts:
        outs = ops.splitq_packed_group_matmul(x, grp)
    assert counts == {"splitq_packed_group_matmul": 1, "total": 1}
    for o, m in zip(outs, members):
        want = ops.splitq_packed_matmul(x, m)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(want))
