"""Quantization-quality observability: quant reports, online divergence
probes, serving-path evaluators, and the HF checkpoint importer.

The contracts under test (this tentpole):

* the online probe is PURE OBSERVATION — greedy streams and compile
  counts are bit-identical with the probe on and off, plain and
  speculative, both cache families, while the probe-on run files a
  nonzero number of divergence samples into real histograms,
* the serving-path evaluators reproduce bare-model numbers exactly
  (MCQ) / to float tolerance (perplexity), and the packed INT8 engine
  scores what fake-quant scores on a trained LM,
* the per-layer quant report obeys the paper's invariant (splitting
  never hurts SQNR), ranks worst-first, and round-trips through the
  registry's Prometheus exposition,
* histogram quantile summaries and ``Registry.merge`` are exact and
  survive a ``parse_prometheus`` round-trip,
* HF-named safetensors checkpoints import bitwise onto the config zoo
  (orientation, norm offset, and layer stacking all inverted correctly),
  and malformed checkpoints fail loudly.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint.hf_import import (
    export_hf_state,
    import_hf_checkpoint,
    import_hf_state,
    read_safetensors,
    write_safetensors,
)
from repro.configs import get_config
from repro.core import QuantPolicy, build_quant_report, restructure
from repro.eval import (
    mcq_eval,
    mcq_problems,
    perplexity_eval,
    serve_mcq_accuracy,
    serve_perplexity,
    train_small_lm,
)
from repro.eval.tasks import eval_sequences
from repro.data.pipeline import SyntheticLM
from repro.eval.train import DATA_SEED
from repro.launch.serve import BatchedServer, Request
from repro.models import build_model
from repro.obs import NullRegistry, Registry, parse_prometheus


def _tiny_model(arch="llama32-1b", n_layers=2, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests(cfg, lens, gen, seed0=100):
    return [
        Request(i, np.random.default_rng(seed0 + i).integers(
            0, cfg.vocab_size, ln, dtype=np.int32), gen)
        for i, ln in enumerate(lens)
    ]


# ---------------------------------------------------------------------------
# Online divergence probe: non-perturbing, and actually measuring
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,n_layers", [("llama32-1b", 2),
                                           ("zamba2-1.2b", 4)])
@pytest.mark.parametrize("speculate", [0, 3])
def test_probe_streams_bit_identical_and_divergence_nonzero(arch, n_layers,
                                                            speculate):
    """The acceptance pin: serving a packed-INT4 engine with
    ``quality_probe`` on and off yields identical greedy streams and
    compile counts, while the probe-on run records a nonzero KL
    distribution against the fp reference."""
    cfg, model, fp_params = _tiny_model(arch, n_layers=n_layers)
    qparams = restructure(fp_params, QuantPolicy(bits=4, packed=True)
                          ).as_executable(group=True)
    draft = (restructure(fp_params, QuantPolicy(bits=2, packed=True))
             .as_executable(group=True) if speculate else None)
    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=24, speculate=speculate, draft_params=draft)
    lens, gen = [6, 11, 4, 9], 5

    def serve(probe):
        reqs = _requests(cfg, lens, gen)
        server = BatchedServer(
            model, qparams, quality_probe=probe,
            probe_params=fp_params if probe else None, **kw)
        stats = server.run(reqs)
        return ({r.rid: r.out for r in reqs}, stats["decode_compiles"],
                stats["prefill_compiles"], stats, server)

    off = serve(0)
    on = serve(2)
    assert on[0] == off[0], (arch, speculate)        # streams bit-identical
    assert on[1:3] == off[1:3], (arch, speculate)    # no extra compiles
    pr = on[3]["probe"]
    assert "probe" not in off[3]
    assert pr["every"] == 2 and pr["samples"] > 0
    assert 0.0 <= pr["top1_agreement_rate"] <= 1.0
    reg = on[4].registry
    kl = reg.histogram("quality_probe_kl")
    total = sum(h.count for _, h in kl.series())
    assert total == pr["samples"]
    # INT4 vs fp genuinely diverges: the KL mass is nonzero
    assert sum(h.sum for _, h in kl.series()) > 0
    mad = reg.histogram("quality_probe_max_abs_diff")
    assert sum(h.count for _, h in mad.series()) == pr["samples"]
    assert reg.total("quality_probe_samples_total") == pr["samples"]
    assert reg.total("quality_probe_top1_agree_total") == (
        pr["top1_agreements"])
    # probed positions land in the timeline for per-request attribution
    probes = [e for e in on[4].timeline.records() if e["kind"] == "probe"]
    assert len(probes) == pr["samples"]
    assert all(e["kl"] >= 0 and e["agree"] in (0, 1) for e in probes)


def test_probe_requires_reference_params():
    cfg, model, params = _tiny_model()
    with pytest.raises(ValueError, match="probe_params"):
        BatchedServer(model, params, batch_slots=2, max_len=32,
                      quality_probe=4)


# ---------------------------------------------------------------------------
# Serving-path evaluators == bare-model evaluators
# ---------------------------------------------------------------------------


def test_serving_mcq_matches_bare_mcq_exactly():
    """Teacher-forced capture through the real engine selects the same
    argmax options as the bare batched forward: identical accuracy."""
    cfg, model, params = _tiny_model()
    n = 60
    bare = mcq_eval(cfg, model, params, n_problems=n)
    served = serve_mcq_accuracy(
        model, params, mcq_problems(cfg.vocab_size, n), slots=4)
    assert served == bare


def test_serving_perplexity_matches_bare_perplexity():
    cfg, model, params = _tiny_model()
    seqs = eval_sequences(SyntheticLM(cfg.vocab_size, seed=DATA_SEED),
                          8, 24)
    bare = perplexity_eval(cfg, model, params, seqs, ctx_len=8)
    served = serve_perplexity(model, params, seqs, ctx_len=8, slots=4)
    assert served["tokens"] == bare["tokens"]
    assert abs(served["nll"] - bare["nll"]) < 1e-3


def test_teacher_forcing_rejected_under_speculation():
    """Forced continuations would silently diverge from the verifier's
    accept/reject bookkeeping — refused up front."""
    cfg, model, params = _tiny_model()
    draft = restructure(params, QuantPolicy(bits=4, packed=True)
                        ).as_executable(group=True)
    server = BatchedServer(model, params, batch_slots=2, max_len=32,
                           paged=True, page_size=4, num_pages=24,
                           speculate=3, draft_params=draft)
    reqs = [Request(0, np.arange(4, dtype=np.int32), 4,
                    force=np.array([1, 2, 3, 4], np.int32))]
    with pytest.raises(ValueError, match="force"):
        server.run(reqs)


@pytest.fixture(scope="module")
def trained_lm():
    """One short pretrain shared by the engine-agreement tests (enough
    steps to be decisively above chance, small enough for CPU CI)."""
    return train_small_lm(steps=120)


def test_packed_int8_serving_matches_fake_quant(trained_lm):
    """The packed INT8 engine and materialized fake-quant weights score
    the same trained model within noise — the engine path itself does not
    cost accuracy."""
    cfg, model, params, _ = trained_lm
    problems = mcq_problems(cfg.vocab_size, 100)
    accs = {}
    for tag, engine in (("fake", "materialize"), ("packed", "exec")):
        qm = restructure(params, QuantPolicy(bits=8, split=True,
                                             packed=engine == "exec"))
        p = (qm.materialize() if engine == "materialize"
             else qm.as_executable(group=True))
        accs[tag] = serve_mcq_accuracy(model, p, problems, slots=4)
    fp = serve_mcq_accuracy(model, params, problems, slots=4)
    assert fp > 0.30                      # trained: decisively above chance
    assert abs(accs["packed"] - accs["fake"]) <= 0.02
    assert abs(accs["packed"] - fp) <= 0.05   # INT8 ~ fp (paper Table 1)


# ---------------------------------------------------------------------------
# Per-layer quant report
# ---------------------------------------------------------------------------


def test_quant_report_invariants_and_prometheus_roundtrip(tmp_path):
    cfg, model, params = _tiny_model()
    rep = build_quant_report(params, QuantPolicy(bits=4, packed=True))
    assert rep.layers
    for r in rep.layers:
        # the paper's core claim, asserted per layer: splitting never hurts
        assert r.sqnr_split_db >= r.sqnr_base_db - 1e-6, r.layer
        assert 0.0 <= r.clip_frac_base <= 1.0
        assert 0.0 <= r.outlier_frac <= 1.0
    ranked = rep.ranked()
    assert [r.sqnr_split_db for r in ranked] == sorted(
        r.sqnr_split_db for r in ranked)
    s = rep.summary()
    assert s["layers"] == len(rep.layers)
    assert s["worst_layer"] == ranked[0].layer

    out = tmp_path / "report.json"
    rep.save(out)
    import json
    blob = json.loads(out.read_text())
    assert blob["schema"] == 1 and len(blob["layers"]) == len(rep.layers)

    reg = Registry(const_labels={"family": cfg.name})
    rep.record(reg)
    parsed = parse_prometheus(reg.to_prometheus())
    sq = {(lbl["layer"], lbl["split"]): v
          for lbl, v in parsed["quant_layer_sqnr_db"]}
    for r in rep.layers:
        assert sq[(r.layer, "0")] == pytest.approx(r.sqnr_base_db)
        assert sq[(r.layer, "1")] == pytest.approx(r.sqnr_split_db)
    assert parsed["quant_layers_total"][0][1] == len(rep.layers)


# ---------------------------------------------------------------------------
# Registry: quantile summaries + merge
# ---------------------------------------------------------------------------


def test_histogram_quantiles_in_snapshot():
    reg = Registry()
    h = reg.histogram("t", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in [0.5] * 50 + [3.0] * 45 + [7.0] * 5:
        h.observe(v)
    assert h.quantile(0.5) == 1.0
    assert h.quantile(0.99) == 8.0
    snap = reg.snapshot()["metrics"]["t"]["series"][0]
    assert snap["quantiles"] == {"p50": 1.0, "p90": 4.0, "p99": 8.0}


def test_registry_merge_exact_and_roundtrip():
    def make(n):
        r = Registry()
        r.counter("c").inc(n, kind="x")
        r.gauge("g").set(n)
        h = r.histogram("h", buckets=(1.0, 10.0))
        for v in range(n):
            h.observe(float(v))
        return r

    a, b = make(3), make(5)
    a.merge(b)
    assert a.value("c", kind="x") == 8
    assert a.value("g") == 5            # gauges: last write wins
    h = a.histogram("h")
    assert sum(hh.count for _, hh in h.series()) == 8
    # merged state survives the text exposition round-trip
    parsed = parse_prometheus(a.to_prometheus(include_global=False))
    assert dict(parsed["c"][0][0]) == {"kind": "x"}
    assert parsed["c"][0][1] == 8
    counts = {lbl["le"]: v for lbl, v in parsed["h_bucket"]}
    assert counts["+Inf"] == 8

    with pytest.raises(ValueError, match="bucket"):
        bad = Registry()
        bad.histogram("h", buckets=(2.0, 3.0))
        a.merge(bad)

    null = NullRegistry()
    null.merge(a)                        # inert, not an error
    assert not null.enabled
    c = Registry()
    c.merge(null)                        # merging a disabled source: no-op
    assert c.to_prometheus(include_global=False).strip() == ""


# ---------------------------------------------------------------------------
# HF checkpoint import
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama32-1b", "qwen3-0.6b"])
def test_hf_import_roundtrip_bitwise(arch, tmp_path):
    """init → HF names → safetensors bytes → import reproduces the exact
    tree (structure and bits), hence the exact forward."""
    cfg, model, params = _tiny_model(arch)
    path = tmp_path / "model.safetensors"
    write_safetensors(path, export_hf_state(params, cfg),
                      metadata={"format": "pt"})
    imported = import_hf_checkpoint(path, cfg)
    flat_a = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(imported)[0]
    assert [k for k, _ in flat_a] == [k for k, _ in flat_b]
    for (k, x), (_, y) in zip(flat_a, flat_b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), (arch, k)
    toks = np.arange(6, dtype=np.int32)[None]
    lens = np.array([6], np.int32)
    la, _ = model.prefill(params, {"tokens": toks, "lengths": lens},
                          model.init_cache(1, 16))
    lb, _ = model.prefill(imported, {"tokens": toks, "lengths": lens},
                          model.init_cache(1, 16))
    assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_hf_import_failure_modes(tmp_path):
    cfg, _, params = _tiny_model()
    state = export_hf_state(params, cfg)

    missing = dict(state)
    del missing["model.layers.0.self_attn.q_proj.weight"]
    with pytest.raises(KeyError, match="q_proj"):
        import_hf_state(missing, cfg)

    extra = dict(state)
    extra["model.layers.0.self_attn.rotary_emb.inv_freq"] = np.zeros(
        4, np.float32)                   # known-harmless HF extra: ignored
    extra["some.unknown.weight"] = np.zeros(4, np.float32)
    with pytest.raises(ValueError, match="unmapped"):
        import_hf_state(extra, cfg)
    import_hf_state(extra, cfg, strict=False)   # opt-out accepts it

    wrong = dict(state)
    wrong["model.norm.weight"] = np.zeros(3, np.float32)
    with pytest.raises(ValueError, match="shape"):
        import_hf_state(wrong, cfg)

    hybrid = get_config("zamba2-1.2b").reduced()
    with pytest.raises(NotImplementedError, match="family"):
        import_hf_state(state, hybrid)

    with pytest.raises(ValueError, match="safetensors"):
        p = tmp_path / "short.safetensors"
        p.write_bytes(b"abc")
        read_safetensors(p)


def test_safetensors_dtype_fidelity(tmp_path):
    """f16/bf16/int tensors survive the byte-level round trip."""
    import ml_dtypes
    tensors = {
        "a": np.arange(6, dtype=np.float16).reshape(2, 3),
        "b": np.arange(4, dtype=np.int64),
        "c": np.linspace(-1, 1, 8, dtype=np.float32).astype(
            ml_dtypes.bfloat16),
    }
    p = tmp_path / "t.safetensors"
    write_safetensors(p, tensors)
    back = read_safetensors(p)
    assert set(back) == set(tensors)
    for k in tensors:
        assert back[k].dtype == tensors[k].dtype
        assert np.array_equal(back[k], tensors[k])
