"""Asyncio service front-end e2e: SSE streams, drain, metrics.

The service (``repro.serve.app``) is a transport, not a scheduler: it
must change how tokens travel — HTTP in, SSE frames out, fair admission
in between — and never which tokens exist. What these tests pin:

* BIT-IDENTITY: greedy token streams received over SSE equal a plain
  library ``BatchedServer.run`` on the same workload — llama AND zamba2,
  plain and speculative decoding,
* DRAIN: a real SIGTERM (and the POST /drain route) mid-stream retires
  in-flight requests with partial streams, every open SSE stream gets a
  terminal ``status: "preempted"`` frame, queued requests return
  unserved, and the page pool drains to zero — no leaks,
* /metrics round-trips through ``parse_prometheus`` and carries the
  serving families; /healthz reports drain state,
* malformed submissions get 400s without perturbing the engine.

Tests drive real sockets on an ephemeral port; the engine runs its
normal synchronous loop in the service's worker thread.
"""
import asyncio
import os
import signal

import jax
import numpy as np
import pytest
from serve_helpers import make_requests, tiny_model

from repro.launch.serve import BatchedServer
from repro.obs import parse_prometheus
from repro.runtime.fault import PreemptionGuard
from repro.serve import FairScheduler
from repro.serve.app import ServeApp, http_request, sse_generate


def _server_kw(speculate=0, draft_params=None):
    kw = dict(batch_slots=2, max_len=48, paged=True, page_size=4,
              num_pages=24)
    if speculate:
        kw.update(speculate=speculate, draft_params=draft_params)
    return kw


def _payloads(reqs, tenants=("light", "heavy"), weights=(3.0, 1.0)):
    return [{
        "rid": r.rid, "prompt": r.prompt.tolist(), "max_new": r.max_new,
        "tenant": tenants[i % len(tenants)],
        "weight": weights[i % len(weights)],
    } for i, r in enumerate(reqs)]


async def _serve_over_sse(app, payloads, *, drain_after=None,
                          kill_after=None):
    """Run the workload through the service; optionally POST /drain (or
    SIGTERM the process) once ``*_after`` tokens have streamed."""
    seen = []

    def on_tok(evt):
        seen.append(evt)
        if drain_after is not None and len(seen) == drain_after:
            asyncio.ensure_future(
                http_request(app.host, app.port, "POST", "/drain"))
        if kill_after is not None and len(seen) == kill_after:
            os.kill(os.getpid(), signal.SIGTERM)

    results = await asyncio.gather(*[
        sse_generate(app.host, app.port, p, on_token=on_tok)
        for p in payloads
    ])
    return results, seen


@pytest.mark.parametrize("arch,n_layers,speculate", [
    ("llama32-1b", 2, 0),
    ("llama32-1b", 2, 4),
    ("zamba2-1.2b", 4, 0),
    ("zamba2-1.2b", 4, 4),
])
def test_sse_streams_bit_identical_to_library_run(arch, n_layers, speculate):
    """The service invariant: greedy SSE streams == library streams,
    with fair admission and the full HTTP hop in between."""
    cfg, model, params = tiny_model(arch, n_layers=n_layers)
    draft = model.init(jax.random.PRNGKey(99)) if speculate else None
    kw = _server_kw(speculate, draft)
    lens, gens = [6, 9, 5, 7], [8, 6, 8, 4]

    ref_reqs = make_requests(cfg, lens, gens)
    BatchedServer(model, params, **kw).run(ref_reqs)
    ref = {r.rid: list(r.out) for r in ref_reqs}
    assert all(len(v) > 0 for v in ref.values())

    async def go():
        app = ServeApp(BatchedServer(model, params, **kw),
                       fair=FairScheduler(quantum=16.0))
        await app.start()
        payloads = _payloads(make_requests(cfg, lens, gens))
        results, _ = await _serve_over_sse(app, payloads)
        stats = await app.stop()
        return payloads, results, stats

    payloads, results, stats = asyncio.run(go())
    got = {p["rid"]: r["tokens"] for p, r in zip(payloads, results)}
    assert got == ref, (arch, speculate, got, ref)
    for r in results:
        assert r["code"] == 200
        assert r["done"]["status"] == "ok"
        assert r["done"]["tokens"] == len(r["tokens"])
    assert stats["requests"] == len(payloads)
    assert stats["pages"]["leaked"] == 0
    if speculate:
        assert stats["spec"]["draft_pages_leaked"] == 0


def test_sigterm_drains_streams_with_terminal_frames():
    """A real SIGTERM mid-stream: the installed guard trips, in-flight
    requests retire partial, every open SSE stream ends with a
    ``preempted`` terminal frame, nothing leaks."""
    cfg, model, params = tiny_model()
    guard = PreemptionGuard().install()
    server = BatchedServer(model, params, guard=guard, **_server_kw())
    lens, gens = [6, 9], [32, 32]  # long: the drain always lands mid-run

    async def go():
        app = ServeApp(server)
        await app.start()
        payloads = _payloads(make_requests(cfg, lens, gens))
        results, seen = await _serve_over_sse(app, payloads, kill_after=4)
        stats = await app.stop()
        return results, seen, stats

    try:
        results, seen, stats = asyncio.run(go())
    finally:
        guard.uninstall()
    res = stats["resilience"]
    assert res["drained"], res
    assert all(r["done"] is not None for r in results), "stream left open"
    statuses = sorted(r["done"]["status"] for r in results)
    assert "preempted" in statuses, statuses
    for r in results:  # partial but never over-long, frames all accounted
        assert r["done"]["tokens"] == len(r["tokens"]) < 32
    assert stats["pages"]["leaked"] == 0
    assert server.alloc.in_use == 0


def test_post_drain_route_drains_and_503s_new_work():
    cfg, model, params = tiny_model()
    server = BatchedServer(model, params, **_server_kw())
    lens, gens = [6, 9], [32, 32]

    async def go():
        app = ServeApp(server)
        await app.start()
        payloads = _payloads(make_requests(cfg, lens, gens))
        results, _ = await _serve_over_sse(app, payloads, drain_after=4)
        # draining: health reports it and new submissions bounce
        code, body = await http_request(app.host, app.port, "GET", "/healthz")
        assert code == 200 and b"draining" in body
        late = await sse_generate(app.host, app.port, payloads[0])
        assert late["code"] == 503
        stats = await app.stop()
        return results, stats

    results, stats = asyncio.run(go())
    assert stats["resilience"]["drained"]
    assert all(r["done"] is not None for r in results)
    assert stats["pages"]["leaked"] == 0


def test_metrics_roundtrip_and_healthz():
    cfg, model, params = tiny_model()
    server = BatchedServer(model, params, **_server_kw())

    async def go():
        app = ServeApp(server)
        await app.start()
        code, body = await http_request(app.host, app.port, "GET", "/healthz")
        assert code == 200 and b'"ok"' in body
        payloads = _payloads(make_requests(cfg, [6, 9], [8, 8]))
        results, _ = await _serve_over_sse(app, payloads)
        # quiesce: the final SSE frame can reach the client a beat before
        # the engine thread books that wave's counters, so drain the
        # engine (listener stays up) before the exact-count scrape
        app.guard.requested = True
        while app._thread.is_alive():
            await asyncio.sleep(0.01)
        code, text = await http_request(app.host, app.port, "GET", "/metrics")
        assert code == 200
        code, _ = await http_request(app.host, app.port, "GET", "/nope")
        assert code == 404
        stats = await app.stop()
        return results, text.decode(), stats

    results, text, stats = asyncio.run(go())
    fams = parse_prometheus(text)  # raises on any unscrapeable line
    assert "serve_tokens_total" in fams
    streamed = sum(len(r["tokens"]) for r in results)
    assert sum(v for _, v in fams["serve_tokens_total"]) == streamed
    assert "serve_ttft_seconds_count" in fams
    assert stats["tokens"] == streamed


def test_bad_requests_get_400s_without_perturbing_the_engine():
    cfg, model, params = tiny_model()
    server = BatchedServer(model, params, **_server_kw())

    async def go():
        app = ServeApp(server)
        await app.start()
        bad = [
            b"not json",
            b'{"max_new": 4}',                       # no prompt
            b'{"prompt": [], "max_new": 4}',         # empty prompt
            b'{"prompt": [1, 2], "max_new": 0}',     # max_new out of range
        ]
        for body in bad:
            code, _ = await http_request(app.host, app.port, "POST",
                                         "/v1/generate", body)
            assert code == 400, body
        # the engine still serves fine afterwards
        payloads = _payloads(make_requests(cfg, [6], [4]))
        results, _ = await _serve_over_sse(app, payloads)
        stats = await app.stop()
        return results, stats

    results, stats = asyncio.run(go())
    assert results[0]["done"]["status"] == "ok"
    assert len(results[0]["tokens"]) == 4
    assert stats["requests"] == 1
    assert stats["pages"]["leaked"] == 0
