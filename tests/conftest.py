"""Suite-wide setup. MUST run before jax is first imported.

The CI/dev container ships libtpu but has no TPU: without an explicit
platform, jax's backend probe blocks ~8 minutes per process before falling
back to CPU (this alone made the suite take half an hour). Tests are
interpret-mode CPU by design; export JAX_PLATFORMS yourself to override.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
