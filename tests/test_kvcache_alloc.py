"""PageAllocator invariants: no double-assignment, no leaks, refcounts.

Property-style: a deterministic seeded random walk over alloc / free /
retain always runs (the hypothesis-driven variant rides along when
hypothesis is installed; offline CI gets it via the stub as a skip). The
invariants after EVERY operation:

* a live page is never handed out twice (all owner sets are disjoint),
* ``free + in_use == total``,
* releasing every owner returns the pool to zero pages in use.
"""
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    from hypothesis_stub import hypothesis, st

from repro.kvcache import OutOfPages, PageAllocator, pages_for


def _random_walk(seed: int, num_pages: int, ops: int):
    """Drive an allocator with a random op sequence, checking invariants
    after every step; returns when every owner has been released."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages)
    owners: list[list[int]] = []   # each entry = one owner's page list
    live: list[int] = []           # multiset of live (page, owner) claims

    def check():
        assert alloc.free_pages + alloc.in_use == num_pages
        # refcount-1 invariant: pages handed to distinct alloc() calls are
        # disjoint; a page's total owner count matches its refcount
        counts: dict[int, int] = {}
        for pages in owners:
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        assert set(counts) == {p for p in counts if alloc.refcount(p) > 0}
        for p, c in counts.items():
            assert alloc.refcount(p) == c, (p, c, alloc.refcount(p))
        assert alloc.in_use == len(counts)
        assert 0.0 <= alloc.fragmentation() <= 1.0

    for _ in range(ops):
        op = rng.integers(0, 3)
        if op == 0:  # alloc
            n = int(rng.integers(0, max(num_pages // 2, 1)) )
            if alloc.can_alloc(n):
                pages = alloc.alloc(n)
                assert len(pages) == n == len(set(pages))
                # freshly allocated pages must not collide with live ones
                flat = {p for o in owners for p in o}
                assert not (set(pages) & flat), "double-assigned live page"
                owners.append(pages)
            else:
                with pytest.raises(OutOfPages):
                    alloc.alloc(n)
        elif op == 1 and owners:  # free one owner
            idx = int(rng.integers(0, len(owners)))
            alloc.free(owners.pop(idx))
        elif op == 2 and owners:  # retain: add a sharing owner
            idx = int(rng.integers(0, len(owners)))
            shared = list(owners[idx])
            alloc.retain(shared)
            owners.append(shared)
        check()
    while owners:
        alloc.free(owners.pop())
        check()
    assert alloc.in_use == 0, "pages leaked"
    assert alloc.free_pages == num_pages
    return alloc


def test_random_walk_never_double_assigns_never_leaks():
    for seed in range(5):
        _random_walk(seed, num_pages=13, ops=120)


@hypothesis.given(st.integers(min_value=0, max_value=10_000),
                  st.integers(min_value=1, max_value=64),
                  st.integers(min_value=1, max_value=200))
@hypothesis.settings(max_examples=25, deadline=None)
def test_random_walk_property(seed, num_pages, ops):
    _random_walk(seed, num_pages, ops)


def test_refcounted_page_survives_partial_free():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2)
    alloc.retain(pages)          # second owner (shared prefix)
    alloc.free(pages)            # first owner drops
    assert alloc.in_use == 2     # still live under the second owner
    assert all(alloc.refcount(p) == 1 for p in pages)
    reuse = alloc.alloc(2)       # the two remaining free pages
    assert not (set(reuse) & set(pages))
    alloc.free(pages)
    assert alloc.in_use == 2     # only `reuse` remains
    alloc.free(reuse)
    assert alloc.in_use == 0


def test_error_paths():
    alloc = PageAllocator(2)
    with pytest.raises(OutOfPages):
        alloc.alloc(3)
    pages = alloc.alloc(2)
    with pytest.raises(KeyError):
        alloc.free([99])                 # never allocated
    alloc.free(pages)
    with pytest.raises(KeyError):
        alloc.free(pages)                # double free
    with pytest.raises(KeyError):
        alloc.retain(pages)              # retain of a free page
    with pytest.raises(ValueError):
        PageAllocator(0)


def test_stats_and_fragmentation():
    alloc = PageAllocator(8)
    a = alloc.alloc(4)
    assert alloc.stats()["in_use"] == 4
    assert alloc.stats()["peak_in_use"] == 4
    alloc.free(a)
    s = alloc.stats()
    assert s["free"] == 8 and s["in_use"] == 0 and s["peak_in_use"] == 4
    # LIFO free list: page ids are recycled, still no double assignment
    b = alloc.alloc(8)
    assert sorted(b) == list(range(8))
    alloc.free(b)
    assert alloc.fragmentation() == 0.0  # whole pool is one free run


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(33, 8) == 5
