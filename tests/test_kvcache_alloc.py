"""PageAllocator invariants: no double-assignment, no leaks, refcounts,
copy-on-write writer exclusivity.

Property-style: a deterministic seeded random walk over alloc / free /
retain / cow always runs (the REAL hypothesis-driven variant rides along
when the package is installed — CI runs it in its own job step; offline
containers fall back to the stub, which skips it). The invariants after
EVERY operation:

* a live page is never handed out twice (fresh pages never collide with
  any live owner's),
* ``free + in_use == total`` (and ``shared`` counts exactly the pages
  with more than one owner),
* NO DOUBLE WRITER: a page an owner is about to write has refcount 1 —
  ``cow`` either confirms exclusivity or trades the claim for a fresh
  private copy, never mutating other owners' views,
* on-demand GROWTH (an owner extending its page list mid-life, the
  serve path's ``_ensure_rows``) hands out only fresh pages, and a
  preemption-style release reports exactly how many pages actually
  returned to the pool (shared pages only lose a reference),
* ``audit()`` — the structural self-check the serving runtime runs after
  every preemption — passes after EVERY operation,
* the SPILL tier's allocator-level contract: spilling an owner returns
  all its pages to the pool (only the page count survives, on the host
  store), and a later restore lands exclusively on fresh refcount-1
  pages — never on a page another owner or the prefix index still reads,
* releasing every owner returns the pool to zero pages in use.
"""
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    from hypothesis_stub import hypothesis, st

from repro.kvcache import OutOfPages, PageAllocator, pages_for


def _random_walk(seed: int, num_pages: int, ops: int):
    """Drive an allocator with a random op sequence, checking invariants
    after every step; returns when every owner has been released."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages)
    owners: list[list[int]] = []   # each entry = one owner's page list
    spilled: list[int] = []        # page counts of spilled-out owners

    def check():
        assert alloc.free_pages + alloc.in_use == num_pages
        # refcount-1 invariant: pages handed to distinct alloc() calls are
        # disjoint; a page's total owner count matches its refcount
        counts: dict[int, int] = {}
        for pages in owners:
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        assert set(counts) == {p for p in counts if alloc.refcount(p) > 0}
        for p, c in counts.items():
            assert alloc.refcount(p) == c, (p, c, alloc.refcount(p))
        assert alloc.in_use == len(counts)
        # shared accounting: exactly the pages with more than one owner
        assert alloc.shared == sum(1 for c in counts.values() if c > 1)
        assert alloc.stats()["shared"] == alloc.shared
        assert 0.0 <= alloc.fragmentation() <= 1.0
        alloc.audit()  # structural check: free list vs refcount ledger

    for _ in range(ops):
        op = rng.integers(0, 9)
        if op == 0:  # alloc
            n = int(rng.integers(0, max(num_pages // 2, 1)) )
            if alloc.can_alloc(n):
                pages = alloc.alloc(n)
                assert len(pages) == n == len(set(pages))
                # freshly allocated pages must not collide with live ones
                flat = {p for o in owners for p in o}
                assert not (set(pages) & flat), "double-assigned live page"
                owners.append(pages)
            else:
                with pytest.raises(OutOfPages):
                    alloc.alloc(n)
        elif op == 1 and owners:  # free one owner
            idx = int(rng.integers(0, len(owners)))
            pages = owners.pop(idx)
            # free() reports how many pages actually returned to the pool:
            # exactly those this owner held exclusively
            expect = sum(1 for p in pages if alloc.refcount(p) == 1)
            assert alloc.free(pages) == expect
        elif op == 2 and owners:  # retain: add a sharing owner
            idx = int(rng.integers(0, len(owners)))
            shared = list(owners[idx])
            alloc.retain(shared)
            owners.append(shared)
        elif op == 3 and owners:  # write intent: cow then "scatter"
            idx = int(rng.integers(0, len(owners)))
            own = owners[idx]
            if own and alloc.refcount(own[0]) > 1 and not alloc.can_alloc(1):
                with pytest.raises(OutOfPages):
                    alloc.cow(own[0])  # shared + empty pool: no copy source
            elif own and alloc.free_pages > 0:
                j = int(rng.integers(0, len(own)))
                before = {p for k, o in enumerate(owners) if k != idx
                          for p in o}
                was_shared = alloc.refcount(own[j]) > 1
                page, copied = alloc.cow(own[j])
                assert copied == was_shared
                assert copied == (page != own[j])
                own[j] = page
                # NO DOUBLE WRITER: the page about to be written is now
                # exclusively this owner's, and cow never moved any OTHER
                # owner's claims
                assert alloc.refcount(page) == 1, "shared page written"
                assert page not in before, "cow stole a live page"
                after = {p for k, o in enumerate(owners) if k != idx
                         for p in o}
                assert before == after, "cow mutated another owner"
        elif op == 4 and owners:  # truncate: shrink an owner's tail
            idx = int(rng.integers(0, len(owners)))
            own = owners[idx]
            keep = int(rng.integers(0, len(own) + 1))
            dropped = own[keep:]
            owners[idx] = alloc.truncate(own, keep)
            assert owners[idx] == own[:keep]
            # dropped pages lose exactly ONE reference (shared pages
            # survive under their other owners)
            for p in dropped:
                expect = sum(o.count(p) for o in owners)
                assert alloc.refcount(p) == expect, (p, expect)
            if not owners[idx]:
                owners.pop(idx)
        elif op == 5 and owners:  # on-demand growth (serve _ensure_rows)
            idx = int(rng.integers(0, len(owners)))
            n = int(rng.integers(1, 3))
            if alloc.can_alloc(n):
                grown = alloc.alloc(n)
                flat = {p for o in owners for p in o}
                assert not (set(grown) & flat), "growth reused a live page"
                owners[idx] = owners[idx] + grown
        elif op == 6 and owners:  # preemption: victim releases everything
            idx = int(rng.integers(0, len(owners)))
            pages = owners.pop(idx)
            before_free = alloc.free_pages
            returned = alloc.free(pages)
            # the pool gains exactly what free() reports — the victim's
            # exclusive pages; shared ones survive under other owners
            assert alloc.free_pages == before_free + returned
            assert returned == sum(
                1 for p in pages
                if not any(p in o for o in owners)
            )
        elif op == 7 and owners:  # spill-to-disk: pages freed, rows kept
            # the serve path's _maybe_spill: a preempted owner's page
            # CONTENTS move to the host store and every page returns to
            # the pool (shared ones just lose this owner's reference) —
            # only the page COUNT must survive for the restore
            idx = int(rng.integers(0, len(owners)))
            pages = owners.pop(idx)
            spilled.append(len(pages))
            alloc.free(pages)
        elif op == 8 and spilled:  # restore: reload into FRESH pages only
            n = spilled[-1]
            if alloc.can_alloc(n):
                spilled.pop()
                fresh = alloc.alloc(n)
                # restore overwrites page contents, so the target pages
                # must be exclusively owned and never a live page some
                # other request (or the prefix index) still reads
                flat = {p for o in owners for p in o}
                assert not (set(fresh) & flat), "restore reused a live page"
                assert all(alloc.refcount(p) == 1 for p in fresh)
                owners.append(fresh)
        check()
    while owners:
        assert alloc.free(owners.pop()) >= 0
        check()
    assert alloc.in_use == 0, "pages leaked"
    assert alloc.free_pages == num_pages
    return alloc


def test_random_walk_never_double_assigns_never_leaks():
    for seed in range(5):
        _random_walk(seed, num_pages=13, ops=120)


@hypothesis.given(st.integers(min_value=0, max_value=10_000),
                  st.integers(min_value=1, max_value=64),
                  st.integers(min_value=1, max_value=200))
@hypothesis.settings(max_examples=25, deadline=None)
def test_random_walk_property(seed, num_pages, ops):
    _random_walk(seed, num_pages, ops)


def test_truncate_frees_tail_keeps_prefix():
    """truncate releases an owner's tail pages (the speculative drafter's
    early release) without touching other owners' claims."""
    alloc = PageAllocator(8)
    pages = alloc.alloc(5)
    kept = alloc.truncate(pages, 2)
    assert kept == pages[:2]
    assert alloc.in_use == 2
    # shared tails survive under the other owner
    alloc.retain(kept)
    rest = alloc.truncate(kept, 0)  # full release of THIS owner's claim
    assert rest == []
    assert alloc.in_use == 2 and all(alloc.refcount(p) == 1 for p in kept)
    alloc.free(kept)
    assert alloc.in_use == 0
    with pytest.raises(ValueError):
        alloc.truncate([0], -1)


def test_refcounted_page_survives_partial_free():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2)
    alloc.retain(pages)          # second owner (shared prefix)
    alloc.free(pages)            # first owner drops
    assert alloc.in_use == 2     # still live under the second owner
    assert all(alloc.refcount(p) == 1 for p in pages)
    reuse = alloc.alloc(2)       # the two remaining free pages
    assert not (set(reuse) & set(pages))
    alloc.free(pages)
    assert alloc.in_use == 2     # only `reuse` remains
    alloc.free(reuse)
    assert alloc.in_use == 0


def test_cow_shared_page_trades_claim_for_fresh_copy():
    alloc = PageAllocator(4)
    [p] = alloc.alloc(1)
    alloc.retain([p])             # two owners: the page is read-only now
    new, copied = alloc.cow(p)
    assert copied and new != p
    assert alloc.refcount(new) == 1   # caller is the exclusive writer
    assert alloc.refcount(p) == 1     # the OTHER owner's view is untouched
    assert alloc.cow_copies == 1
    alloc.free([new])
    alloc.free([p])
    assert alloc.in_use == 0


def test_cow_exclusive_page_is_identity():
    alloc = PageAllocator(2)
    [p] = alloc.alloc(1)
    assert alloc.cow(p) == (p, False)
    assert alloc.cow_copies == 0
    alloc.free([p])
    with pytest.raises(KeyError):
        alloc.cow(p)  # cow of a free page


def test_cow_shared_with_empty_pool_raises():
    alloc = PageAllocator(1)
    [p] = alloc.alloc(1)
    alloc.retain([p])
    with pytest.raises(OutOfPages):
        alloc.cow(p)
    # the failed cow must not have corrupted the refcount
    assert alloc.refcount(p) == 2
    alloc.free([p])
    alloc.free([p])
    assert alloc.in_use == 0


def test_error_paths():
    alloc = PageAllocator(2)
    with pytest.raises(OutOfPages):
        alloc.alloc(3)
    pages = alloc.alloc(2)
    with pytest.raises(KeyError):
        alloc.free([99])                 # never allocated
    alloc.free(pages)
    with pytest.raises(KeyError):
        alloc.free(pages)                # double free
    with pytest.raises(KeyError):
        alloc.retain(pages)              # retain of a free page
    with pytest.raises(ValueError):
        PageAllocator(0)


def test_stats_and_fragmentation():
    alloc = PageAllocator(8)
    a = alloc.alloc(4)
    assert alloc.stats()["in_use"] == 4
    assert alloc.stats()["peak_in_use"] == 4
    alloc.free(a)
    s = alloc.stats()
    assert s["free"] == 8 and s["in_use"] == 0 and s["peak_in_use"] == 4
    # LIFO free list: page ids are recycled, still no double assignment
    b = alloc.alloc(8)
    assert sorted(b) == list(range(8))
    alloc.free(b)
    assert alloc.fragmentation() == 0.0  # whole pool is one free run


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(33, 8) == 5
