"""Observability subsystem: metrics registry, per-request tracing, and
the structured scheduler timeline.

The contracts under test (the telemetry tentpole):

* telemetry is PURE OBSERVATION — greedy streams and compile counts are
  bit-identical between a live registry and ``Observability.disabled()``
  (the no-op registry), plain and speculative, both cache families,
* the registry's live counters agree with the stats dict (they are built
  from the same events, so they can never diverge),
* span invariants: per-request spans are time-ordered, their emitted
  counts sum to exactly ``len(out)``, TTFT <= total latency, and a
  preempted-and-restored request carries a ``replay`` span,
* per-replica metric series sum to the aggregate under DP (subprocess,
  2x2 mesh on 8 fake devices),
* the timeline is a ring: dropped records are counted, exported as a
  metric, and fail the serve CLI loudly (nonzero exit),
* the Prometheus exposition round-trips through ``parse_prometheus``.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy, restructure
from repro.launch.serve import BatchedServer, Request
from repro.models import build_model
from repro.obs import (
    DEFAULT_TIME_BUCKETS,
    NullRegistry,
    Observability,
    Registry,
    Timeline,
    global_registry,
    parse_prometheus,
    read_jsonl,
    reset_global_registry,
)


def _tiny_model(arch="llama32-1b", n_layers=2, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests(cfg, lens, gen, seed0=100):
    return [
        Request(i, np.random.default_rng(seed0 + i).integers(
            0, cfg.vocab_size, ln, dtype=np.int32), gen)
        for i, ln in enumerate(lens)
    ]


# ---------------------------------------------------------------------------
# Registry unit pins
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = Registry()
    c = reg.counter("reqs_total", "h")
    c.inc(replica=0)
    c.inc(2, replica=1)
    assert c.value(replica=0) == 1 and c.value(replica=1) == 2
    assert reg.total("reqs_total") == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    reg.gauge("depth").set(4)
    reg.gauge("depth").set(7)  # get-or-create returns the same family
    assert reg.value("depth") == 7
    h = reg.histogram("lat_seconds", "h")
    for v in (2e-4, 2e-4, 1.0):
        h.observe(v)
    assert h.quantile(0.5) <= 1e-3  # two of three sit in the 200us bucket
    assert h.quantile(1.0) >= 1.0
    # a name registered as one kind cannot be re-registered as another
    with pytest.raises(TypeError):
        reg.counter("depth")


def test_prometheus_roundtrip_and_const_labels():
    reg = Registry(const_labels={"family": "dense", "engine": "packed"})
    reg.counter("serve_tokens_total", "emitted").inc(5, replica=0)
    reg.counter("serve_tokens_total").inc(3, replica=1)
    reg.histogram("serve_ttft_seconds", "ttft").observe(0.01, replica=0)
    text = reg.to_prometheus(include_global=False)
    snap = parse_prometheus(text)
    toks = snap["serve_tokens_total"]
    assert sum(v for _, v in toks) == 8
    # const labels stamped onto every series
    assert all(lbl["family"] == "dense" and lbl["engine"] == "packed"
               for lbl, _ in toks)
    assert {lbl["replica"] for lbl, _ in toks} == {"0", "1"}
    # histogram exports the cumulative +Inf bucket and _sum/_count
    inf = [v for lbl, v in snap["serve_ttft_seconds_bucket"]
           if lbl["le"] == "+Inf"]
    assert inf == [1.0]
    assert snap["serve_ttft_seconds_count"][0][1] == 1
    # strict parser: garbage must raise, not be skipped
    with pytest.raises(ValueError):
        parse_prometheus("this is { not a metric\n")


def test_null_registry_is_inert():
    reg = NullRegistry()
    assert not reg.enabled
    reg.counter("a_total").inc(5)
    reg.gauge("b").set(1)
    reg.histogram("c_seconds").observe(0.5)
    assert reg.snapshot()["metrics"] == {}
    assert reg.to_prometheus() == ""


def test_global_registry_merged_into_exports():
    reset_global_registry()
    try:
        global_registry().counter("tune_cache_hits_total", "h").inc(4)
        reg = Registry(const_labels={"engine": "packed"})
        reg.counter("serve_tokens_total").inc(2)
        snap = reg.snapshot()
        assert snap["metrics"]["tune_cache_hits_total"]["series"][0][
            "value"] == 4
        parsed = parse_prometheus(reg.to_prometheus())
        assert parsed["tune_cache_hits_total"][0][1] == 4
        # the global registry itself does not re-merge (no recursion)
        assert "serve_tokens_total" not in global_registry().snapshot()[
            "metrics"]
    finally:
        reset_global_registry()


# ---------------------------------------------------------------------------
# Timeline unit pins
# ---------------------------------------------------------------------------


def test_timeline_ring_drops_and_legacy_rendering(tmp_path):
    tl = Timeline(cap=3)
    tl.set_tick(0)
    tl.emit("prefill", rows=2)
    tl.emit("admission", rid=7)        # timeline-only detail
    tl.emit("decode", rows=2)
    tl.emit("preempt", rid=3)
    tl.emit("replay", rid=3, tokens=9)
    assert len(tl) == 3 and tl.seq == 5 and tl.dropped == 2
    # legacy strings render only the kinds the old list held
    assert tl.legacy_events() == ["decode", "preempt:3", "replay:3"]
    p = tmp_path / "t.jsonl"
    assert tl.to_jsonl(p) == 3
    meta, recs = read_jsonl(p)
    assert meta["events"] == 5 and meta["dropped"] == 2 and meta["cap"] == 3
    assert [r["kind"] for r in recs] == ["decode", "preempt", "replay"]
    assert [r["seq"] for r in recs] == [2, 3, 4]  # monotone survives drops
    with pytest.raises(ValueError):
        Timeline(cap=-1)
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "decode"}\n')
    with pytest.raises(ValueError, match="meta"):
        read_jsonl(bad)


# ---------------------------------------------------------------------------
# Bit-identity: telemetry is pure observation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,n_layers", [("llama32-1b", 2),
                                           ("zamba2-1.2b", 4)])
@pytest.mark.parametrize("speculate", [0, 3])
def test_streams_bit_identical_with_and_without_registry(arch, n_layers,
                                                         speculate):
    """The tentpole's acceptance pin: the SAME workload served with a live
    registry and with the no-op registry produces identical greedy streams,
    identical compile counts, and identical legacy event strings."""
    cfg, model, params = _tiny_model(arch, n_layers=n_layers)
    draft = (restructure(params, QuantPolicy(bits=4, packed=True))
             .as_executable(group=True) if speculate else None)
    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=24, speculate=speculate, draft_params=draft)
    lens, gen = [6, 11, 4, 9], 5

    def serve(obs):
        reqs = _requests(cfg, lens, gen)
        server = BatchedServer(model, params, obs=obs, **kw)
        stats = server.run(reqs)
        return ({r.rid: r.out for r in reqs}, stats["decode_compiles"],
                stats["prefill_compiles"], server.events, server)

    on = serve(None)                          # default: live registry
    off = serve(Observability.disabled())     # no-op registry + tracer
    assert on[4].registry.enabled and not off[4].registry.enabled
    assert on[0] == off[0], (arch, speculate)
    assert on[1:4] == off[1:4], (arch, speculate)
    # the disabled bundle still keeps the REAL timeline (events compat)
    assert off[3] and off[3] == on[3]
    assert off[4].tracer.requests() == []


def test_registry_counters_match_stats_dict():
    """Live counters and the stats dict are built from the same events:
    totals must agree exactly."""
    cfg, model, params = _tiny_model()
    reqs = _requests(cfg, [6, 11, 4], 5)
    server = BatchedServer(model, params, batch_slots=2, max_len=32,
                           paged=True, page_size=4, num_pages=24,
                           prefix_cache=True)
    stats = server.run(reqs)
    reg = server.registry
    assert reg.total("serve_tokens_total") == stats["tokens"]
    assert reg.total("serve_requests_total") == stats["requests"]
    assert reg.value("serve_requests_total", status="ok", replica=0) == 3
    assert reg.value("serve_jit_compiles",
                     step="decode") == stats["decode_compiles"]
    assert reg.value("serve_decode_ticks") == stats["decode_steps"]
    assert reg.value("kv_pages_leaked") == stats["pages"]["leaked"] == 0
    assert reg.value("prefix_hits", replica=0) == stats["prefix"]["hits"]
    assert reg.value("obs_trace_events") == server.timeline.seq > 0
    # the step timer saw every jitted seam the run exercised
    st = stats["obs"]["step_time"]
    assert set(st) >= {"prefill", "decode"}
    assert all(v["count"] > 0 and v["total_s"] >= 0 for v in st.values())
    hist = reg.histogram("serve_step_seconds")
    assert sum(h.count for _, h in hist.series()) == sum(
        v["count"] for v in st.values())


def test_spec_counters_match_spec_stats():
    cfg, model, params = _tiny_model()
    draft = restructure(params, QuantPolicy(bits=4, packed=True)
                        ).as_executable(group=True)
    reqs = _requests(cfg, [6, 11, 4, 9], 6)
    server = BatchedServer(model, params, batch_slots=2, max_len=32,
                           paged=True, page_size=4, num_pages=24,
                           speculate=3, draft_params=draft)
    stats = server.run(reqs)
    sp, reg = stats["spec"], server.registry
    assert reg.total("spec_drafted_total") == sp["drafted"] > 0
    assert reg.total("spec_accepted_total") == sp["accepted"] > 0
    assert reg.total("spec_verify_forwards_total") == sp["target_forwards"]
    assert reg.total("spec_draft_forwards_total") == sp["draft_forwards"]
    assert reg.value("spec_acceptance_rate") == sp["acceptance_rate"]


# ---------------------------------------------------------------------------
# Span invariants
# ---------------------------------------------------------------------------


def _check_span_invariants(server, reqs):
    for r in reqs:
        tr = server.tracer.request(r.rid)
        assert tr is not None, r.rid
        spans = tr["spans"]
        kinds = [s["kind"] for s in spans]
        assert kinds[0] == "queued" and kinds[-1] == "retired", kinds
        # spans are time-ordered with monotone start AND end times
        for a, b in zip(spans, spans[1:]):
            assert b["t0"] >= a["t0"] and b["t1"] >= a["t1"], (r.rid, kinds)
        for s in spans:
            assert s["t1"] >= s["t0"], s
        # every emitted token is attributed to exactly one span
        assert sum(s.get("emitted", 0) for s in spans) == len(r.out), (
            r.rid, spans)
        assert tr["emitted"] == len(r.out)
        if r.out:
            assert tr["ttft_s"] <= tr["latency_s"], tr
            assert tr["queue_wait_s"] <= tr["ttft_s"], tr
        if tr.get("tpot_s") is not None and len(r.out) > 1:
            assert tr["tpot_s"] >= 0


@pytest.mark.parametrize("speculate", [0, 3])
def test_span_invariants_plain_and_speculative(speculate):
    cfg, model, params = _tiny_model()
    draft = (restructure(params, QuantPolicy(bits=4, packed=True))
             .as_executable(group=True) if speculate else None)
    reqs = _requests(cfg, [6, 11, 4, 9], 5)
    server = BatchedServer(model, params, batch_slots=2, max_len=32,
                           paged=True, page_size=4, num_pages=24,
                           speculate=speculate, draft_params=draft)
    stats = server.run(reqs)
    _check_span_invariants(server, reqs)
    summ = stats["obs"]["requests"]
    assert summ["requests"] == len(reqs)
    assert summ["ttft_s"]["p50"] <= summ["latency_s"]["max"]
    if speculate:
        # verify spans carry the draft/accept attribution
        vs = [s for r in reqs for s in server.tracer.request(r.rid)["spans"]
              if s["kind"] == "verify"]
        assert vs and any(s.get("accepted", 0) > 0 for s in vs)


def test_preempted_request_carries_replay_span():
    """Page pressure under growth forces preemption: the victim's trace
    must show preempt -> replay -> (re)prefill, its replay tokens must be
    counted, and the live resilience counters must match the stats."""
    cfg, model, params = _tiny_model()
    reqs = _requests(cfg, [8, 8, 8, 8], 8)
    server = BatchedServer(model, params, batch_slots=4, max_len=16,
                           paged=True, page_size=8, num_pages=6,
                           page_growth=True)
    stats = server.run(reqs)
    res = stats["resilience"]
    assert res["preemptions"] > 0 and res["replays"] > 0
    reg = server.registry
    assert reg.total("resilience_preemptions_total") == res["preemptions"]
    assert reg.total("resilience_replays_total") == res["replays"]
    _check_span_invariants(server, reqs)
    victims = [server.tracer.request(r.rid) for r in reqs]
    victims = [t for t in victims if t["preemptions"] > 0]
    assert victims
    for t in victims:
        kinds = [s["kind"] for s in t["spans"]]
        i = kinds.index("preempt")
        assert "replay" in kinds[i:], kinds
        j = i + kinds[i:].index("replay")
        assert "prefill" in kinds[j:], kinds  # the restore really re-fed
        assert t["replay_tokens"] > 0
    # timeline carries the same story as structured records
    assert len(server.timeline.records("preempt")) == res["preemptions"]
    assert len(server.timeline.records("replay")) == res["replays"]


def test_server_trace_cap_ring_drops_counted():
    cfg, model, params = _tiny_model()
    reqs = _requests(cfg, [6, 9], 6)
    server = BatchedServer(model, params, batch_slots=2, max_len=24,
                           trace_cap=2)
    stats = server.run(reqs)
    assert server.timeline.dropped > 0
    assert stats["obs"]["trace_dropped"] == server.timeline.dropped
    assert server.registry.value(
        "obs_trace_dropped") == server.timeline.dropped


def test_serve_cli_fails_loudly_on_trace_drops(monkeypatch):
    """--trace-cap small enough to wrap the ring must exit nonzero: a
    truncated timeline silently read as complete is an observability
    bug."""
    import repro.launch.serve as serve_mod

    tiny = get_config("llama32-1b").reduced()
    tiny = dataclasses.replace(tiny, n_layers=2)

    class _Proxy:
        def reduced(self):
            return tiny

        def __getattr__(self, item):
            return getattr(tiny, item)

    monkeypatch.setattr("repro.configs.get_config", lambda name: _Proxy())
    argv = ["--no-reduced", "--no-split", "--bits", "4", "--engine", "fake",
            "--batch", "2", "--requests", "2", "--prompt-len", "4",
            "--gen", "6"]
    assert serve_mod.main(argv + ["--trace-cap", "2"]) != 0


# ---------------------------------------------------------------------------
# Per-replica series sum to the aggregate (2x2 mesh, subprocess)
# ---------------------------------------------------------------------------


_MESH_METRICS = """
    import os
    assert "--xla_force_host_platform_device_count=8" in os.environ["XLA_FLAGS"]
    import jax, numpy as np
    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import BatchedServer, Request
    from repro.models import build_model
    from repro.obs import parse_prometheus

    cfg = get_config("llama32-1b").reduced()
    model = build_model(cfg)
    fp = model.init(jax.random.PRNGKey(0))
    params = restructure(fp, QuantPolicy(bits=4, split=True, packed=True)
                         ).as_executable(group=True)
    rng = np.random.default_rng(0)
    common = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    reqs = [Request(i, np.concatenate([
        common, rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)]), 6)
        for i in range(6)]
    mesh = make_mesh((2, 2), ("data", "model"))
    srv = BatchedServer(model, params, 4, 48, paged=True, page_size=8,
                        prefix_cache=True, mesh=mesh)
    stats = srv.run(reqs)
    reg = srv.registry
    assert stats["requests"] == 6

    # per-replica token/request series sum to the aggregate, and BOTH
    # replicas actually served (the DP split is real)
    per = [reg.value("serve_tokens_total", replica=r) for r in (0, 1)]
    assert sum(per) == reg.total("serve_tokens_total") == stats["tokens"]
    assert all(v > 0 for v in per), per
    assert reg.total("serve_requests_total") == 6

    # pool gauges per replica mirror the per-replica pool stats
    for r, ps in enumerate(stats["pages"]["per_replica"]):
        assert reg.value("kv_pages_peak", replica=r) == ps["peak_in_use"]
        assert reg.value("kv_pages_in_use", replica=r) == ps["in_use"]

    # prefix counters: replica series sum to the aggregated stats dict
    hits = sum(reg.value("prefix_hits", replica=r) for r in (0, 1))
    assert hits == stats["prefix"]["hits"] > 0
    assert reg.value("mesh_data_replicas") == 2
    assert reg.value("mesh_model_shards") == 2

    # the whole mesh run's exposition round-trips
    snap = parse_prometheus(reg.to_prometheus())
    for name in ("serve_tokens_total", "kv_pages_peak", "prefix_hits",
                 "serve_ttft_seconds_bucket", "mesh_data_replicas"):
        assert name in snap, name
    tok = {lbl["replica"]: v for lbl, v in snap["serve_tokens_total"]}
    assert tok == {"0": float(per[0]), "1": float(per[1])}
    print("OK mesh-metrics")
"""


def test_per_replica_metrics_sum_to_aggregate_2x2():
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MESH_METRICS)],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert "OK mesh-metrics" in r.stdout


# ---------------------------------------------------------------------------
# Shared timing helper + autotune counters
# ---------------------------------------------------------------------------


def test_timeit_is_the_shared_clock():
    """kernel_bench and the autotuner must both delegate to
    ``obs.profile.timeit`` (one warmup discipline, median-of-k)."""
    from repro.obs.profile import timeit

    calls = []
    assert timeit(lambda: calls.append(1), iters=3, warmup=2) >= 0.0
    assert len(calls) == 5  # 2 warmup + 3 timed

    import inspect

    from benchmarks import kernel_bench
    from repro.engine import autotune

    assert "timeit" in inspect.getsource(kernel_bench._time)
    assert "timeit" in inspect.getsource(autotune.autotune)


def test_autotune_counters_ride_global_registry():
    from repro.engine.autotune import autotune, choose_block, get_cache

    reset_global_registry()
    try:
        choose_block(8, 256, 256, 4)  # cold cache: a miss
        g = global_registry()
        assert g.value("tune_cache_misses_total") == 1
        best, timings = autotune(lambda blk: None, 8, 256, 256, 4,
                                 candidates=[(8, 128, 128), (8, 256, 128)],
                                 iters=1)
        assert g.value("autotune_trials_total") == 2
        assert g.value("autotune_winners_total") == 1
        assert get_cache().get(8, 256, 256, 4) == best
        choose_block(8, 256, 256, 4)  # now served from the cache
        assert g.value("tune_cache_hits_total") == 1
    finally:
        reset_global_registry()
        from repro.engine.autotune import reset_cache
        reset_cache()


def test_step_timer_disabled_is_passthrough():
    from repro.obs import StepTimer

    on = StepTimer(Registry())
    off = StepTimer(NullRegistry())
    assert on.enabled and not off.enabled
    assert off.run("decode", lambda: 41) == 41 and off.summary() == {}
    assert on.run("decode", lambda: 41) == 41
    s = on.summary()
    assert s["decode"]["count"] == 1 and s["decode"]["total_s"] >= 0


def test_default_time_buckets_cover_serving_latencies():
    assert DEFAULT_TIME_BUCKETS[0] == pytest.approx(1e-4)
    assert DEFAULT_TIME_BUCKETS[-1] > 50  # ~52s: slow CI mesh runs fit
    assert list(DEFAULT_TIME_BUCKETS) == sorted(DEFAULT_TIME_BUCKETS)
