"""Fallback shim for property tests when ``hypothesis`` is absent.

This container is offline — hypothesis cannot be pip-installed — and a bare
``import hypothesis`` used to error the WHOLE suite at collection. Test
modules instead do::

    try:
        import hypothesis
        import hypothesis.extra.numpy as hnp
        import hypothesis.strategies as st
    except ImportError:
        from hypothesis_stub import hypothesis, hnp, st

With the stub, strategy expressions evaluate to inert placeholders and
``@hypothesis.given(...)`` marks the test as skipped — the deterministic
tests in the same module keep running unconditionally.

The REAL package is preferred whenever importable: ``pip install -e
.[dev]`` (or the ``property`` extra) pulls it in, and CI runs the
property tests under it in a dedicated ``property-tests`` job that fails
if they report as skipped — the stub is strictly the offline fallback,
never the path of record.
"""
from __future__ import annotations

import pytest

SKIP_REASON = "hypothesis not installed (offline container)"


class _Inert:
    """Absorbs any attribute access / call / iteration; returns itself.

    When called as a decorator (single function argument) it acts as the
    identity so ``@hypothesis.settings(...)`` stacks don't swallow tests."""

    def __call__(self, *a, **k):
        if len(a) == 1 and not k and callable(a[0]) and not isinstance(a[0], type):
            return a[0]
        return self

    def __getattr__(self, name):
        return self

    def __iter__(self):
        return iter(())


class _Hypothesis(_Inert):
    """Top-level ``hypothesis`` stand-in: ``given`` skips the test."""

    @staticmethod
    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason=SKIP_REASON)(fn)

        return deco

    # ``@hypothesis.settings(...)`` / profile management are no-ops
    settings = _Inert()
    HealthCheck = _Inert()


hypothesis = _Hypothesis()
st = _Inert()
hnp = _Inert()
