"""Preempt-to-disk spill tier: restore by page reload must be invisible.

A preempted decoder normally pays replay — its prompt + emitted tokens
re-run through prefill to rebuild the KV cache. The spill tier instead
writes the victim's live page contents (and, for recurrent hybrids, the
slot's ssm/conv state) to host .npz files and restores by reloading pages
into a fresh exclusive reservation. What these tests pin:

* BIT-IDENTITY: with faults injected, greedy streams with the spill tier
  on equal the spill-off (replay) run AND the fault-free baseline — for
  llama (attention KV) and zamba2 (attention + recurrent state),
* the economics: on long contexts the spill run performs strictly fewer
  replay-recompute prefill forwards than the replay run,
* hygiene: every spill file is consumed by its restore (or dropped at
  retirement/drain) — zero orphans after every run, including drains
  that interrupt a spilled-but-never-restored request,
* the threshold gate: contexts below ``spill_threshold`` rows replay
  instead of spilling,
* ``SpillStore`` round-trips payloads exactly and accounts its traffic.
"""
import numpy as np
import pytest
from serve_helpers import make_requests, serve_once, tiny_model

from repro.serve import SpillStore


def _spill_kw(store=None, threshold=0):
    kw = dict(batch_slots=2, max_len=48, paged=True, page_size=4,
              num_pages=10, page_growth=True)
    if store is not None:
        kw.update(spill_store=store, spill_threshold=threshold)
    return kw


# ---------------------------------------------------------------------------
# SpillStore unit pins
# ---------------------------------------------------------------------------


def test_spill_store_roundtrip(tmp_path):
    store = SpillStore(tmp_path / "spill")
    payload = {"rows": np.int32(7),
               "pool.pages": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
               "state.ssm": np.ones((2, 5), np.float16)}
    store.spill(3, payload)
    assert store.has(3) and len(store.files()) == 1
    back = store.restore(3)
    assert set(back) == set(payload)
    for k in payload:
        assert np.array_equal(back[k], payload[k]), k
        assert back[k].dtype == np.asarray(payload[k]).dtype, k
    assert store.drop(3) and not store.has(3)
    assert not store.drop(3)  # second drop is a no-op
    s = store.stats()
    assert s["spills"] == 1 and s["restores"] == 1 and s["drops"] == 1
    assert s["orphans"] == 0 and s["bytes_written"] > 0


def test_spill_store_missing_restore_raises(tmp_path):
    store = SpillStore(tmp_path)
    with pytest.raises(FileNotFoundError):
        store.restore(42)


# ---------------------------------------------------------------------------
# Engine integration: bit-identity, economics, hygiene
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,n_layers", [
    ("llama32-1b", 2),
    ("zamba2-1.2b", 4),
])
def test_spill_restore_streams_bit_identical(arch, n_layers, tmp_path):
    """Injected pool faults, long generations: the spill run's streams
    equal the replay run's AND the clean baseline's, with fewer
    recompute forwards and zero leaks/orphans — both families."""
    cfg, model, params = tiny_model(arch, n_layers=n_layers)
    lens, gens = [10, 14], [16, 16]  # long tails: every victim is eligible
    inject = "oop@tick2,oop@tick6"

    base, _ = serve_once(model, params, make_requests(cfg, lens, gens),
                         **_spill_kw())
    replay, rstats = serve_once(model, params, make_requests(cfg, lens, gens),
                                inject=inject, **_spill_kw())
    store = SpillStore(tmp_path / arch)
    spill, sstats = serve_once(model, params, make_requests(cfg, lens, gens),
                               inject=inject, **_spill_kw(store))
    assert replay == base, (replay, base)
    assert spill == base, (spill, base)

    rres, sres = rstats["resilience"], sstats["resilience"]
    assert rres["preemptions"] >= 1 and sres["preemptions"] >= 1
    assert rres["spills"] == 0
    assert sres["spills"] >= 1, sres
    assert sres["spill_restores"] == sres["spills"]
    # the tier's point: page reload displaces replay recompute
    assert sres["recompute_forwards"] < rres["recompute_forwards"], (
        sres, rres)
    assert sres["spill_store"]["orphans"] == 0
    assert len(store.files()) == 0
    for stats in (rstats, sstats):
        assert stats["pages"]["leaked"] == 0
        assert any(e.startswith("preempt:") for e in stats["_events"])
    assert any(e.startswith("spill:") for e in sstats["_events"])
    assert any(e.startswith("restore:") for e in sstats["_events"])


def test_spill_threshold_gates_small_contexts(tmp_path):
    """Victims whose cache holds fewer rows than the threshold replay
    through prefill; the store never sees them."""
    cfg, model, params = tiny_model()
    store = SpillStore(tmp_path)
    out, stats = serve_once(
        model, params, make_requests(cfg, [10, 14], [16, 16]),
        inject="oop@tick2", **_spill_kw(store, threshold=10_000))
    base, _ = serve_once(model, params,
                         make_requests(cfg, [10, 14], [16, 16]),
                         **_spill_kw())
    assert out == base
    res = stats["resilience"]
    assert res["preemptions"] >= 1 and res["spills"] == 0, res
    assert res["replays"] >= 1
    assert stats["resilience"]["spill_store"]["orphans"] == 0


def test_drain_drops_unrestored_spill_files(tmp_path):
    """A request spilled and never restored before a drain must not
    orphan its file: the guard trips the moment the first spill lands,
    and the drain path drops the pending victim's file."""
    from repro.launch.serve import BatchedServer
    from repro.runtime.fault import PreemptionGuard

    cfg, model, params = tiny_model()
    store = SpillStore(tmp_path)
    server = BatchedServer(model, params, inject="oop@tick2",
                           guard=PreemptionGuard(), spill_store=store,
                           **_spill_kw())

    def on_token(r, tok):
        if server.spills >= 1:  # a victim's file now sits in the store
            server.guard.requested = True

    reqs = make_requests(cfg, [10, 14], [16, 16])
    stats = server.run(reqs, on_token=on_token)
    res = stats["resilience"]
    assert res["drained"] and res["spills"] >= 1, res
    # the spilled victim never got restored (guard fired first), yet the
    # drain consumed its file — nothing orphans, nothing leaks
    assert res["spill_restores"] < res["spills"], res
    assert res["spill_store"]["orphans"] == 0
    assert len(store.files()) == 0
    assert stats["pages"]["leaked"] == 0
