"""Distribution layer: sharding rules, ZeRO/FSDP specs, reduced dry-run via
subprocess (8 fake devices), multi-device train-step equivalence, elastic
checkpoint reshard, loop-aware HLO cost model."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding as shd


def _spec(path, shape, *, n_model=16, n_data=16):
    return shd.param_spec(path, shape, n_model=n_model, n_data=n_data)


def test_param_rules_tp():
    assert _spec("layers/attn/wq", (28, 1024, 2048)) == P(None, None, "model")
    assert _spec("layers/attn/wo", (28, 2048, 1024)) == P(None, "model", None)
    assert _spec("layers/mlp/w_up", (28, 1024, 3072)) == P(None, None, "model")
    assert _spec("layers/mlp/w_down", (28, 3072, 1024)) == P(None, "model", None)
    assert _spec("embed/table", (151936, 1024)) == P("model", None)
    # whisper: vocab 51865 not divisible by 16 -> falls back to d_model
    assert _spec("embed/table", (51865, 1024)) == P(None, "model")
    # norms replicated
    assert _spec("layers/norm1_scale", (28, 1024)) == P()
    assert _spec("layers/moe/router/w", (28, 2048, 64)) == P()


def test_param_rules_are_mesh_instance_scoped():
    """No module-global mesh dims: the same call site can evaluate rules for
    two different mesh shapes back to back and each answers for its own."""
    assert _spec("layers/attn/wq", (28, 1024, 2048), n_model=2) \
        == P(None, None, "model")
    # 2048 % 3 != 0 -> replicated for the 3-way mesh, still sharded for 16
    assert _spec("layers/attn/wq", (28, 1024, 2048), n_model=3) == P()
    assert _spec("layers/attn/wq", (28, 1024, 2048), n_model=16) \
        == P(None, None, "model")


def test_param_rules_ep_and_fsdp():
    # deepseek experts: EP over model + FSDP over data (>2^31 elements)
    spec = _spec("layers/moe/experts/w_up", (28, 64, 2048, 1408))
    assert spec == P(None, "model", "data", None)
    # small expert banks: EP only
    spec = _spec("layers/moe/experts/w_up", (2, 64, 64, 64))
    assert spec == P(None, "model", None, None)


def test_zero1_adds_data_axis_divisibly():
    base = _spec("layers/attn/wq", (28, 1024, 2048))
    z = shd.zero1_spec(base, (28, 1024, 2048), 16)
    assert z == P(None, "data", "model")
    # never duplicates data (FSDP params)
    fs = P(None, "model", "data", None)
    assert shd.zero1_spec(fs, (28, 64, 2048, 1408), 16) == fs
    # skips non-divisible dims (51865 % 16 != 0)
    z2 = shd.zero1_spec(P(None, "model"), (51865, 1024), 16)
    assert z2 == P(None, "model")


def test_cache_specs_kv_fallbacks():
    import jax

    cache = {
        "kv": jax.ShapeDtypeStruct((48, 2, 128, 32768, 8, 128), np.dtype("float32")),
        "len": jax.ShapeDtypeStruct((), np.dtype("int32")),
    }
    specs = shd.cache_specs_tree(cache, long_context=False, axes=("data",),
                                 n_dp=16, n_model=16)
    # kv=8 not divisible by 16 -> head_dim sharded instead
    assert specs["kv"] == P(None, None, ("data",), None, None, "model")
    specs = shd.cache_specs_tree(cache, long_context=True, axes=("data",),
                                 n_dp=16, n_model=16)
    assert specs["kv"] == P(None, None, None, "data", None, "model")


def _run(sub):
    return subprocess.run(
        [sys.executable, "-c", sub], capture_output=True, text=True,
        timeout=600, cwd="/root/repo",
        # JAX_PLATFORMS=cpu: skip the ~8-minute TPU-backend probe (the
        # container ships libtpu but has no TPU)
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
    )


def test_reduced_dryrun_subprocess():
    """Full dry-run machinery on a reduced cell with 8 fake devices."""
    sub = textwrap.dedent("""
        import json, pathlib, tempfile
        from repro.launch import dryrun
        out = pathlib.Path(tempfile.mkdtemp())
        rec = dryrun.run_cell("qwen3-0.6b", "train_4k", "multi", out,
                              reduced=True, reduced_devices=8)
        assert rec["status"] == "ok", rec
        assert rec["t_collective_s"] > 0
        assert rec["per_device_peak_bytes"] > 0
        print("OK", rec["bottleneck"])
    """)
    r = _run(sub)
    assert r.returncode == 0, r.stderr[-2500:]
    assert "OK" in r.stdout


def test_train_step_multidevice_matches_single():
    """The sharded train step must produce the same loss trajectory as the
    single-device run (GSPMD correctness end-to-end)."""
    sub = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.optim import adamw
        from repro.runtime import steps as S

        cfg = get_config("qwen3-0.6b").reduced()
        model = build_model(cfg)
        shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
        oc = adamw.AdamWConfig(peak_lr=1e-3, warmup=2, total_steps=10)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32),
                 "labels": rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)}

        losses = {}
        for mesh_shape in [(1, 1), (2, 4)]:
            mesh = make_mesh(mesh_shape, ("data", "model"))
            fn, (pshd, oshd, bshd), _ = S.build_train_step(model, mesh, oc, shape)
            with mesh:
                params = jax.jit(model.init, out_shardings=pshd)(jax.random.PRNGKey(0))
                opt = jax.jit(adamw.init_opt_state, out_shardings=oshd)(params)
                ls = []
                for _ in range(3):
                    b = {k: jax.device_put(v, bshd[k]) for k, v in batch.items()}
                    params, opt, m = fn(params, opt, b)
                    ls.append(float(m["loss"]))
            losses[mesh_shape] = ls
        a, b = losses[(1, 1)], losses[(2, 4)]
        # fp32 reduction-order drift (sharded logsumexp/psum on the CPU
        # backend) compounds over optimizer steps; real GSPMD bugs show up
        # as order-of-magnitude divergence, not percent-level drift
        assert np.allclose(a, b, rtol=5e-2, atol=2e-2), (a, b)
        print("OK", a, b)
    """)
    r = _run(sub)
    assert r.returncode == 0, r.stderr[-2500:]
    assert "OK" in r.stdout


def test_elastic_restore_across_meshes():
    """Checkpoint on a 2x4 mesh, restore onto 4x2 and 1x1 (elastic)."""
    sub = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.mesh import make_mesh

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        m1 = make_mesh((2, 4), ("data", "model"))
        s1 = {"w": NamedSharding(m1, P("data", "model"))}
        t1 = jax.device_put(tree, s1)
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(1, t1, blocking=True)

        m2 = make_mesh((4, 2), ("data", "model"))
        s2 = {"w": NamedSharding(m2, P("model", "data"))}
        _, t2 = mgr.restore(1, tree, s2)
        np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(tree["w"]))
        _, t3 = mgr.restore(1, tree)  # single device
        np.testing.assert_array_equal(np.asarray(t3["w"]), np.asarray(tree["w"]))
        print("OK")
    """)
    r = _run(sub)
    assert r.returncode == 0, r.stderr[-2500:]
    assert "OK" in r.stdout


def test_hlocost_loop_awareness():
    import jax
    import jax.numpy as jnp

    from repro.roofline import hlocost

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jnp.zeros((64, 64))
    w = jnp.zeros((6, 64, 64))
    c = jax.jit(f).lower(x, w).compile()
    cost = hlocost.analyze(c.as_text())
    want = 6 * 2 * 64**3
    assert abs(cost.dot_flops - want) / want < 0.01
    # XLA's own counter sees the body once — ours is ~6x larger
    xla_cost = c.cost_analysis()
    if isinstance(xla_cost, list):  # jax <= 0.4.x: one dict per computation
        xla_cost = xla_cost[0] if xla_cost else {}
    assert cost.dot_flops > 5 * float(xla_cost["flops"]) * 0.8
