"""Speculative decoding: drafts never change WHAT is decoded, only how
fast.

The contract under test (the speculative-serving tentpole):
* greedy speculation is BIT-IDENTICAL to non-speculative serving (which
  test_paged_kv pins against isolated decoding) for attention (llama) and
  hybrid recurrent (zamba2) families — even with an adversarially BAD
  drafter that gets every draft rejected (rollback-heavy: every round
  rewinds ``cache["len"]`` and, for zamba2, restores + recomputes
  recurrent state),
* rollback leaks nothing: target pool AND draft pool return to zero pages
  in use after every workload, including rejection-on-every-round,
* the rejection sampler is distribution-preserving: empirical acceptance
  matches ``sum(min(p, q))`` and the emitted-token marginal matches the
  target distribution exactly (Leviathan et al. 2023),
* sampled speculative streams stay a function of (seed, rid, model) —
  independent of batch slots, like PR 4 pinned for plain sampling,
* speculation composes with the prefix cache (COW guard + shared pages)
  and with chunked prefill,
* compile discipline: the k+1 verify chunk compiles exactly once;
  ``decode_step`` is never traced by the target in spec mode.
"""
import dataclasses

import jax
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    from hypothesis_stub import hypothesis, st

from repro.configs import get_config
from repro.core import QuantPolicy, restructure
from repro.launch.serve import BatchedServer, Request
from repro.models import build_model
from repro.spec.policy import accept_greedy, accept_speculative, shaped_probs


def _tiny_model(arch="llama32-1b", n_layers=2, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests(cfg, lens, gen, seed0=100):
    return [
        Request(i, np.random.default_rng(seed0 + i).integers(
            0, cfg.vocab_size, ln, dtype=np.int32), gen)
        for i, ln in enumerate(lens)
    ]


def _serve(model, params, reqs, **kw):
    server = BatchedServer(model, params, **kw)
    stats = server.run(reqs)
    stats["_events"] = server.events
    return {r.rid: r.out for r in reqs}, stats


# ---------------------------------------------------------------------------
# Differential pin: greedy speculation == non-speculative serving
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,n_layers", [("llama32-1b", 2),
                                           ("zamba2-1.2b", 4)])
def test_greedy_speculation_bit_identical(arch, n_layers):
    """Acceptance: with --speculate k (greedy), emitted tokens are
    bit-identical to non-speculative decode — INT4 packed drafter against
    the fp target, both cache families."""
    cfg, model, params = _tiny_model(arch, n_layers=n_layers)
    draft = restructure(
        params, QuantPolicy(bits=4, packed=True)
    ).as_executable(group=True)
    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=24)
    gen, lens = 6, [6, 11, 4, 9]
    base, bstats = _serve(model, params, _requests(cfg, lens, gen), **kw)
    spec, sstats = _serve(model, params, _requests(cfg, lens, gen),
                          speculate=3, draft_params=draft, **kw)
    assert spec == base, (arch, spec, base)
    sp = sstats["spec"]
    assert sp["rounds"] > 0 and sp["drafted"] > 0, sp
    # one target forward per round serves the whole batch: strictly fewer
    # target forwards than emitted tokens even before counting acceptance
    assert sp["target_forwards_per_token"] < 1.0, sp
    assert sp["verify_compiles"] == 1, sp
    # the target never runs a plain decode step in spec mode: every
    # decode-ready slot rides a verify wave
    assert "decode" not in sstats["_events"], sstats["_events"]
    assert "verify" in sstats["_events"]
    assert sstats["pages"]["leaked"] == 0, sstats["pages"]
    assert sp["draft_pages_leaked"] == 0, sp


@pytest.mark.parametrize("arch,n_layers", [("llama32-1b", 2),
                                           ("zamba2-1.2b", 4)])
def test_rollback_heavy_workload_identical_and_leak_free(arch, n_layers):
    """An adversarial drafter (different random weights — essentially zero
    agreement with the target) forces a rejection every round: greedy
    output must STILL be bit-identical, and both page pools must drain.
    This is the rollback stress: every round rewinds ``len`` and, for
    zamba2, restores + recomputes recurrent state."""
    cfg, model, params = _tiny_model(arch, n_layers=n_layers)
    bad_draft = model.init(jax.random.PRNGKey(99))
    kw = dict(batch_slots=2, max_len=32, paged=True, page_size=4,
              num_pages=24)
    gen, lens = 6, [6, 11, 4]
    base, _ = _serve(model, params, _requests(cfg, lens, gen), **kw)
    spec, stats = _serve(model, params, _requests(cfg, lens, gen),
                         speculate=3, draft_params=bad_draft, **kw)
    assert spec == base, (arch, spec, base)
    sp = stats["spec"]
    assert sp["acceptance_rate"] < 0.5, sp  # the drafter really is bad
    if arch == "zamba2-1.2b":
        assert sp["recompute_forwards"] > 0, sp  # recurrent rollback ran
    assert stats["pages"]["leaked"] == 0, stats["pages"]
    assert sp["draft_pages_leaked"] == 0, sp


def test_speculation_composes_with_prefix_cache():
    """Spec + prefix sharing: shared prompt pages are retained read-only
    while verify waves scatter into the tail — the COW guard must keep
    every written page exclusive, outputs identical, and dropping the
    prefix cache must return the pool to zero."""
    cfg, model, params = _tiny_model()
    draft = restructure(
        params, QuantPolicy(bits=4, packed=True)
    ).as_executable(group=True)
    rng = np.random.default_rng(17)
    common = rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
    prompts = [np.concatenate(
        [common, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)]
    ) for t in (3, 5, 2)]
    gen = 5

    def serve(**extra):
        reqs = [Request(i, p.copy(), gen) for i, p in enumerate(prompts)]
        server = BatchedServer(model, params, batch_slots=2, max_len=32,
                               paged=True, page_size=4, num_pages=32,
                               **extra)
        stats = server.run(reqs)
        return {r.rid: r.out for r in reqs}, stats, server

    base, _, _ = serve()
    spec, stats, server = serve(speculate=3, draft_params=draft,
                                prefix_cache=True)
    assert spec == base, (spec, base)
    assert stats["prefix"]["hits"] > 0, stats["prefix"]
    assert stats["pages"]["leaked"] == 0, stats["pages"]
    assert stats["spec"]["draft_pages_leaked"] == 0
    server.drop_prefix_cache()
    assert server.alloc.in_use == 0


def test_speculation_composes_with_chunked_prefill():
    """A long prompt fed in chunk waves while neighbours speculate —
    mid-prefill slots must stay frozen through verify waves."""
    cfg, model, params = _tiny_model()
    draft = restructure(
        params, QuantPolicy(bits=4, packed=True)
    ).as_executable(group=True)
    kw = dict(batch_slots=2, max_len=48, paged=True, page_size=8,
              num_pages=16, prefill_chunk=8)
    gen, lens = 6, [5, 33, 6]
    base, _ = _serve(model, params, _requests(cfg, lens, gen), **kw)
    reqs = _requests(cfg, lens, gen)
    server = BatchedServer(model, params, speculate=4, draft_params=draft,
                           **kw)
    stats = server.run(reqs)
    assert {r.rid: r.out for r in reqs} == base
    # interleave proof: a verify wave ran BETWEEN two prefill waves (the
    # long prompt must not stall its neighbour's speculative decode)
    ev = server.events
    first_p = ev.index("prefill")
    last_p = len(ev) - 1 - ev[::-1].index("prefill")
    assert "verify" in ev[first_p:last_p], ev
    assert stats["pages"]["leaked"] == 0
    assert stats["spec"]["draft_pages_leaked"] == 0


def test_sampled_speculation_independent_of_batch_slots():
    """Sampled spec streams must stay a function of (seed, rid, model):
    slot count changes scheduling and round composition, but every draw
    rides the request's own rng."""
    cfg, model, params = _tiny_model()
    draft = restructure(
        params, QuantPolicy(bits=4, packed=True)
    ).as_executable(group=True)

    def serve(slots):
        reqs = _requests(cfg, [5, 7, 4], gen=5)
        server = BatchedServer(model, params, batch_slots=slots, max_len=32,
                               paged=True, page_size=4, num_pages=36,
                               temperature=0.9, top_k=6, seed=11,
                               speculate=3, draft_params=draft)
        server.run(reqs)
        return {r.rid: r.out for r in reqs}

    assert serve(1) == serve(2) == serve(3)


def test_gen_too_short_to_draft_still_served():
    """Requests with max_new < 3 never draft (kk would be 0): they ride
    verify waves as single-token rows and the draft pool is never touched
    for them."""
    cfg, model, params = _tiny_model()
    draft = restructure(
        params, QuantPolicy(bits=4, packed=True)
    ).as_executable(group=True)
    kw = dict(batch_slots=2, max_len=24, paged=True, page_size=4,
              num_pages=16)
    for gen in (1, 2):
        base, _ = _serve(model, params, _requests(cfg, [5, 8], gen), **kw)
        spec, stats = _serve(model, params, _requests(cfg, [5, 8], gen),
                             speculate=3, draft_params=draft, **kw)
        assert spec == base, (gen, spec, base)
        assert stats["spec"]["drafted"] == 0, (gen, stats["spec"])
        assert stats["spec"]["draft_pages_leaked"] == 0


# ---------------------------------------------------------------------------
# Rejection-sampling policy: distribution preservation
# ---------------------------------------------------------------------------


def _rand_dist(rng, v):
    p = rng.random(v) ** 3 + 1e-9
    return p / p.sum()


def test_acceptance_rate_matches_min_p_q():
    """P(accept draft at position 0) must equal sum_x min(p(x), q(x)) —
    the defining identity of speculative rejection sampling."""
    rng = np.random.default_rng(0)
    v, trials = 8, 20000
    q, p = _rand_dist(rng, v), _rand_dist(rng, v)
    want = np.minimum(p, q).sum()
    hits = 0
    for _ in range(trials):
        d = int(rng.choice(v, p=q))
        m, _ = accept_speculative([d], q[None], np.stack([p, p]), rng)
        hits += m
    got = hits / trials
    assert abs(got - want) < 0.02, (got, want)


def test_emitted_token_marginal_matches_target():
    """The emitted first token (accepted draft OR residual resample) must
    be an EXACT sample from p, regardless of q: this is what makes
    speculation an optimization rather than an approximation."""
    rng = np.random.default_rng(1)
    v, trials = 6, 40000
    q, p = _rand_dist(rng, v), _rand_dist(rng, v)
    counts = np.zeros(v)
    for _ in range(trials):
        d = int(rng.choice(v, p=q))
        m, tok = accept_speculative([d], q[None], np.stack([p, p]), rng)
        counts[d if m >= 1 else tok] += 1
    emp = counts / trials
    np.testing.assert_allclose(emp, p, atol=0.015)


def test_greedy_accept_is_prefix_match():
    top = np.array([1, 0, 2])  # device-argmaxed target ids per position
    # all drafts match -> bonus token from the last position
    assert accept_greedy([1, 0], top) == (2, 2)
    # first mismatch stops acceptance and emits the target argmax there
    assert accept_greedy([1, 2], top) == (1, 0)
    assert accept_greedy([0, 0], top) == (0, 1)
    assert accept_greedy([], top[:1]) == (0, 1)


def test_shaped_probs_matches_sampler_shaping():
    """shaped_probs is the single source of truth sample_token draws from:
    greedy collapses to a one-hot, top-k zeroes the tail, top-p keeps the
    minimal nucleus."""
    logits = np.array([0.5, 3.0, 2.5, -1.0, 2.9])
    assert shaped_probs(logits).tolist() == [0, 1, 0, 0, 0]
    pk = shaped_probs(logits, temperature=1.0, top_k=3)
    assert (pk > 0).sum() == 3 and pk.argmax() == 1
    assert abs(pk.sum() - 1.0) < 1e-12
    pp = shaped_probs(logits, temperature=0.5, top_p=0.45)
    assert (pp > 0).sum() == 1 and pp[1] == 1.0


@hypothesis.given(
    st.integers(min_value=2, max_value=12),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=2 ** 31 - 1),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_accept_speculative_invariants(v, k, seed):
    """Structural invariants over random distributions: 0 <= m <= k, the
    emitted token is in the target support, q == p accepts everything and
    emits a p-sample, and greedy acceptance length equals the prefix-match
    length with the target argmaxes."""
    rng = np.random.default_rng(seed)
    q = np.stack([_rand_dist(rng, v) for _ in range(k)])
    p = np.stack([_rand_dist(rng, v) for _ in range(k + 1)])
    drafts = [int(rng.choice(v, p=q[j])) for j in range(k)]
    m, tok = accept_speculative(drafts, q, p, rng)
    assert 0 <= m <= k
    assert p[m][tok] > 0  # emitted token lies in the target support
    # identical distributions: everything accepted, bonus from p[k]
    m2, tok2 = accept_speculative(drafts, p[:k], p, rng)
    assert m2 == k and p[k][tok2] > 0
    # greedy: acceptance length == longest prefix matching target argmax
    top = np.argmax(p, axis=-1)
    gm, gtok = accept_greedy(drafts, top)
    want = 0
    for j, d in enumerate(drafts):
        if d != int(top[j]):
            break
        want += 1
    assert gm == want and gtok == int(top[gm])
