"""Weight-SQNR sweep: SplitQuantV2 vs baseline per-tensor linear quant on
real layer shapes of every assigned architecture (random init — the
baseline-vs-split DELTA is what transfers; init scale does not change it).
Generalizes the paper's single-model result across the 10-arch pool."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.split import split_error_stats


def _rep_weight(cfg, seed=0):
    """A representative big projection for the arch (outlier-salted)."""
    rng = np.random.default_rng(seed)
    # cap the sampled projection at ~8M elements: the split-vs-baseline
    # SQNR delta is size-stable and the full 150M-element nemotron matrix
    # takes minutes per arch on this 1-core container
    d = min(cfg.d_model, 2048)
    f = cfg.moe.d_expert if cfg.moe else cfg.d_ff
    w = rng.normal(0, 0.02, (d, min(f, 4 * d, 4096))).astype(np.float32)
    flat = w.reshape(-1)
    idx = rng.choice(flat.size, max(8, flat.size // 1000), replace=False)
    flat[idx] = rng.normal(0, 0.3, idx.size)
    return jnp.asarray(w)


def run() -> list[tuple[str, float, str]]:
    rows = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        w = _rep_weight(cfg)
        s = split_error_stats(w, 4)
        gain = float(s["sqnr_split_db"]) - float(s["sqnr_base_db"])
        rows.append((
            f"sqnr/{arch}_int4_gain_db", gain,
            f"base {float(s['sqnr_base_db']):.1f} dB -> "
            f"split {float(s['sqnr_split_db']):.1f} dB",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
