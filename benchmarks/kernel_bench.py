"""Kernel + engine micro-bench: wall time of the quantized-matmul execution
paths on CPU (interpret-mode Pallas is NOT representative of TPU — the point
here is (a) the paths run, (b) relative cost of the XLA-fused jnp variants,
and (c) weight-bytes accounting per path, which IS the TPU-relevant number
for decode (weight-bandwidth-bound).

Also emits ``BENCH_quant_engine.json`` at the repo root — a persistent
perf-trajectory record (tokens/s per engine, weight-bytes/token per path,
kernel wall times, launches/block) that this and future PRs append to
compare against.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlinear import (
    splitq_linear_3pass,
    splitq_linear_fused,
    splitq_linear_packed,
)
from repro.core.split import split_quantize, split_quantize_packed
from repro.obs.profile import timeit

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / (
    "BENCH_quant_engine.json"
)


def _time(f, *args, iters=5):
    # the shared benchmark clock (warmup + block_until_ready + median):
    # bench rows and autotune winners are measured the same way
    return timeit(f, *args, iters=iters, warmup=1)


def _serve_stats(engine: str, gen: int = 4,
                 prompt_lens: tuple[int, ...] = (8, 8),
                 shared_prefix: int = 0, speculate: int = 0,
                 batch_slots: int = 2, mesh_shape=None, **server_kw) -> dict:
    """Tiny end-to-end serve run per engine path (reduced llama, CPU).

    ``server_kw`` forwards to BatchedServer — e.g. ``paged=True,
    page_size=8, num_pages=...`` for the paged KV cache, or
    ``prefill_chunk=N`` for chunked prefill. ``shared_prefix`` prepends a
    common token prefix to every prompt (the production system-prompt
    pattern the prefix cache exists for). ``engine="fp"`` serves the
    unquantized weights; ``speculate=k`` adds a packed-INT4 drafter of the
    same weights (the self-speculation pairing: cheap quantized drafts,
    full-precision verification)."""
    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.engine import decode_weight_bytes
    from repro.kernels import ops
    from repro.launch.serve import BatchedServer, Request
    from repro.models import build_model

    cfg = get_config("llama32-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    draft_params = None
    if speculate:
        draft_params = restructure(
            params, QuantPolicy(bits=4, packed=True)
        ).as_executable(group=True)
    if engine != "fp":
        qm = restructure(params,
                         QuantPolicy(bits=4, packed=engine == "packed"))
        if engine == "fake":
            params = qm.materialize()
        else:
            params = qm.as_executable(group=True)
    common = np.random.default_rng(99).integers(
        0, cfg.vocab_size, shared_prefix, dtype=np.int32)
    mesh = None
    if mesh_shape is not None:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(mesh_shape, ("data", "model"))
    with ops.count_launches() as launches:
        server = BatchedServer(
            model, params, batch_slots=batch_slots,
            max_len=shared_prefix + max(prompt_lens) + gen + 8,
            speculate=speculate, draft_params=draft_params, mesh=mesh,
            **server_kw)
        reqs = [
            Request(i, np.concatenate([common, np.random.default_rng(i)
                    .integers(0, cfg.vocab_size, ln, dtype=np.int32)]), gen)
            for i, ln in enumerate(prompt_lens)
        ]
        stats = server.run(reqs)
    stats["weight_bytes_per_token"] = decode_weight_bytes(
        params, tie_embeddings=cfg.tie_embeddings)
    stats["quant_kernel_launches_traced"] = dict(launches)
    return stats


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    k, n, m = 1024, 1024, 16
    w = jnp.asarray(rng.normal(0, 0.02, (k, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    sq = split_quantize(w, 4)
    psq = split_quantize_packed(w, 4)

    rows = []
    t3 = _time(jax.jit(splitq_linear_3pass), x, sq)
    tf = _time(jax.jit(splitq_linear_fused), x, sq)
    tp = _time(jax.jit(splitq_linear_packed), x, psq)
    rows.append(("kernel/3pass_us", t3 * 1e6, "paper deployment: 3 matmuls"))
    rows.append(("kernel/fused_us", tf * 1e6, "fused sum-then-matmul"))
    rows.append(("kernel/packed_us", tp * 1e6, "6-bit packed layout"))
    # weight bytes per layer read at decode (the TPU-side figure of merit)
    bytes_3plane = float(3 * k * n // 2)
    bytes_packed = float(k * n // 2 + k * n // 4)
    rows.append(("kernel/bytes_3plane", bytes_3plane, "12 bit/weight (paper)"))
    rows.append(("kernel/bytes_packed", bytes_packed,
                 "6 bit/weight (ours) = 2x less HBM traffic at decode"))

    # engine end-to-end: fake-quant vs packed-kernel serving
    serve = {eng: _serve_stats(eng) for eng in ("fake", "packed")}
    for eng, st in serve.items():
        rows.append((f"engine/{eng}_tok_per_s", st["tok_per_s"],
                     f"{st['tokens']} tokens end-to-end (reduced llama)"))
        rows.append((f"engine/{eng}_weight_bytes_per_token",
                     float(st["weight_bytes_per_token"]),
                     "decode reads every weight once per token"))

    # slot-swap continuous batching: heterogeneous prompts, requests > slots
    # (multi-wave), packed engine — per-slot cache lengths + bucketing
    slotswap = _serve_stats("packed", prompt_lens=(4, 16, 23, 5))
    serve["slotswap_packed"] = slotswap
    rows.append(("engine/slotswap_tok_per_s", slotswap["tok_per_s"],
                 f"{slotswap['tokens']} tokens, prompts 4/16/23/5 through "
                 f"2 slots ({slotswap['prefill_waves']} prefill waves)"))
    rows.append(("engine/slotswap_decode_compiles",
                 float(slotswap["decode_compiles"]),
                 "decode must compile exactly once across slot swaps"))
    rows.append(("engine/slotswap_prefill_compiles",
                 float(slotswap["prefill_compiles"]),
                 f"pow2 buckets {slotswap['prefill_buckets']} "
                 "bound prefill recompiles"))

    # paged KV cache vs contiguous strips: same heterogeneous workload, one
    # long prompt chunk-prefilled, pool smaller than batch x max_len — the
    # memory win is MEASURED per request, not asserted
    paged = _serve_stats("packed", prompt_lens=(4, 16, 23, 5),
                         paged=True, page_size=8, num_pages=8,
                         prefill_chunk=8)
    serve["paged_packed"] = paged
    rows.append(("serve/paged_tok_per_s", paged["tok_per_s"],
                 f"{paged['tokens']} tokens, paged KV (page=8, pool=8 < "
                 f"dense 10), chunked prefill, "
                 f"{paged['prefill_waves']} waves"))
    rows.append(("serve/paged_decode_compiles",
                 float(paged["decode_compiles"]),
                 "paged decode must also compile exactly once"))
    rows.append(("serve/paged_pages_leaked",
                 float(paged["pages"]["leaked"]),
                 "pages still in use after all requests retired"))
    dense_res = slotswap["kv_bytes_reserved_per_request"]
    paged_res = paged["kv_bytes_reserved_per_request"]
    rows.append(("serve/paged_kv_bytes_per_request_mean",
                 float(paged_res["mean"]),
                 f"vs {dense_res['mean']} contiguous: each request reserves "
                 "only the pages its prompt+gen needs"))
    rows.append(("serve/paged_vs_contiguous_kv_reserve_ratio",
                 dense_res["mean"] / max(paged_res["mean"], 1),
                 "contiguous reserves batch x max_len regardless of length"))

    # observability: operational latency percentiles + the tick-time
    # breakdown, read from the paged run's telemetry (stats["obs"] is the
    # tracer/StepTimer view — the bench no longer reaches into server
    # internals for timing). CPU interpret wall times: trajectory, not
    # absolute truth.
    obs = paged["obs"]
    ttft = obs["requests"].get("ttft_s", {})
    tpot = obs["requests"].get("tpot_s", {})
    rows.append(("serve/obs_ttft_ms_p50", ttft.get("p50", 0.0) * 1e3,
                 f"time to first token, p95={ttft.get('p95', 0.0) * 1e3:.0f}"
                 f"ms over {obs['requests'].get('requests', 0)} requests"))
    rows.append(("serve/obs_tpot_ms_p50", tpot.get("p50", 0.0) * 1e3,
                 "steady-state ms per output token (paged run)"))
    for seam, st in sorted(obs["step_time"].items()):
        rows.append((f"serve/obs_tick_{seam}_ms_mean", st["mean_s"] * 1e3,
                     f"{st['count']} {seam} steps, "
                     f"{st['total_s'] * 1e3:.0f}ms total (block_until_ready"
                     " host wall)"))
    rows.append(("serve/obs_trace_dropped", float(obs["trace_dropped"]),
                 "timeline ring-buffer drops (must be 0 in smokes)"))

    # prefix sharing: the SAME common-system-prompt workload (24-token
    # shared prefix = 3 full pages, heterogeneous tails) with and without
    # the prefix cache — reserved pages and prefill tokens must drop
    paged_kw = dict(prompt_lens=(4, 16, 23, 5), shared_prefix=24,
                    paged=True, page_size=8, num_pages=16)
    unshared = _serve_stats("packed", **paged_kw)
    shared = _serve_stats("packed", **paged_kw, prefix_cache=True)
    serve["prefix_unshared"] = unshared
    serve["prefix_shared"] = shared
    rows.append(("serve/prefix_pages_allocated",
                 float(shared["pages"]["pages_allocated"]),
                 f"vs {unshared['pages']['pages_allocated']} unshared: "
                 "matched prefix pages are retained, not re-reserved"))
    rows.append(("serve/prefix_prefill_tokens",
                 float(shared["prefill_tokens"]),
                 f"vs {unshared['prefill_tokens']} unshared: the shared "
                 "prefix is not recomputed"))
    rows.append(("serve/prefix_hit_tokens",
                 float(shared["prefix"]["hit_tokens"]),
                 f"{shared['prefix']['hits']} hits, "
                 f"{shared['pages']['cow_copies']} copy-on-writes"))
    rows.append(("serve/prefix_kv_bytes_per_request_mean",
                 float(shared["kv_bytes_reserved_per_request"]["mean"]),
                 f"vs {unshared['kv_bytes_reserved_per_request']['mean']} "
                 "unshared (reservations net of shared pages)"))
    rows.append(("serve/prefix_pages_leaked",
                 float(shared["pages"]["leaked"]),
                 "pages neither owned nor cached after retirement"))

    # speculative decoding: fp target + packed INT4 drafter (the paper's
    # accuracy result cashed in as serving latency) vs the SAME workload
    # decoded plainly — accepted tokens per target forward is the win
    spec_kw = dict(gen=12, prompt_lens=(6, 14), paged=True, page_size=8,
                   num_pages=16)
    spec_base = _serve_stats("fp", **spec_kw)
    serve["spec_baseline_fp"] = spec_base
    for k in (2, 4):
        st = _serve_stats("fp", **spec_kw, speculate=k)
        serve[f"spec_k{k}_fp"] = st
        sp = st["spec"]
        rows.append((f"serve/spec_k{k}_emitted_per_target_forward",
                     sp["emitted_per_target_forward"],
                     f"{sp['emitted']} tokens / {sp['target_forwards']} "
                     f"target forwards (accept rate "
                     f"{sp['acceptance_rate']:.2f})"))
        rows.append((f"serve/spec_k{k}_target_forwards_per_token",
                     sp["target_forwards_per_token"],
                     f"vs 1 decode forward/token non-speculative (k={k})"))
        rows.append((f"serve/spec_k{k}_tok_per_s", st["tok_per_s"],
                     f"vs {spec_base['tok_per_s']:.1f} baseline (CPU "
                     "interpret wall time: not TPU-representative; the "
                     "forwards/token column is)"))
        rows.append((f"serve/spec_k{k}_pages_leaked",
                     float(st["pages"]["leaked"]
                           + sp["draft_pages_leaked"]),
                     "target + draft pools after rollback-heavy serving"))

    # serving under pressure: prompt-only reservation with on-demand page
    # growth vs full end-to-end reservation on the SAME 6-page pool — the
    # overcommit admits strictly more concurrent requests, repaid with
    # victim preemption + exact replay instead of admission stalls
    pressure_kw = dict(gen=8, prompt_lens=(8, 8, 8, 8), batch_slots=4,
                       paged=True, page_size=8, num_pages=6)
    full = _serve_stats("packed", **pressure_kw)
    grow = _serve_stats("packed", **pressure_kw, page_growth=True)
    serve["pressure_full"] = full
    serve["pressure_growth"] = grow
    fres, gres = full["resilience"], grow["resilience"]
    rows.append(("serve/pressure_full_peak_concurrency",
                 float(fres["peak_concurrency"]),
                 "full reservation: 2 pages/request up front on 6 pages"))
    rows.append(("serve/pressure_growth_peak_concurrency",
                 float(gres["peak_concurrency"]),
                 "prompt-only reservation + per-tick growth, same pool "
                 "(must admit strictly more than full reservation)"))
    rows.append(("serve/pressure_growth_preemptions",
                 float(gres["preemptions"]),
                 f"victims preempted to honor the overcommit "
                 f"({gres['replay_tokens']} tokens replayed exactly)"))
    rows.append(("serve/pressure_pages_leaked",
                 float(full["pages"]["leaked"] + grow["pages"]["leaked"]),
                 "both pools after pressure serving"))

    # mesh-sharded serving: 2 DP replicas split the admission queue and the
    # page pool into replica-local ranges, 2-way exact TP shards every
    # packed matmul's output dim. Bit-identity to the single-device streams
    # is pinned by tests/test_sharded_serving.py; the record here is the
    # per-replica KV memory bill and the compile discipline on the mesh path
    if jax.device_count() >= 4:
        sharded = _serve_stats("packed", gen=8,
                               prompt_lens=(12, 12, 12, 12), batch_slots=4,
                               shared_prefix=16, paged=True, page_size=8,
                               prefix_cache=True, mesh_shape=(2, 2))
        serve["shard_2x2_packed"] = sharded
        rows.append(("serve/shard_tok_per_s", sharded["tok_per_s"],
                     f"{sharded['tokens']} tokens on a 2x2 (data x model) "
                     "mesh, paged KV + prefix cache"))
        rows.append(("serve/shard_decode_compiles",
                     float(sharded["decode_compiles"]),
                     "sharded decode must also compile exactly once"))
        for r, kv in enumerate(
                sharded["mesh"]["kv_reserved_bytes_per_replica"]):
            rows.append((f"serve/shard_kv_reserved_bytes_replica{r}",
                         float(kv),
                         "peak KV pages reserved by this DP replica's "
                         "range of the pool (device-local bytes)"))
        rows.append(("serve/shard_pages_leaked",
                     float(sharded["pages"]["leaked"]),
                     "pool state after sharded serving"))
    else:
        rows.append(("serve/shard_skipped", 1.0,
                     "mesh rows need >= 4 devices (set XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8)"))

    # quantized-storage bytes/token: packed (6 bit/wt) vs 3-plane (12 bit/wt)
    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.models import build_model

    cfg = get_config("llama32-1b").reduced()
    params0 = build_model(cfg).init(jax.random.PRNGKey(0))

    # serving as a service: (a) the SLO loop retunes the chunked-prefill
    # budget against an inter-token target, (b) deficit round-robin
    # admission protects a light tenant queued behind a heavy one. Both
    # comparisons are measured on WARM servers (a first run compiles every
    # bucket) so interpret-mode compile time doesn't drown the scheduling
    # signal, and gaps are token-granular on_token wall stamps — the p99
    # inter-token gap is exactly the decoder stall a long prefill wave
    # causes, which the SLO controller exists to shrink.
    from repro.launch.serve import BatchedServer, Request
    from repro.serve import FairScheduler, SLOController

    model_s = build_model(cfg)

    def _gap_p99(stamps: dict) -> float:
        gaps = sorted(b - a for ts in stamps.values()
                      for a, b in zip(ts, ts[1:]))
        if not gaps:
            return 0.0
        return gaps[min(int(0.99 * len(gaps)), len(gaps) - 1)]

    def _mixed_reqs(base):
        # long-prompt arrivals mid-decode are the stall the SLO loop
        # exists for: the 256-token prefill must be COMPUTE-bound (O(S^2)
        # attention) so one fixed-chunk wave genuinely blocks the live
        # decoder — short prompts here are overhead-bound and show
        # nothing. Four long arrivals put several stall gaps in the
        # distribution, so p99 reads a stall, not a one-off host hiccup.
        rng = np.random.default_rng(5)
        lens_gens = ((6, 30), (256, 4)) * 4
        return [Request(base + i,
                        rng.integers(0, cfg.vocab_size, ln, dtype=np.int32),
                        gen)
                for i, (ln, gen) in enumerate(lens_gens)]

    def _timed_run(slo):
        server = BatchedServer(model_s, params0, batch_slots=2,
                               max_len=256 + 30 + 8, paged=True, page_size=8,
                               num_pages=80, prefill_chunk=256, slo=slo)
        server.run(_mixed_reqs(0))  # warm every bucket the run will touch
        stamps: dict[int, list[float]] = {}

        def on_token(r, tok):
            stamps.setdefault(r.rid, []).append(time.monotonic())

        stats = server.run(_mixed_reqs(1000), on_token=on_token)
        return stats, _gap_p99(stamps)

    fixed_stats, p99_fixed = _timed_run(None)
    slo_stats, p99_slo = _timed_run(
        SLOController(tpot_ms=0.05, chunk=256, chunk_min=8, chunk_max=256))
    rows.append(("serve/service_tpot_ms_p99_fixed", p99_fixed * 1e3,
                 "p99 inter-token gap, fixed 256-token prefill chunk: a "
                 "long prompt stalls the live decoder a whole wave"))
    rows.append(("serve/service_tpot_ms_p99_slo", p99_slo * 1e3,
                 f"vs {p99_fixed * 1e3:.0f}ms fixed: the SLO loop shrank "
                 f"the chunk to {slo_stats['slo']['chunk']} (must be "
                 "strictly lower)"))
    rows.append(("serve/service_slo_adjustments",
                 float(slo_stats["slo"]["adjustments"]),
                 "budget moves the controller made (must be > 0: the "
                 "loop demonstrably acts)"))
    serve["service_slo"] = {
        "fixed_tpot_p99_s": p99_fixed, "slo_tpot_p99_s": p99_slo,
        "final_chunk": slo_stats["slo"]["chunk"],
        "adjustments": slo_stats["slo"]["adjustments"],
        "history": slo_stats["slo"]["history"],
        "pages_leaked": (fixed_stats["pages"]["leaked"]
                         + slo_stats["pages"]["leaked"]),
    }

    def _fair_reqs(base):
        rng = np.random.default_rng(9)
        heavy = [Request(base + i,
                         rng.integers(0, cfg.vocab_size, 12, dtype=np.int32),
                         8) for i in range(8)]
        light = [Request(base + 100 + i,
                         rng.integers(0, cfg.vocab_size, 12, dtype=np.int32),
                         8) for i in range(2)]
        return heavy, light

    def _light_ttft(use_drr):
        """Median light-tenant TTFT when 2 light requests are submitted
        BEHIND 8 heavy ones: FIFO serves them last; DRR (weight 3)
        releases them in the first round."""
        server = BatchedServer(model_s, params0, batch_slots=2,
                               max_len=12 + 8 + 8, paged=True, page_size=8,
                               num_pages=24)

        def ordered(base):
            heavy, light = _fair_reqs(base)
            if not use_drr:
                return heavy + light  # submission order
            fair = FairScheduler(quantum=20.0)
            for r in heavy:
                fair.submit("heavy", r, weight=1.0)
            for r in light:
                fair.submit("light", r, weight=3.0)
            out = []
            while fair.backlog:
                out += fair.drain(1)
            return out

        server.run(ordered(0))       # warm
        server.run(ordered(2000))    # measured (fresh rids -> fresh traces)
        ttfts = sorted(d["ttft_s"] for d in server.tracer.requests()
                       if d["rid"] >= 2100)
        return ttfts[len(ttfts) // 2]

    ttft_fifo = _light_ttft(False)
    ttft_fair = _light_ttft(True)
    rows.append(("serve/service_ttft_ms_light_fifo", ttft_fifo * 1e3,
                 "light tenant's TTFT p50 queued behind 8 heavy requests, "
                 "plain FIFO admission"))
    rows.append(("serve/service_ttft_ms_light_fair", ttft_fair * 1e3,
                 f"vs {ttft_fifo * 1e3:.0f}ms FIFO: weighted DRR releases "
                 "the light tenant in round one (must be strictly lower)"))
    serve["service_fairness"] = {
        "light_ttft_s_fifo": ttft_fifo, "light_ttft_s_fair": ttft_fair,
    }
    q_packed = restructure(params0, QuantPolicy(bits=4, packed=True))
    q_planes = restructure(params0, QuantPolicy(bits=4, packed=False))
    b_packed = q_packed.size_bytes()["quantized"]
    b_planes = q_planes.size_bytes()["quantized"]
    rows.append(("engine/packed_vs_3plane_bytes_ratio", b_planes / b_packed,
                 "quantized weight bytes/token: must be ~2x (6 vs 12 bit)"))

    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "backend": jax.default_backend(),
        "problem": {"m": m, "k": k, "n": n, "bits": 4},
        "kernel_wall_us": {"3pass": t3 * 1e6, "fused": tf * 1e6,
                           "packed": tp * 1e6},
        "weight_bytes_per_layer": {
            "3plane": bytes_3plane, "packed": bytes_packed,
            "packed_vs_3plane_ratio": bytes_3plane / bytes_packed,
        },
        "serve": serve,
        "weight_bytes_per_token_quantized": {
            "packed": b_packed, "3plane": b_planes,
            "packed_vs_3plane_ratio": b_planes / b_packed,
        },
        "note": "CPU interpret-mode wall times are not TPU-representative; "
                "bytes/token accounting is.",
    }
    # append to the persistent perf trajectory (one entry per run)
    runs = []
    if BENCH_PATH.exists():
        try:
            prev = json.loads(BENCH_PATH.read_text())
            runs = prev.get("runs", [prev] if "serve" in prev else [])
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    BENCH_PATH.write_text(json.dumps({"schema": 2, "runs": runs}, indent=2))
    rows.append(("engine/bench_json_written", float(len(runs)),
                 f"{BENCH_PATH.name} ({len(runs)} run(s) recorded)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
