"""Kernel micro-bench: wall time of the quantized-matmul execution paths on
CPU (interpret-mode Pallas is NOT representative of TPU — the point here is
(a) the paths run, (b) the XLA-fused jnp variants' relative cost, and
(c) weight-bytes accounting per path, which IS the TPU-relevant number for
decode (weight-bandwidth-bound)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlinear import (
    splitq_linear_3pass,
    splitq_linear_fused,
    splitq_linear_packed,
)
from repro.core.split import split_quantize, split_quantize_packed


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    k, n, m = 1024, 1024, 16
    w = jnp.asarray(rng.normal(0, 0.02, (k, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    sq = split_quantize(w, 4)
    psq = split_quantize_packed(w, 4)

    rows = []
    t3 = _time(jax.jit(splitq_linear_3pass), x, sq)
    tf = _time(jax.jit(splitq_linear_fused), x, sq)
    tp = _time(jax.jit(splitq_linear_packed), x, psq)
    rows.append(("kernel/3pass_us", t3 * 1e6, "paper deployment: 3 matmuls"))
    rows.append(("kernel/fused_us", tf * 1e6, "fused sum-then-matmul"))
    rows.append(("kernel/packed_us", tp * 1e6, "6-bit packed layout"))
    # weight bytes per layer read at decode (the TPU-side figure of merit)
    rows.append(("kernel/bytes_3plane", float(3 * k * n // 2),
                 "12 bit/weight (paper)"))
    rows.append(("kernel/bytes_packed", float(k * n // 2 + k * n // 4),
                 "6 bit/weight (ours) = 2x less HBM traffic at decode"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
