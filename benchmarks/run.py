"""Benchmark harness — one module per paper table/figure + scale analyses.

Prints ``name,value,derived`` CSV. Modules:
  table1_accuracy  — paper Table 1 (INT2/4/8 × baseline/SplitQuantV2)
  timing           — paper §4.3 running time (CPU-only preprocessing)
  sqnr_sweep       — SplitQuantV2 gain across all 10 assigned archs
  k_ablation       — paper §5 k=2/3/dynamic ablation
  kernel_bench     — quantized-matmul path costs + bandwidth accounting
  roofline_table   — dry-run roofline terms per (arch × shape × mesh)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    import benchmarks.k_ablation as k_ablation
    import benchmarks.kernel_bench as kernel_bench
    import benchmarks.roofline_table as roofline_table
    import benchmarks.sqnr_sweep as sqnr_sweep
    import benchmarks.table1_accuracy as table1_accuracy
    import benchmarks.timing as timing

    mods = [timing, sqnr_sweep, k_ablation, kernel_bench, roofline_table,
            table1_accuracy]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,value,derived")
    failed = 0
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        if only and only != name:
            continue
        try:
            for row_name, value, derived in mod.run():
                print(f"{row_name},{value},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,-1,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
