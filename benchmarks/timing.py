"""Paper §4.3 reproduction: SplitQuantV2 preprocessing + quantization time,
CPU only, as a function of model size.

The paper: 1B params in 1m58s preprocessing + 8s quantization on an Apple
M4. We measure our histogram-Lloyd + split pipeline on this container's
CPU across model sizes and report per-parameter throughput so the 1B
extrapolation is explicit.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantPolicy, restructure
from repro.core.kmeans import kmeans1d
from repro.core.split import split_quantize


def _params_like(n_layers, d, ff, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {
            "attn": {
                "wq": jnp.asarray(rng.normal(0, 0.02, (n_layers, d, d)).astype(np.float32)),
                "wo": jnp.asarray(rng.normal(0, 0.02, (n_layers, d, d)).astype(np.float32)),
            },
            "mlp": {
                "w_up": jnp.asarray(rng.normal(0, 0.02, (n_layers, d, ff)).astype(np.float32)),
                "w_down": jnp.asarray(rng.normal(0, 0.02, (n_layers, ff, d)).astype(np.float32)),
            },
        }
    }


def run() -> list[tuple[str, float, str]]:
    rows = []
    # kernel-level: k-means throughput (the preprocessing hot loop)
    for n in (1 << 20, 1 << 23):
        x = jnp.asarray(np.random.default_rng(0).normal(size=n).astype(np.float32))
        kmeans1d(x).centroids.block_until_ready()  # compile
        t0 = time.time()
        kmeans1d(x).centroids.block_until_ready()
        dt = time.time() - t0
        rows.append((f"timing/kmeans1d_{n>>20}M_ms", dt * 1e3,
                     f"{n/dt/1e6:.0f} Mweights/s"))

    # whole-model: restructure+quantize throughput
    for (L, d, ff, tag) in ((4, 256, 1024, "8.4M"), (8, 512, 2048, "29M")):
        params = _params_like(L, d, ff)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        t0 = time.time()
        qm = restructure(params, QuantPolicy(bits=4))
        jax.block_until_ready(jax.tree.leaves(qm.qleaves))
        dt = time.time() - t0
        rate = n_params / dt
        extrap_1b = 1e9 / rate
        rows.append((f"timing/splitquant_{tag}_s", dt,
                     f"{rate/1e6:.1f} Mparam/s -> 1B in {extrap_1b:.0f}s "
                     f"(paper: 126s on Apple M4)"))

    # storage accounting: the paper's 3/8-of-FP32 INT4 claim
    params = _params_like(2, 256, 1024)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    qm = restructure(params, QuantPolicy(bits=4))
    frac = qm.size_bytes()["total"] / (n_params * 4)
    rows.append(("timing/int4_size_fraction", frac, "paper claims 3/8=0.375"))
    qmp = restructure(params, QuantPolicy(bits=4, packed=True))
    fracp = qmp.size_bytes()["total"] / (n_params * 4)
    rows.append(("timing/int4_packed_size_fraction", fracp,
                 "beyond-paper 6-bit layout: 3/16=0.1875"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
