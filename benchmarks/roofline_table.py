"""Render the roofline table from dry-run JSON records (deliverable g).

Reads experiments/dryrun/*.json and emits CSV rows + a markdown table for
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def run() -> list[tuple[str, float, str]]:
    rows = []
    for r in load_records():
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            rows.append((tag, -1.0, f"skipped: {r['reason'][:60]}"))
            continue
        if r["status"] != "ok":
            rows.append((tag, -2.0, f"ERROR {r.get('error','')[:60]}"))
            continue
        t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append((
            tag, t * 1e3,
            f"bottleneck={r['bottleneck']} comp={r['t_compute_s']*1e3:.1f}ms "
            f"mem={r['t_memory_s']*1e3:.1f}ms coll={r['t_collective_s']*1e3:.1f}ms "
            f"useful={r['useful_fraction']:.2f} mfu_bound={r.get('mfu_bound',0):.3f} "
            f"hbm/dev={r['per_device_peak_bytes']/2**30:.1f}GiB",
        ))
    return rows


def markdown(mesh: str = "single") -> str:
    recs = load_records()
    hdr = ("| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
           "bottleneck | useful | MFU-bound | HBM/dev GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in recs:
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped (full attention @500k) | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']*1e3:.1f} | {r['t_memory_s']*1e3:.1f} | "
            f"{r['t_collective_s']*1e3:.1f} | {r['bottleneck']} | "
            f"{r['useful_fraction']:.2f} | {r.get('mfu_bound',0):.3f} | "
            f"{r['per_device_peak_bytes']/2**30:.1f} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    print(markdown())
