"""Paper §5 ablation: k=2 vs k=3 (fixed) vs dynamic per-layer k.

Reports SQNR and storage fraction per option — the accuracy/size trade the
paper proposes as future work, implemented."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.quantize import compute_qparams, dequantize, quantize
from repro.core.split import choose_k, split_quantize, sqnr_db


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.02, (1024, 1024)).astype(np.float32)
    flat = w.reshape(-1)
    idx = rng.choice(flat.size, 1024, replace=False)
    flat[idx] = rng.normal(0, 0.3, 1024)
    w = jnp.asarray(w)

    rows = []
    qp = compute_qparams(w, 4)
    base = dequantize(quantize(w, qp), qp)
    rows.append(("k_ablation/k1_sqnr_db", float(sqnr_db(w, base)),
                 "baseline per-tensor, 4/32 size"))
    for k in (2, 3, 4):
        sq = split_quantize(w, 4, k=k)
        rows.append((
            f"k_ablation/k{k}_sqnr_db", float(sqnr_db(w, sq.dequantize())),
            f"{k} planes, {k}*4/32={k*4/32:.3f} size "
            f"(packed: {(4+2)/32:.3f})",
        ))
    kd = choose_k(w, 4, max_k=4)
    rows.append(("k_ablation/dynamic_k", float(kd),
                 "paper §5 dynamic-k heuristic choice"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
