"""Paper Table 1 reproduction: INT8/INT4/INT2 × {baseline, SplitQuantV2}.

The paper evaluates Llama-3.2-1B on ARC (1165 4-way MCQ). Offline we train
a small LM of the same family (llama32-1b reduced) on a synthetic Markov
language until it beats chance on a 4-way next-token MCQ task built from
held-out samples, then quantize with/without SplitQuantV2 and replay the
paper's table. The signature to reproduce (paper §4.2): INT8 ≈ FP for both;
INT4 baseline degraded, SplitQuantV2 recovers to ≈ FP; INT2 ≈ chance for
both. Also checks §4.1 (FP split preserves outputs exactly).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model, restructure
from repro.data.pipeline import DataLoader, SyntheticLM
from repro.models import build_model
from repro.optim import adamw


def train_small_lm(steps=260, batch=16, seq=64, seed=0):
    cfg = get_config("llama32-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw.init_opt_state(params)
    opt_cfg = adamw.AdamWConfig(peak_lr=2e-3, warmup=20, total_steps=steps)
    loader = DataLoader(SyntheticLM(cfg.vocab_size, seed=7), batch, seq, seed=seed)

    @jax.jit
    def step(params, opt, batch):
        (loss, m), g = jax.value_and_grad(model.train_loss, has_aux=True)(
            params, batch
        )
        params, opt, _ = adamw.apply_updates(opt_cfg, params, g, opt)
        return params, opt, loss

    for s in range(steps):
        b = loader.batch_at(s)
        params, opt, loss = step(params, opt,
                                 {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, model, params, float(loss)


def mcq_eval(cfg, model, params, n_problems=200, seed=123):
    """4-way MCQ: which continuation token is most likely after a context
    sampled from the training distribution? Distractors are random tokens.
    Accuracy = fraction where the model ranks the true token highest."""
    src = SyntheticLM(cfg.vocab_size, seed=7)
    rng = np.random.default_rng(seed)
    ctx_len = 32
    correct = 0

    @jax.jit
    def last_logits(params, tokens):
        from repro.models import transformer as tfm

        x = tfm.embed_tokens(cfg, params, tokens)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                               tokens.shape).astype(jnp.int32)
        h, _, _ = tfm.decoder_forward(cfg, params, x, pos)
        return tfm.logits_fn(cfg, params, h[:, -1:])

    seqs = np.stack([src.sample(np.random.default_rng((seed, i)), ctx_len + 1)
                     for i in range(n_problems)])
    logits = np.asarray(last_logits(params, jnp.asarray(seqs[:, :-1])))[:, 0]
    for i in range(n_problems):
        truth = seqs[i, -1]
        options = [truth] + list(
            rng.choice(cfg.vocab_size, 3, replace=False)
        )
        scores = [logits[i, o] for o in options]
        if int(np.argmax(scores)) == 0:
            correct += 1
    return correct / n_problems


def run() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.time()
    cfg, model, params, final_loss = train_small_lm()
    rows.append(("table1/train_loss", final_loss, "small-LM pretrain"))

    acc_fp = mcq_eval(cfg, model, params)
    rows.append(("table1/acc_fp", acc_fp, "original floating point"))

    # §4.1 functionality preservation: FP split == original, exactly
    qm = restructure(params, QuantPolicy(bits=4, min_size=256))
    from repro.core.split import split_fp

    ok = True
    for pth, qt in list(qm.qleaves.items())[:4]:
        w = None  # reconstruct original from planes is the cheap check
    # direct check on a weight: planes sum == original
    from repro.models import transformer as tfm
    w = np.asarray(params["layers"]["attn"]["wq"][0])
    planes, _ = split_fp(jnp.asarray(w))
    exact = bool((np.asarray(planes.sum(0)) == w).all())
    rows.append(("table1/fp_split_exact", float(exact), "paper §4.1"))

    for bits in (8, 4, 2):
        p_base = quantize_model(params, bits, split=False)
        p_split = quantize_model(params, bits, split=True)
        a_base = mcq_eval(cfg, model, p_base)
        a_split = mcq_eval(cfg, model, p_split)
        rows.append((f"table1/acc_int{bits}_baseline", a_base,
                     f"linear INT{bits}"))
        rows.append((f"table1/acc_int{bits}_splitquantv2", a_split,
                     f"SplitQuantV2 + linear INT{bits}"))
    rows.append(("table1/wall_s", time.time() - t0, "total bench time"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
