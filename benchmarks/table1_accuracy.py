"""Paper Table 1 reproduction: INT8/INT4/INT2 × {baseline, SplitQuantV2}.

The paper evaluates Llama-3.2-1B on ARC (1165 4-way MCQ). Offline we train
a small LM of the same family (llama32-1b reduced) on a synthetic Markov
language until it beats chance on a 4-way next-token MCQ task built from
held-out samples, then quantize with/without SplitQuantV2 and replay the
paper's table. The signature to reproduce (paper §4.2): INT8 ≈ FP for both;
INT4 baseline degraded, SplitQuantV2 recovers to ≈ FP; INT2 ≈ chance for
both. Also checks §4.1 (FP split preserves outputs exactly).

Thin wrapper: the train/eval machinery lives in :mod:`repro.eval` (the
serving-path evaluators and the CI quality gate use the same library);
this script keeps the historical ``table1/*`` row names.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import quantize_model
from repro.core.split import split_fp
from repro.eval import mcq_eval, train_small_lm


def run() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.time()
    cfg, model, params, final_loss = train_small_lm()
    rows.append(("table1/train_loss", final_loss, "small-LM pretrain"))

    acc_fp = mcq_eval(cfg, model, params)
    rows.append(("table1/acc_fp", acc_fp, "original floating point"))

    # §4.1 functionality preservation: FP split == original, exactly
    w = np.asarray(params["layers"]["attn"]["wq"][0])
    planes, _ = split_fp(jnp.asarray(w))
    exact = bool((np.asarray(planes.sum(0)) == w).all())
    rows.append(("table1/fp_split_exact", float(exact), "paper §4.1"))

    for bits in (8, 4, 2):
        p_base = quantize_model(params, bits, split=False)
        p_split = quantize_model(params, bits, split=True)
        a_base = mcq_eval(cfg, model, p_base)
        a_split = mcq_eval(cfg, model, p_split)
        rows.append((f"table1/acc_int{bits}_baseline", a_base,
                     f"linear INT{bits}"))
        rows.append((f"table1/acc_int{bits}_splitquantv2", a_split,
                     f"SplitQuantV2 + linear INT{bits}"))
    rows.append(("table1/wall_s", time.time() - t0, "total bench time"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
