"""Serve a SplitQuantV2-INT4 model with batched requests (continuous
batching): heterogeneous prompt lengths share fixed batch slots via the
per-slot KV cache lengths, with power-of-two prompt bucketing so slot
swaps don't recompile per prompt length.

The second run exercises the paged serving stack end to end: PAGED KV
cache (each request reserves only the pages its prompt + generation needs
from a shared pool), CHUNKED PREFILL (the long prompt is fed in 8-token
waves interleaved with its neighbours' decode steps), the PREFIX CACHE (a
24-token shared system prompt is prefilled once and its pages retained
read-only by every later request — cross-wave dedup serializes identical
prefixes arriving together), and seeded top-k sampling streamed through
``on_token``.

The third run turns on SPECULATIVE DECODING: the packed INT4 executable
drafts 4 tokens per request and the fp target verifies them in one
batched forward — greedy output is bit-identical to plain decoding, with
fewer target forwards than emitted tokens.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    rc = main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "8",
        "--batch", "4", "--prompt-lens", "4,16,23,9", "--gen", "8",
    ])
    # paged KV + chunked prefill + prefix cache + seeded top-k sampling:
    # the 40-token prompt is fed in 8-token waves between decode steps of
    # its neighbours, and the 24-token shared prefix (3 full pages of 8)
    # is prefilled once, then served from retained read-only pages
    rc = rc or main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "6",
        "--batch", "2", "--prompt-lens", "4,40,9", "--gen", "6",
        "--paged", "--page-size", "8", "--num-pages", "24",
        "--prefill-chunk", "8", "--shared-prefix", "24", "--prefix-cache",
        "--temperature", "0.7", "--top-k", "16", "--seed", "11",
    ])
    # speculative decoding: fp target + packed INT4 drafter of the same
    # weights; exits nonzero on zero acceptance, any leaked page (either
    # pool), or a verify recompile
    rc = rc or main([
        "--arch", "llama32-1b", "--bits", "0", "--requests", "4",
        "--batch", "2", "--prompt-lens", "6,14", "--gen", "10",
        "--paged", "--page-size", "8", "--num-pages", "16",
        "--speculate", "4", "--draft-engine", "packed",
    ])
    raise SystemExit(rc)
