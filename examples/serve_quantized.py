"""Serve a SplitQuantV2-INT4 model with batched requests (continuous
batching): heterogeneous prompt lengths share fixed batch slots via the
per-slot KV cache lengths, with power-of-two prompt bucketing so slot
swaps don't recompile per prompt length.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "8",
        "--batch", "4", "--prompt-lens", "4,16,23,9", "--gen", "8",
    ])
