"""Serve a SplitQuantV2-INT4 model with batched requests (continuous
batching): heterogeneous prompt lengths share fixed batch slots via the
per-slot KV cache lengths, with power-of-two prompt bucketing so slot
swaps don't recompile per prompt length.

The second run uses the PAGED KV cache: each request reserves only the
pages its prompt + generation needs from a shared pool (no batch x max_len
strips), a long prompt is prefilled in chunk waves interleaved with decode
steps, and tokens stream back through the ``on_token`` callback with
seeded top-k sampling.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    rc = main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "8",
        "--batch", "4", "--prompt-lens", "4,16,23,9", "--gen", "8",
    ])
    # paged KV + chunked prefill + seeded top-k sampling: the 40-token
    # prompt is fed in 8-token waves between decode steps of its neighbours
    rc = rc or main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "6",
        "--batch", "2", "--prompt-lens", "4,40,9", "--gen", "6",
        "--paged", "--page-size", "8", "--num-pages", "14",
        "--prefill-chunk", "8", "--temperature", "0.7", "--top-k", "16",
        "--seed", "11",
    ])
    raise SystemExit(rc)
