"""Serve a SplitQuantV2-INT4 model with batched requests (continuous
batching): heterogeneous prompt lengths share fixed batch slots via the
per-slot KV cache lengths, with power-of-two prompt bucketing so slot
swaps don't recompile per prompt length.

The second run exercises the paged serving stack end to end: PAGED KV
cache (each request reserves only the pages its prompt + generation needs
from a shared pool), CHUNKED PREFILL (the long prompt is fed in 8-token
waves interleaved with its neighbours' decode steps), the PREFIX CACHE (a
24-token shared system prompt is prefilled once and its pages retained
read-only by every later request — cross-wave dedup serializes identical
prefixes arriving together), and seeded top-k sampling streamed through
``on_token``.

The third run turns on SPECULATIVE DECODING: the packed INT4 executable
drafts 4 tokens per request and the fp target verifies them in one
batched forward — greedy output is bit-identical to plain decoding, with
fewer target forwards than emitted tokens.

The fourth run serves MESH-SHARDED: a ``--mesh DxM`` (data x model)
device mesh splits the batch slots and the KV page pool into D
replica-local ranges (the per-device page-pool stats print per replica)
while M-way exact tensor parallelism shards every packed matmul's output
dim — greedy streams stay bit-identical to the single-device path. Pass
``--mesh 2x2`` (with XLA_FLAGS=--xla_force_host_platform_device_count=8
on a CPU host) to see real data-parallel splitting; the default 1x1 mesh
exercises the same sharded code path on one device.

Every run exports its telemetry through ``repro.obs``: the paged run
writes a Prometheus metrics snapshot + the scheduler-timeline JSONL
(``--metrics-out`` / ``--trace-out``), and this script then reads the
metrics back through ``Registry`` parsing — the supported consumption
path (no reaching into server internals).

    PYTHONPATH=src python examples/serve_quantized.py [--mesh DxM]
"""
import pathlib
import sys
import tempfile

from repro.launch.serve import main
from repro.obs import parse_prometheus

if __name__ == "__main__":
    mesh = "1x1"
    if "--mesh" in sys.argv:
        mesh = sys.argv[sys.argv.index("--mesh") + 1]
    outdir = pathlib.Path(tempfile.mkdtemp(prefix="serve_obs_"))
    metrics = outdir / "metrics.prom"
    trace = outdir / "timeline.jsonl"
    rc = main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "8",
        "--batch", "4", "--prompt-lens", "4,16,23,9", "--gen", "8",
    ])
    # paged KV + chunked prefill + prefix cache + seeded top-k sampling:
    # the 40-token prompt is fed in 8-token waves between decode steps of
    # its neighbours, and the 24-token shared prefix (3 full pages of 8)
    # is prefilled once, then served from retained read-only pages
    rc = rc or main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "6",
        "--batch", "2", "--prompt-lens", "4,40,9", "--gen", "6",
        "--paged", "--page-size", "8", "--num-pages", "24",
        "--prefill-chunk", "8", "--shared-prefix", "24", "--prefix-cache",
        "--temperature", "0.7", "--top-k", "16", "--seed", "11",
        "--metrics-out", str(metrics), "--trace-out", str(trace),
    ])
    if rc == 0:
        # the exported snapshot is the public read path for run telemetry:
        # parse it back instead of poking at BatchedServer attributes
        snap = parse_prometheus(metrics.read_text())
        toks = sum(v for _, v in snap.get("serve_tokens_total", []))
        hits = sum(v for _, v in snap.get("prefix_hits", []))
        print(f"[obs] paged run telemetry: {int(toks)} tokens emitted, "
              f"{int(hits)} prefix hits -> {metrics}")
        print(f"[obs] scheduler timeline -> {trace}")
    # speculative decoding: fp target + packed INT4 drafter of the same
    # weights; exits nonzero on zero acceptance, any leaked page (either
    # pool), or a verify recompile
    rc = rc or main([
        "--arch", "llama32-1b", "--bits", "0", "--requests", "4",
        "--batch", "2", "--prompt-lens", "6,14", "--gen", "10",
        "--paged", "--page-size", "8", "--num-pages", "16",
        "--speculate", "4", "--draft-engine", "packed",
    ])
    # mesh-sharded serving: D data replicas split the admission queue and
    # the page pool (per-replica stats print after the run), M-way exact
    # TP shards the packed matmuls; greedy streams match single-device
    rc = rc or main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "8",
        "--batch", "4", "--prompt-len", "12", "--gen", "8",
        "--paged", "--page-size", "8", "--shared-prefix", "16",
        "--prefix-cache", "--speculate", "3", "--mesh", mesh,
    ])
    raise SystemExit(rc)
