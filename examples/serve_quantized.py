"""Serve a SplitQuantV2-INT4 model with batched requests (continuous
batching-lite): the serving-side example.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main([
        "--arch", "llama32-1b", "--bits", "4", "--requests", "8",
        "--batch", "4", "--prompt-len", "16", "--gen", "8",
    ])
