"""Apply SplitQuantV2 to ANY assigned architecture (--arch) and report
per-layer-class SQNR + storage. Demonstrates the whole-model restructuring
pass (policy exclusions included) on the real config shapes at reduced
depth so it runs on CPU in seconds.

    PYTHONPATH=src python examples/quantize_llm.py --arch deepseek-moe-16b --bits 4

``--quant-report out.json`` additionally writes the ranked per-layer
quality report (baseline-vs-split SQNR, clipping, outlier mass — worst
layer first; see :class:`repro.core.QuantReport`).
"""
import argparse

import jax

from repro.configs import ALL_ARCHS, get_config
from repro.core import (
    QuantPolicy,
    build_quant_report,
    restructure,
    sqnr_db,
)
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b", choices=list(ALL_ARCHS))
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--quant-report", default="",
                    help="write the ranked per-layer QuantReport JSON "
                         "artifact to this path")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    policy = QuantPolicy(bits=args.bits, packed=args.packed, min_size=1024)
    qm = restructure(params, policy)
    eff = qm.materialize()

    print(f"{args.arch} (reduced): {n_params/1e6:.2f}M params, "
          f"{len(qm.qleaves)} tensors split+quantized, "
          f"{len(qm.passthrough)} excluded by policy")
    # per-leaf SQNR
    from repro.core.apply import _path_str
    flat_e, _ = jax.tree_util.tree_flatten_with_path(eff)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    print(f"{'tensor':42s} {'SQNR dB':>8s}")
    for (pa, orig), (_, new) in zip(flat_p, flat_e):
        name = _path_str(pa)
        if name in qm.qleaves:
            print(f"{name:42s} {float(sqnr_db(orig, new)):8.1f}")
    sz = qm.size_bytes()
    print(f"storage: quantized {sz['quantized']} B + passthrough "
          f"{sz['passthrough']} B = {sz['total']/(n_params*4):.3f} of fp32")

    if args.quant_report:
        rep = build_quant_report(params, policy)
        rep.save(args.quant_report)
        s = rep.summary()
        print(f"quant report -> {args.quant_report}: {s['layers']} layers, "
              f"mean SQNR gain {s['mean_sqnr_gain_db']:+.2f} dB, worst "
              f"layer {s['worst_layer']} "
              f"({s['worst_layer_sqnr_split_db']:.2f} dB after split)")
        print("worst 5 layers (post-split SQNR ascending):")
        for r in rep.worst(5):
            print(f"  {r.layer:42s} base {r.sqnr_base_db:6.2f} dB -> "
                  f"split {r.sqnr_split_db:6.2f} dB  "
                  f"(clip {r.clip_frac_base:.4f}, outliers "
                  f"{r.outlier_frac:.3f})")


if __name__ == "__main__":
    main()
