"""Apply SplitQuantV2 to ANY assigned architecture (--arch) and report
per-layer-class SQNR + storage. Demonstrates the whole-model restructuring
pass (policy exclusions included) on the real config shapes at reduced
depth so it runs on CPU in seconds.

    PYTHONPATH=src python examples/quantize_llm.py --arch deepseek-moe-16b --bits 4
"""
import argparse

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core import QuantPolicy, restructure, sqnr_db
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b", choices=list(ALL_ARCHS))
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--packed", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    qm = restructure(params, QuantPolicy(bits=args.bits, packed=args.packed,
                                         min_size=1024))
    eff = qm.materialize()

    print(f"{args.arch} (reduced): {n_params/1e6:.2f}M params, "
          f"{len(qm.qleaves)} tensors split+quantized, "
          f"{len(qm.passthrough)} excluded by policy")
    flat_o = dict(jax.tree_util.tree_flatten_with_path(params)[0])
    for path, orig in list(flat_o.items()):
        name = "/".join(str(getattr(p, "key", "")) for p in path)
        if name in qm.qleaves:
            w_hat = None
    # per-leaf SQNR
    from repro.core.apply import _path_str
    flat_e, _ = jax.tree_util.tree_flatten_with_path(eff)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    print(f"{'tensor':42s} {'SQNR dB':>8s}")
    for (pa, orig), (_, new) in zip(flat_p, flat_e):
        name = _path_str(pa)
        if name in qm.qleaves:
            print(f"{name:42s} {float(sqnr_db(orig, new)):8.1f}")
    sz = qm.size_bytes()
    print(f"storage: quantized {sz['quantized']} B + passthrough "
          f"{sz['passthrough']} B = {sz['total']/(n_params*4):.3f} of fp32")


if __name__ == "__main__":
    main()
