"""End-to-end driver: train a small LM a few hundred steps, then reproduce
the paper's Table-1 signature on it.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 260]

Pipeline: synthetic-language pretrain (repro.eval.train) → SplitQuantV2
restructuring → INT8/4/2 eval with and without the split → table
printout. Expected: INT8 flat, INT4 recovered by SplitQuantV2, INT2 dead
(paper §4.2).
"""
import argparse

from repro.core import quantize_model
from repro.eval import mcq_eval, train_small_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=260)
    args = ap.parse_args()

    cfg, model, params, loss = train_small_lm(steps=args.steps)
    print(f"trained llama32-1b (reduced) {args.steps} steps; loss={loss:.3f}")
    acc_fp = mcq_eval(cfg, model, params)
    print(f"\n{'':16s}{'baseline':>10s}{'splitquantv2':>14s}")
    print(f"{'original':16s}{acc_fp:10.3f}{acc_fp:14.3f}")

    for bits in (8, 4, 2):
        a_b = mcq_eval(cfg, model, quantize_model(params, bits, split=False))
        a_s = mcq_eval(cfg, model, quantize_model(params, bits, split=True))
        print(f"{'INT%d' % bits:16s}{a_b:10.3f}{a_s:14.3f}")
    print("\n(expect: INT8 ≈ original for both; INT4 baseline degraded and "
          "SplitQuantV2 recovered; INT2 ≈ chance=0.25 for both)")


if __name__ == "__main__":
    main()
