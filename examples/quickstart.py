"""Quickstart: SplitQuantV2 in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Splits a weight matrix with k-means (k=3), verifies exact FP function
preservation (paper §4.1), quantizes to INT4 with and without the split,
and prints the resolution gain (paper §4.2 at the weight level).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    split_error_stats, split_fp, split_quantize, split_quantize_packed,
)

rng = np.random.default_rng(0)
w = rng.normal(0, 0.02, (512, 512)).astype(np.float32)
w.reshape(-1)[rng.choice(w.size, 500, replace=False)] = rng.normal(0, 0.3, 500)
w = jnp.asarray(w)
x = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))

# 1. split into lower/middle/upper cluster layers — function preserved
planes, info = split_fp(w, k=3)
assert (np.asarray(planes.sum(0)) == np.asarray(w)).all()
y_orig = x @ w
y_split = sum(x @ planes[c] for c in range(3))
print("max |y_split - y_orig| =", float(jnp.abs(y_split - y_orig).max()))
print("cluster sizes:", np.asarray(info.counts))

# 2. INT4: baseline linear quant vs SplitQuantV2
stats = split_error_stats(w, bits=4)
print(f"INT4 baseline SQNR   : {float(stats['sqnr_base_db']):.1f} dB")
print(f"INT4 SplitQuantV2    : {float(stats['sqnr_split_db']):.1f} dB")

# 3. storage: paper 3-plane (12 bit/wt) vs beyond-paper packed (6 bit/wt)
sq = split_quantize(w, 4)
psq = split_quantize_packed(w, 4)
nbytes = lambda t: sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t))
print(f"fp32 {w.size*4} B | 3-plane {nbytes(sq)} B | packed {nbytes(psq)} B")
assert (np.asarray(sq.dequantize()) == np.asarray(psq.dequantize())).all()
print("packed layout is bit-identical to the paper's 3-plane layout")
