"""Fused quantize+pack elementwise Pallas kernel.

Used by the preprocessing pass after SplitQuantV2 clustering: one pass over
the weights computes codes = clip(round(S·w) + Z) and packs them ``per`` per
byte along the minor axis — HBM traffic is read-once/write-b/8, instead of a
quantize pass + a separate pack pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_pack_kernel(w_ref, s_ref, z_ref, o_ref, *, bits: int):
    per = 8 // bits
    s = s_ref[0, 0]
    z = z_ref[0, 0]
    q = jnp.round(s * w_ref[...].astype(jnp.float32)) + z
    q = jnp.clip(q, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1).astype(jnp.int32)
    r, c = q.shape
    if per == 1:
        o_ref[...] = q.astype(jnp.int8)
        return
    u = (q & ((1 << bits) - 1)).astype(jnp.uint8)
    u = u.reshape(r, c // per, per)
    packed = u[..., 0]
    for i in range(1, per):
        packed = packed | (u[..., i] << jnp.uint8(i * bits))
    o_ref[...] = packed.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "br", "bc", "interpret"))
def quantize_pack_pallas(
    w: jax.Array,      # (R, C)
    scale: jax.Array,  # ()
    zero: jax.Array,   # ()
    bits: int,
    br: int = 256,
    bc: int = 512,
    interpret: bool = False,
) -> jax.Array:
    per = 8 // bits
    r, c = w.shape
    assert r % br == 0 and c % bc == 0 and bc % per == 0
    s = scale.reshape(1, 1).astype(jnp.float32)
    z = zero.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_quantize_pack_kernel, bits=bits),
        grid=(r // br, c // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, bc // per), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c // per), jnp.int8),
        interpret=interpret,
    )(w, s, z)
