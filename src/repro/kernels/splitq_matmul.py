"""Fused SplitQuantV2 matmul — the paper's 3 layers in ONE kernel pass.

The paper deploys a split layer as 3 real low-bit layers (its §5 limitation:
3× matmuls, 3× activation reads). On TPU we fuse: for each (bm, bn, bk)
tile, all k packed planes are unpacked + dequantized + **summed in VMEM**,
then a single MXU matmul consumes the sum. Per tile this is 3 cheap VPU
unpack/dequant passes + 1 MXU matmul instead of 3 MXU matmuls + 3 HBM
activation streams.

Correctness relies on the split invariant (tested in test_split_equiv):
plane supports are disjoint and off-support entries dequantize to exactly
0.0, so the VMEM sum reconstructs Ŵ bit-exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant_matmul import _unpack_tile


def _splitq_kernel(
    x_ref, planes_ref, s_ref, z_ref, o_ref, acc_ref, *, bits: int, nk: int, k: int
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = None
    for c in range(k):  # static unroll: k == 3
        q = _unpack_tile(planes_ref[c], bits).astype(jnp.float32)
        wc = (q - z_ref[c, 0]) * s_ref[c, 0]  # s_ref holds reciprocals
        w = wc if w is None else w + wc
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bits", "bm", "bn", "bk", "interpret")
)
def splitq_matmul_pallas(
    x: jax.Array,       # (M, K)
    planes: jax.Array,  # (k, K, N//per) int8 carriers
    scales: jax.Array,  # (k,)
    zeros: jax.Array,   # (k,)
    bits: int,
    bm: int = 128,
    bn: int = 512,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    per = 8 // bits
    kclusters = planes.shape[0]
    m, kdim = x.shape
    n = planes.shape[2] * per
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    nk = kdim // bk
    inv_s = (1.0 / scales).reshape(kclusters, 1).astype(jnp.float32)
    z = zeros.reshape(kclusters, 1).astype(jnp.float32)
    grid = (m // bm, n // bn, nk)
    kwargs = {}
    if not interpret:
        # (M, N) parallel + K arbitrary => Mosaic double-buffers the packed
        # plane DMA against the MXU sweep (decode is weight-BW-bound).
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    return pl.pallas_call(
        functools.partial(_splitq_kernel, bits=bits, nk=nk, k=kclusters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec(
                (kclusters, bk, bn // per), lambda i, j, kk: (0, kk, j)
            ),
            pl.BlockSpec((kclusters, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((kclusters, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, planes, inv_s, z)
