"""Beyond-paper (b+2)-bit packed SplitQuantV2 matmul kernel.

Storage: one b-bit code + one 2-bit cluster id per weight + a k-entry
(1/S, Z) LUT. For INT4 that is 6 bits/weight — **half** the paper's 3-plane
footprint (12 bits) and half its HBM weight traffic, with bit-identical
dequantized values. Decode-time matmuls are weight-bandwidth-bound, so this
directly converts the paper's §5 limitation into a ~2× bandwidth win.

In-kernel dequant: the 3-way LUT gather is realized as a chain of
vectorized selects (TPU has no VMEM gather; k is static and tiny, so
2 selects per element on the VPU beat any gather emulation).

Grouped projections: the same kernel serves a fused QKV (or gate+up)
launch. Members are concatenated along N with each member's span padded to
a multiple of the block width, so an output block j belongs to exactly one
member; ``group_starts`` (static, in units of bn) tells the kernel which
member's k LUT rows to use. Cluster ids stay 2 bits — grouping costs zero
extra weight bandwidth.

Pipelining: grid dims (M, N) are declared ``parallel`` and the K sweep
``arbitrary`` so Mosaic double-buffers the packed weight DMA against the
MXU work (weight HBM streaming is the decode bottleneck this kernel
exists to hide).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant_matmul import _unpack_tile


def _lut_select(cid: jax.Array, lut_ref, k: int) -> jax.Array:
    """out[i] = lut[cid[i]] via select chain; cid int32, lut_ref (k, 1)."""
    out = jnp.full(cid.shape, lut_ref[0, 0], jnp.float32)
    for c in range(1, k):
        out = jnp.where(cid == c, lut_ref[c, 0], out)
    return out


def _lut_select_grouped(cid, g, lut_ref, k: int, groups: int) -> jax.Array:
    """out[i] = lut[g*k + cid[i]] with g a traced scalar member index.

    The member's k LUT entries are picked with (groups-1)*k SCALAR selects
    (register ops, once per tile); the per-element vector work stays at the
    same k-1 selects as the ungrouped path."""
    vals = []
    for c in range(k):
        v = lut_ref[c, 0]
        for gg in range(1, groups):
            v = jnp.where(g == gg, lut_ref[gg * k + c, 0], v)
        vals.append(v)
    out = jnp.full(cid.shape, vals[0], jnp.float32)
    for c in range(1, k):
        out = jnp.where(cid == c, vals[c], out)
    return out


def _splitq_packed_kernel(
    x_ref, codes_ref, cids_ref, s_ref, z_ref, o_ref, acc_ref,
    *, bits: int, nk: int, k: int, group_starts: tuple[int, ...],
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _unpack_tile(codes_ref[...], bits).astype(jnp.float32)
    cid = _unpack_tile(cids_ref[...], 2) & 0x3  # int32, 2-bit ids unsigned
    if len(group_starts) <= 1:
        inv_s = _lut_select(cid, s_ref, k)
        z = _lut_select(cid, z_ref, k)
    else:
        j = pl.program_id(1)
        g = jnp.int32(0)
        for b in group_starts[1:]:
            g = g + (j >= b).astype(jnp.int32)
        inv_s = _lut_select_grouped(cid, g, s_ref, k, len(group_starts))
        z = _lut_select_grouped(cid, g, z_ref, k, len(group_starts))
    w = (q - z) * inv_s
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "bm", "bn", "bk", "group_starts", "interpret"),
)
def splitq_packed_matmul_pallas(
    x: jax.Array,      # (M, K)
    codes: jax.Array,  # (K, N//per) int8 carriers
    cids: jax.Array,   # (K, N//4) packed 2-bit ids
    scales: jax.Array, # (G*k,)  member-major LUT (G==1 for a single tensor)
    zeros: jax.Array,  # (G*k,)
    bits: int,
    bm: int = 128,
    bn: int = 512,
    bk: int = 128,
    group_starts: tuple[int, ...] = (),
    interpret: bool = False,
) -> jax.Array:
    per = 8 // bits
    groups = max(1, len(group_starts))
    k = scales.shape[0] // groups
    m, kdim = x.shape
    n = codes.shape[1] * per
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    assert bn % 4 == 0
    nk = kdim // bk
    inv_s = (1.0 / scales).reshape(groups * k, 1).astype(jnp.float32)
    z = zeros.reshape(groups * k, 1).astype(jnp.float32)
    grid = (m // bm, n // bn, nk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    return pl.pallas_call(
        functools.partial(
            _splitq_packed_kernel, bits=bits, nk=nk, k=k,
            group_starts=group_starts,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // per), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn // 4), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((groups * k, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((groups * k, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, codes, cids, inv_s, z)
