"""Beyond-paper (b+2)-bit packed SplitQuantV2 matmul kernel.

Storage: one b-bit code + one 2-bit cluster id per weight + a k-entry
(1/S, Z) LUT. For INT4 that is 6 bits/weight — **half** the paper's 3-plane
footprint (12 bits) and half its HBM weight traffic, with bit-identical
dequantized values. Decode-time matmuls are weight-bandwidth-bound, so this
directly converts the paper's §5 limitation into a ~2× bandwidth win.

In-kernel dequant: the 3-way LUT gather is realized as a chain of
vectorized selects (TPU has no VMEM gather; k is static and tiny, so
2 selects per element on the VPU beat any gather emulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant_matmul import _unpack_tile


def _lut_select(cid: jax.Array, lut_ref, k: int) -> jax.Array:
    """out[i] = lut[cid[i]] via select chain; cid int32, lut_ref (k, 1)."""
    out = jnp.full(cid.shape, lut_ref[0, 0], jnp.float32)
    for c in range(1, k):
        out = jnp.where(cid == c, lut_ref[c, 0], out)
    return out


def _splitq_packed_kernel(
    x_ref, codes_ref, cids_ref, s_ref, z_ref, o_ref, acc_ref,
    *, bits: int, nk: int, k: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _unpack_tile(codes_ref[...], bits).astype(jnp.float32)
    cid = _unpack_tile(cids_ref[...], 2) & 0x3  # int32, 2-bit ids unsigned
    inv_s = _lut_select(cid, s_ref, k)
    z = _lut_select(cid, z_ref, k)
    w = (q - z) * inv_s
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bits", "bm", "bn", "bk", "interpret")
)
def splitq_packed_matmul_pallas(
    x: jax.Array,      # (M, K)
    codes: jax.Array,  # (K, N//per) int8 carriers
    cids: jax.Array,   # (K, N//4) packed 2-bit ids
    scales: jax.Array, # (k,)
    zeros: jax.Array,  # (k,)
    bits: int,
    bm: int = 128,
    bn: int = 512,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    per = 8 // bits
    k = scales.shape[0]
    m, kdim = x.shape
    n = codes.shape[1] * per
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    assert bn % 4 == 0
    nk = kdim // bk
    inv_s = (1.0 / scales).reshape(k, 1).astype(jnp.float32)
    z = zeros.reshape(k, 1).astype(jnp.float32)
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_splitq_packed_kernel, bits=bits, nk=nk, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // per), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn // 4), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((k, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((k, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, cids, inv_s, z)
