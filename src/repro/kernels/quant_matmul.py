"""W4A16/W8A16/W2A16 dequant-in-VMEM matmul Pallas kernel.

The baseline deployment kernel for linearly-quantized weights: packed int-b
codes are streamed HBM→VMEM, unpacked + dequantized tile-by-tile in VMEM,
and fed to the MXU as fp32/bf16 with an fp32 VMEM accumulator.

Tiling: grid (M/bm, N/bn, K/bk), K innermost (sequential on TPU) so the
(bm, bn) accumulator lives in a VMEM scratch across the K sweep. Block
shapes default to MXU-aligned (128, 128, 512); the packed weight tile is
(bk, bn/per) int8 — e.g. (128, 256) for int4 at bn=512, keeping the minor
dim a multiple of 128 as the int8 VREG layout wants.

Weight layout: codes packed along the last (N) axis, little-nibble-first —
byte j of row i holds columns per*j .. per*j+per-1 (matches
``repro.core.quantize.pack_codes``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_tile(packed: jax.Array, bits: int) -> jax.Array:
    """(r, c) int8 carriers -> (r, c*per) int32 sign-extended codes."""
    if bits == 8:
        return packed.astype(jnp.int32)
    per = 8 // bits
    mask = (1 << bits) - 1
    u = packed.astype(jnp.uint8)
    parts = []
    for i in range(per):
        v = ((u >> jnp.uint8(i * bits)) & jnp.uint8(mask)).astype(jnp.int32)
        v = jnp.where(v >= (1 << (bits - 1)), v - (1 << bits), v)
        parts.append(v)
    q = jnp.stack(parts, axis=-1)
    return q.reshape(packed.shape[0], packed.shape[1] * per)


def _quant_matmul_kernel(
    x_ref, w_ref, s_ref, z_ref, o_ref, acc_ref, *, bits: int, nk: int
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _unpack_tile(w_ref[...], bits).astype(jnp.float32)
    inv_s = s_ref[0, 0]  # reciprocal scale, precomputed host-side
    z = z_ref[0, 0]
    w = (q - z) * inv_s
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w,
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "bm", "bn", "bk", "interpret"),
)
def quant_matmul_pallas(
    x: jax.Array,        # (M, K)
    w_packed: jax.Array, # (K, N//per) int8 carriers
    scale: jax.Array,    # () per-tensor
    zero: jax.Array,     # ()
    bits: int,
    bm: int = 128,
    bn: int = 512,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Caller must pre-pad M/N/K to block multiples (see ops.quant_matmul)."""
    per = 8 // bits
    m, k = x.shape
    n = w_packed.shape[1] * per
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk
    inv_s = (1.0 / scale).reshape(1, 1).astype(jnp.float32)
    z = zero.reshape(1, 1).astype(jnp.float32)
    grid = (m // bm, n // bn, nk)
    kwargs = {}
    if not interpret:
        # (M, N) parallel + K arbitrary => Mosaic double-buffers the packed
        # weight DMA against the MXU sweep.
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // per), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, w_packed, inv_s, z)
