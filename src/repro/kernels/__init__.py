"""Pallas TPU kernels for SplitQuantV2 deployment + preprocessing.

Layout: <name>.py holds the pl.pallas_call + BlockSpec kernel, ops.py the
jit'd public wrappers (padding, backend dispatch), ref.py the pure-jnp
oracles used by the interpret-mode test sweeps.
"""
from repro.kernels import ops, ref  # noqa: F401
