"""RWKV6/GLA chunked linear-attention Pallas kernel (forward).

Cell-A fix for the worst roofline cell (rwkv6-3b train_4k): the pure-JAX
chunked WKV materializes a per-chunk (Q, Q, H, N) pairwise-decay tensor in
HBM — ~1.3e6 ms of memory term at production scale. This kernel keeps all
within-chunk pairwise terms in VMEM: HBM traffic collapses to r/k/v/decay
in + y/state out (the GLA/flash-linear-attention pattern, re-tiled for
TPU: per-(batch·head) grid, chunks sequential so the (N, P) state lives in
a VMEM scratch across the chunk sweep).

Recurrence (matches models/ssm._wkv_chunked and its naive-oracle tests):
    y_t = r_t · (S_{t-1} + u ⊙ k_t v_t^T);   S_t = w_t ⊙ S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, st_out_ref,
                state_ref, *, nc: int, q: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)    # (Q, N)
    k = k_ref[0].astype(jnp.float32)    # (Q, N)
    v = v_ref[0].astype(jnp.float32)    # (Q, P)
    lw = lw_ref[0].astype(jnp.float32)  # (Q, N) log-decays <= 0
    u = u_ref[0].astype(jnp.float32)    # (1, N) bonus

    lcum = jnp.cumsum(lw, axis=0)       # (Q, N)
    lprev = lcum - lw
    state = state_ref[...]              # (N, P)

    # inter-chunk: y_i += (r_i * exp(Lprev_i)) @ S
    y = jax.lax.dot(r * jnp.exp(lprev), state,
                    preferred_element_type=jnp.float32)  # (Q, P)

    # intra-chunk: scores_ij = sum_n r_in k_jn exp(Lprev_i - L_j), j < i
    # (pairwise tensor lives only in VMEM/VREGs — that is the whole point)
    diff = lprev[:, None, :] - lcum[None, :, :]          # (Q, Q, N)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    e = jnp.exp(jnp.where(tri[:, :, None], diff, -jnp.inf))
    scores = jnp.einsum("in,jn,ijn->ij", r, k, e)        # (Q, Q)
    y += jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    # current-token bonus (diagonal)
    y += jnp.sum(r * k * u, axis=1, keepdims=True) * v

    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S' = exp(L_Q) ⊙ S + sum_j exp(L_Q - L_j) k_j v_j^T
    to_end = jnp.exp(lcum[-1:, :] - lcum)                # (Q, N)
    state = state * jnp.exp(lcum[-1])[:, None] + jax.lax.dot(
        (k * to_end).T, v, preferred_element_type=jnp.float32
    )
    state_ref[...] = state

    @pl.when(c == nc - 1)
    def _done():
        st_out_ref[0] = state.astype(st_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(
    r: jax.Array,   # (BH, S, N)
    k: jax.Array,   # (BH, S, N)
    v: jax.Array,   # (BH, S, P)
    lw: jax.Array,  # (BH, S, N) log-decays (<= 0)
    u: jax.Array,   # (BH, 1, N) per-head bonus (broadcast over batch)
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bh, s, n = k.shape
    p = v.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    grid = (bh, nc)
    y, st = pl.pallas_call(
        functools.partial(_wkv_kernel, nc=nc, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, n), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, p), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), r.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
    return y, st
