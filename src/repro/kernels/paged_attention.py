"""Paged-attention decode Pallas kernel — block-table KV gather on TPU.

Decode attention over a PAGED KV cache: instead of indexing one contiguous
``(B, Smax, KV, hd)`` strip, each batch row follows its page-table row
through a shared pool of fixed-size pages. The kernel uses
``PrefetchScalarGridSpec``: the page table and per-row lengths are scalar-
prefetched so the K/V BlockSpec index maps can resolve ``logical page i of
row b`` -> physical page id BEFORE the body runs — K/V never need to be
gathered into a contiguous per-row strip in HBM (the XLA reference path
materialises exactly that gather). The grid still sweeps every logical
page slot per row, so fetch traffic is O(table width), not O(len): dead
slots re-fetch a clamped page and are masked in the body. Skipping them
(and multi-page blocks / double-buffered fetches) is the scheduled TPU
perf pass — see ROADMAP; this kernel is the reference-quality baseline.

Grid ``(B, KV, NP)`` with the page dim innermost (sequential on TPU): the
per-(row, kv-head) output tile and running online-softmax stats live in
VMEM scratch across the page sweep, exactly like the flash kernel's Sk
sweep. GQA is handled by blocking q/o as the ``G = H // KV`` query-head
group of the kv head — scores stay (G, page)-tiny at decode.

Masks: positions ``>= len`` are dead, plus optional sliding-window and
chunked-attention masks on absolute positions (traced scalars, prefetched).
Fully-masked rows (``len == 0``) produce EXACT zeros — the same contract
as the reference softmax guard in ``models/attention.py``, not a uniform
average over garbage.

``paged_attention_reference`` is the pure-jnp oracle (gather + masked
softmax) used for CPU CI and the kernel-equivalence test.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    pt_ref,    # (B, NP) scalar-prefetch: physical page ids
    len_ref,   # (B,)    scalar-prefetch: valid KV length per row
    meta_ref,  # (2,)    scalar-prefetch: [window, chunk] (0 => disabled)
    q_ref,     # (1, 1, G, hd)
    k_ref,     # (1, page, 1, hd) — physical page selected by index_map
    v_ref,     # (1, page, 1, hd)
    o_ref,     # (1, 1, G, hd)
    acc_ref, m_ref, l_ref,
    *, scale: float, page: int, n_pages: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)        # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (page, hd)
    s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    k_len = len_ref[b]
    q_pos = k_len - 1  # the decode token sits at the last valid position
    k_pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < k_len
    w, c = meta_ref[0], meta_ref[1]
    mask &= jnp.where(w > 0, (q_pos - k_pos) < w, True)
    cs = jnp.maximum(c, 1)
    mask &= jnp.where(c > 0, (q_pos // cs) == (k_pos // cs), True)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                    # (G, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    # mask p explicitly: when every key so far is dead, m_cur == NEG_INF and
    # exp(s - m_cur) would be exp(0) == 1 per dead key — the classic
    # garbage-average bug for empty rows. Masked p keeps l at exactly 0.
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(i == n_pages - 1)
    def _done():
        l = l_ref[...]
        out = jnp.where(l > 0, acc_ref[...] / jnp.where(l > 0, l, 1.0), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(
    q: jax.Array,           # (B, KV, G, hd)
    k_pages: jax.Array,     # (P, page, KV, hd)
    v_pages: jax.Array,     # (P, page, KV, hd)
    page_table: jax.Array,  # (B, NP) int32
    lengths: jax.Array,     # (B,) int32 valid KV length (post-write)
    window: jax.Array | int = 0,
    chunk: jax.Array | int = 0,
    interpret: bool = False,
) -> jax.Array:
    b, kvh, g, hd = q.shape
    p_total, page = k_pages.shape[0], k_pages.shape[1]
    n_pages = page_table.shape[1]
    meta = jnp.stack([jnp.asarray(window, jnp.int32).reshape(()),
                      jnp.asarray(chunk, jnp.int32).reshape(())])

    def kv_map(bb, h, i, pt, ln, mt):
        # stale table entries past a row's live pages still index SOME real
        # page; their contributions are masked by len in the body
        return (jnp.clip(pt[bb, i], 0, p_total - 1), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bb, h, i, pt, ln, mt: (bb, h, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, hd), lambda bb, h, i, pt, ln, mt: (bb, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_kernel, scale=hd ** -0.5, page=page, n_pages=n_pages,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(
        page_table.astype(jnp.int32), lengths.astype(jnp.int32), meta,
        q, k_pages, v_pages,
    )


def paged_attention_reference(
    q: jax.Array,           # (B, KV, G, hd)
    k_pages: jax.Array,     # (P, page, KV, hd)
    v_pages: jax.Array,     # (P, page, KV, hd)
    page_table: jax.Array,  # (B, NP)
    lengths: jax.Array,     # (B,)
    window: jax.Array | int = 0,
    chunk: jax.Array | int = 0,
) -> jax.Array:
    """Pure-jnp oracle: logical gather + masked softmax (fp32)."""
    from repro.kvcache.paged import logical_view

    b, kvh, g, hd = q.shape
    page = k_pages.shape[1]
    n_pages = page_table.shape[1]
    # one source of truth for the page addressing math
    kl, vl = logical_view(jnp.stack([k_pages, v_pages]), page_table)
    s_log = n_pages * page
    k_pos = jnp.arange(s_log, dtype=jnp.int32)[None]          # (1, S_log)
    q_pos = (lengths.astype(jnp.int32) - 1)[:, None]          # (B, 1)
    mask = k_pos < lengths.astype(jnp.int32)[:, None]
    w = jnp.asarray(window)
    mask &= jnp.where(w > 0, (q_pos - k_pos) < w, True)
    c = jnp.asarray(chunk)
    mask &= jnp.where(c > 0, (q_pos // jnp.maximum(c, 1))
                      == (k_pos // jnp.maximum(c, 1)), True)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", q.astype(jnp.float32), kl.astype(jnp.float32)
    ) * (hd ** -0.5)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vl.astype(jnp.float32))
    out = jnp.where(l > 0, out / jnp.where(l > 0, l, 1.0), 0.0)
    return out.astype(q.dtype)
