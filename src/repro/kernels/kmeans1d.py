"""Pallas kernel for the 1-D k-means assign+reduce hot loop.

One Lloyd iteration = nearest-centroid assignment + per-cluster (sum, count)
reduction over every scalar weight. For a 20B-parameter model this pass
touches 20B floats × iters, so it is the preprocessing hot spot (the paper's
"2 CPU-minutes for 1B" budget lives here). The kernel streams value tiles
through VMEM and accumulates k running (sum, count) pairs across the
sequential TPU grid into a single output block — O(n) HBM reads, O(k)
writes.

k is static and tiny (=3), so assignment is a select chain on the VPU, not
an argmin gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_reduce_kernel(x_ref, m_ref, c_ref, sums_ref, counts_ref, *, k: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...].astype(jnp.float32)
    mask = m_ref[...].astype(jnp.float32)
    # nearest centroid via select chain (k static, centroids sorted)
    best_d = jnp.abs(x - c_ref[0, 0])
    best_i = jnp.zeros(x.shape, jnp.int32)
    for c in range(1, k):
        d = jnp.abs(x - c_ref[c, 0])
        take = d < best_d
        best_d = jnp.where(take, d, best_d)
        best_i = jnp.where(take, c, best_i)
    for c in range(k):
        sel = jnp.where((best_i == c), mask, 0.0)
        sums_ref[c, 0] += jnp.sum(sel * x)
        counts_ref[c, 0] += jnp.sum(sel)


@functools.partial(jax.jit, static_argnames=("k", "br", "bc", "interpret"))
def kmeans_assign_reduce_pallas(
    x2d: jax.Array,   # (R, C) values (flattened weights, padded)
    mask: jax.Array,  # (R, C) 1.0 for real entries, 0.0 for padding
    centroids: jax.Array,  # (k,)
    k: int = 3,
    br: int = 256,
    bc: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    r, c = x2d.shape
    assert r % br == 0 and c % bc == 0
    cents = centroids.reshape(k, 1).astype(jnp.float32)
    grid = (r // br, c // bc)
    sums, counts = pl.pallas_call(
        functools.partial(_assign_reduce_kernel, k=k),
        grid=(grid[0] * grid[1],),
        in_specs=[
            pl.BlockSpec(
                (br, bc), lambda g, nc=grid[1]: (g // nc, g % nc)
            ),
            pl.BlockSpec(
                (br, bc), lambda g, nc=grid[1]: (g // nc, g % nc)
            ),
            pl.BlockSpec((k, 1), lambda g: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, 1), lambda g: (0, 0)),
            pl.BlockSpec((k, 1), lambda g: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, mask, cents)
    return sums[:, 0], counts[:, 0]
