"""Flash attention (forward) Pallas kernel — TPU-native online-softmax SDPA.

Why it exists here: the dry-run roofline shows every *_train/prefill cell is
memory-bound, dominated by (B, H, Sq, Sk) score traffic (3-13 GB/device per
layer-pass at 32k). Flash attention keeps score blocks in VMEM: HBM traffic
collapses to Q + K + V + O. This kernel is the TPU implementation; in the
XLA-level dry-run its effect is modeled by the ``fused:flash_attn`` region
accounting in roofline/hlocost.py (CPU backend cannot lower Pallas, see
DESIGN.md §Hardware adaptation).

Tiling: grid (B*H, Sq/bq, Sk/bk) with the KV dim innermost (sequential on
TPU): the (bq, hd) output tile + running (max, sum) live in VMEM scratch
across the Sk sweep — the standard 2-pass-free online softmax.
Supports causal masking + sliding window via absolute positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, bq: int, bk: int, nk: int,
):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0].astype(jnp.float32)          # (bk, hd)
    s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0
    )
    k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                     # (bq, bk)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(kk == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,  # (BH, Sk, hd)
    v: jax.Array,  # (BH, Sk, hd)
    causal: bool = True,
    window: int = 0,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nk = sk // bk
    scale = hd ** -0.5
    grid = (bh, sq // bq, nk)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nk=nk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, kk: (b, kk, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, kk: (b, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, kk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
