"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth; tests sweep shapes/dtypes
and ``assert_allclose`` kernel-vs-oracle with ``interpret=True`` on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import unpack_codes


def quant_matmul_ref(
    x: jax.Array,          # (M, K) activations, fp32/bf16
    w_packed: jax.Array,   # (K, N // per) packed int-b codes along N
    scale: jax.Array,      # per-tensor () or per-group (K // G, N)
    zero: jax.Array,       # same shape as scale
    bits: int,
) -> jax.Array:
    """y = x @ dequant(W). Weights packed along the last (N) axis."""
    per = 8 // bits
    n = w_packed.shape[-1] * per
    q = unpack_codes(w_packed, bits, out_len=n).astype(jnp.float32)
    if scale.ndim == 0:
        w = (q - zero) / scale
    else:
        g = q.shape[0] // scale.shape[0]
        s = jnp.repeat(scale, g, axis=0)
        z = jnp.repeat(zero, g, axis=0)
        w = (q - z) / s
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def splitq_matmul_ref(
    x: jax.Array,          # (M, K)
    planes: jax.Array,     # (k, K, N // per) packed int-b codes
    scales: jax.Array,     # (k,)
    zeros: jax.Array,      # (k,)
    bits: int,
) -> jax.Array:
    """Fused SplitQuantV2 matmul: y = x @ sum_c dequant(plane_c)."""
    per = 8 // bits
    n = planes.shape[-1] * per
    w = jnp.zeros((planes.shape[1], n), jnp.float32)
    for c in range(planes.shape[0]):
        q = unpack_codes(planes[c], bits, out_len=n).astype(jnp.float32)
        w = w + (q - zeros[c]) / scales[c]
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def splitq_packed_matmul_ref(
    x: jax.Array,          # (M, K)
    codes: jax.Array,      # (K, N // per) packed int-b codes
    cids: jax.Array,       # (K, N // 4) packed 2-bit cluster ids
    scales: jax.Array,     # (k,)
    zeros: jax.Array,      # (k,)
    bits: int,
) -> jax.Array:
    """Beyond-paper 6-bit layout: w_ij = (q_ij - Z[cid_ij]) / S[cid_ij]."""
    per = 8 // bits
    n = codes.shape[-1] * per
    q = unpack_codes(codes, bits, out_len=n).astype(jnp.float32)
    cid = unpack_codes(cids, 2, out_len=n).astype(jnp.int32) & 0x3
    w = (q - zeros[cid]) / scales[cid]
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def quantize_pack_ref(
    w: jax.Array,          # (R, C), C divisible by 8//bits
    scale: jax.Array,      # ()
    zero: jax.Array,       # ()
    bits: int,
) -> jax.Array:
    """Fused quantize+pack: codes = clip(round(S*w)+Z), packed along C."""
    from repro.core.quantize import pack_codes

    q = jnp.round(scale * w.astype(jnp.float32)) + zero
    q = jnp.clip(q, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1).astype(jnp.int8)
    return pack_codes(q, bits)


def flash_attention_ref(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Plain softmax attention oracle for the flash kernel."""
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    sq, sk = s.shape[1], s.shape[2]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def kmeans_assign_reduce_ref(
    x: jax.Array,          # (n,) values
    centroids: jax.Array,  # (k,)
) -> tuple[jax.Array, jax.Array]:
    """Per-cluster (sum, count) for one Lloyd update step."""
    d = jnp.abs(x[:, None].astype(jnp.float32) - centroids[None, :])
    ids = jnp.argmin(d, axis=1)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(ids, k, dtype=jnp.float32)
    sums = onehot.T @ x.astype(jnp.float32)
    counts = onehot.sum(0)
    return sums, counts
