"""Public jit'd wrappers for the Pallas kernels.

Handles: padding to block multiples (zero-padding K on the activation side
is value-preserving; N/M padding is sliced off), backend dispatch (compiled
Pallas on TPU, ``interpret=True`` elsewhere — this container is CPU, so
tests exercise the interpreter path), block-shape dispatch via the engine
autotuner when the caller passes ``block=None``, and pytree-level entry
points taking the core's SplitQTensor / PackedSplitQTensor /
PackedSplitQGroup containers directly.

``count_launches()`` is a tracing-time hook: wrappers bump a counter when a
quantized kernel is dispatched, so tests can assert launches-per-block of a
traced forward (e.g. grouped QKV + gate/up decode: 4 instead of 7).
"""
from __future__ import annotations

import contextlib
import functools
import math
import threading

import jax
import jax.numpy as jnp

from repro.core.split import PackedSplitQGroup, PackedSplitQTensor, SplitQTensor
from repro.kernels import ref
from repro.kernels.kmeans1d import kmeans_assign_reduce_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.quantize_pack import quantize_pack_pallas
from repro.kernels.splitq_matmul import splitq_matmul_pallas
from repro.kernels.splitq_packed import splitq_packed_matmul_pallas

DEFAULT_BLOCK = (128, 512, 128)

_counter = threading.local()


@contextlib.contextmanager
def count_launches():
    """Count quantized-kernel dispatches (per trace) by kind."""
    prev = getattr(_counter, "counts", None)
    _counter.counts = {}
    try:
        yield _counter.counts
    finally:
        _counter.counts = prev


def _bump(kind: str):
    c = getattr(_counter, "counts", None)
    if c is not None:
        c[kind] = c.get(kind, 0) + 1
        c["total"] = c.get("total", 0) + 1


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _choose(m, k, n, bits, *, max_bn=None, bf16=False):
    from repro.engine.autotune import choose_block
    from repro.runtime.sharding import tp_shards

    # Under exact-TP serving hints the weight's output dim is sharded over
    # `model`: each device runs the PER-SHARD matmul, so the block (and the
    # tune-cache key) must come from n/tp, not the global width.
    tp = tp_shards()
    if tp > 1 and n % tp == 0:
        n, shards = n // tp, tp
    else:
        shards = 1
    return choose_block(m, k, n, bits, max_bn=max_bn, bf16_acts=bf16,
                        n_shards=shards)


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def quant_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    bits: int,
    *,
    block: tuple[int, int, int] | None = None,
) -> jax.Array:
    """y = x @ dequant(W).  x: (..., K); w_packed: (K, N//per)."""
    per = 8 // bits
    lead = x.shape[:-1]
    m = math.prod(lead)
    k = x.shape[-1]
    n = w_packed.shape[1] * per
    bm, bn, bk = block or _choose(m, k, n, bits, bf16=x.dtype == jnp.bfloat16)
    _bump("quant_matmul")
    x2 = _pad_to(x.reshape(m, k), (bm, bk))
    wp = _pad_to(w_packed, (bk, bn // per))
    y = quant_matmul_pallas(
        x2, wp, jnp.asarray(scale), jnp.asarray(zero), bits,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return y[:m, :n].reshape(*lead, n)


def splitq_matmul(
    x: jax.Array, sq: SplitQTensor, *,
    block: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Fused k-plane SplitQuantV2 matmul. x: (..., K); sq.shape == (K, N)."""
    per = 8 // sq.bits
    lead = x.shape[:-1]
    m = math.prod(lead)
    k = x.shape[-1]
    n = sq.shape[-1]
    bm, bn, bk = block or _choose(m, k, n, sq.bits,
                                  bf16=x.dtype == jnp.bfloat16)
    _bump("splitq_matmul")
    x2 = _pad_to(x.reshape(m, k), (bm, bk))
    planes = _pad_to(sq.planes, (1, bk, bn // per))
    y = splitq_matmul_pallas(
        x2, planes, sq.scales, sq.zeros, sq.bits,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return y[:m, :n].reshape(*lead, n)


def splitq_packed_matmul(
    x: jax.Array,
    psq: PackedSplitQTensor,
    *,
    block: tuple[int, int, int] | None = None,
) -> jax.Array:
    """6-bit packed SplitQuantV2 matmul. x: (..., K)."""
    per = 8 // psq.bits
    lead = x.shape[:-1]
    m = math.prod(lead)
    k = x.shape[-1]
    n = psq.shape[-1]
    bm, bn, bk = block or _choose(m, k, n, psq.bits,
                                  bf16=x.dtype == jnp.bfloat16)
    _bump("splitq_packed_matmul")
    x2 = _pad_to(x.reshape(m, k), (bm, bk))
    codes = _pad_to(psq.codes, (bk, bn // per))
    cids = _pad_to(psq.cids, (bk, bn // 4))
    y = splitq_packed_matmul_pallas(
        x2, codes, cids, psq.scales, psq.zeros, psq.bits,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return y[:m, :n].reshape(*lead, n)


def splitq_packed_group_matmul(
    x: jax.Array,
    grp: PackedSplitQGroup,
    *,
    block: tuple[int, int, int] | None = None,
) -> list[jax.Array]:
    """ONE kernel launch for a fused projection group (QKV / gate+up).

    Returns the per-member outputs (padding columns sliced off). Activation
    x is read once instead of once per member — at decode this halves the
    activation HBM traffic of the attention + MLP input projections.
    """
    per = 8 // grp.bits
    lead = x.shape[:-1]
    m = math.prod(lead)
    k = x.shape[-1]
    padded = grp.padded_widths()
    n_tot = sum(padded)
    bm, bn, bk = block or _choose(
        m, k, n_tot, grp.bits, max_bn=grp.align,
        bf16=x.dtype == jnp.bfloat16,
    )
    bn = min(bn, grp.align)
    assert grp.align % bn == 0, (grp.align, bn)
    _bump("splitq_packed_group_matmul")
    x2 = _pad_to(x.reshape(m, k), (bm, bk))
    codes = _pad_to(grp.codes, (bk, n_tot // per))
    cids = _pad_to(grp.cids, (bk, n_tot // 4))
    starts, off = [], 0
    for pw in padded:
        starts.append(off // bn)
        off += pw
    y = splitq_packed_matmul_pallas(
        x2, codes, cids, grp.scales, grp.zeros, grp.bits,
        bm=bm, bn=bn, bk=bk, group_starts=tuple(starts),
        interpret=_interpret(),
    )
    out, off = [], 0
    for w, pw in zip(grp.widths, padded):
        out.append(y[:m, off:off + w].reshape(*lead, w))
        off += pw
    return out


def quantize_pack(
    w: jax.Array, scale: jax.Array, zero: jax.Array, bits: int,
    *, block: tuple[int, int] = (256, 512),
) -> jax.Array:
    """Fused quantize+pack. w: (R, C) -> (R, C//per) int8, C padded entries
    are quantized zeros (caller slices by logical shape)."""
    br, bc = block
    per = 8 // bits
    r, c = w.shape
    w2 = _pad_to(w, (br, bc))
    out = quantize_pack_pallas(
        w2, jnp.asarray(scale), jnp.asarray(zero), bits,
        br=br, bc=bc, interpret=_interpret(),
    )
    return out[:r, : (c + per - 1) // per]


def kmeans_assign_reduce(
    x: jax.Array, centroids: jax.Array, *, block: tuple[int, int] = (256, 512)
) -> tuple[jax.Array, jax.Array]:
    """Per-cluster (sum, count) over all elements of x (any shape)."""
    br, bc = block
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    cols = bc
    rows = -(-n // cols)
    pad = rows * cols - n
    x2 = jnp.pad(flat, (0, pad)).reshape(rows, cols)
    mask = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad)).reshape(rows, cols)
    x2 = _pad_to(x2, (br, bc))
    mask = _pad_to(mask, (br, bc))
    return kmeans_assign_reduce_pallas(
        x2, mask, centroids, k=centroids.shape[0],
        br=br, bc=bc, interpret=_interpret(),
    )


# Re-export oracles for test convenience.
oracle = ref
