"""Public jit'd wrappers for the Pallas kernels.

Handles: padding to block multiples (zero-padding K on the activation side
is value-preserving; N/M padding is sliced off), backend dispatch (compiled
Pallas on TPU, ``interpret=True`` elsewhere — this container is CPU, so
tests exercise the interpreter path), and pytree-level entry points taking
the core's SplitQTensor / PackedSplitQTensor containers directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.split import PackedSplitQTensor, SplitQTensor
from repro.kernels import ref
from repro.kernels.kmeans1d import kmeans_assign_reduce_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.quantize_pack import quantize_pack_pallas
from repro.kernels.splitq_matmul import splitq_matmul_pallas
from repro.kernels.splitq_packed import splitq_packed_matmul_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def quant_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    bits: int,
    *,
    block: tuple[int, int, int] = (128, 512, 128),
) -> jax.Array:
    """y = x @ dequant(W).  x: (..., K); w_packed: (K, N//per)."""
    bm, bn, bk = block
    per = 8 // bits
    lead = x.shape[:-1]
    m = int(jnp.prod(jnp.array(lead))) if lead else 1
    k = x.shape[-1]
    n = w_packed.shape[1] * per
    x2 = _pad_to(x.reshape(m, k), (bm, bk))
    wp = _pad_to(w_packed, (bk, bn // per))
    y = quant_matmul_pallas(
        x2, wp, jnp.asarray(scale), jnp.asarray(zero), bits,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return y[:m, :n].reshape(*lead, n)


def splitq_matmul(
    x: jax.Array, sq: SplitQTensor, *, block: tuple[int, int, int] = (128, 512, 128)
) -> jax.Array:
    """Fused k-plane SplitQuantV2 matmul. x: (..., K); sq.shape == (K, N)."""
    bm, bn, bk = block
    per = 8 // sq.bits
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    k = x.shape[-1]
    n = sq.shape[-1]
    x2 = _pad_to(x.reshape(m, k), (bm, bk))
    planes = _pad_to(sq.planes, (1, bk, bn // per))
    y = splitq_matmul_pallas(
        x2, planes, sq.scales, sq.zeros, sq.bits,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return y[:m, :n].reshape(*lead, n)


def splitq_packed_matmul(
    x: jax.Array,
    psq: PackedSplitQTensor,
    *,
    block: tuple[int, int, int] = (128, 512, 128),
) -> jax.Array:
    """6-bit packed SplitQuantV2 matmul. x: (..., K)."""
    bm, bn, bk = block
    per = 8 // psq.bits
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    k = x.shape[-1]
    n = psq.shape[-1]
    x2 = _pad_to(x.reshape(m, k), (bm, bk))
    codes = _pad_to(psq.codes, (bk, bn // per))
    cids = _pad_to(psq.cids, (bk, bn // 4))
    y = splitq_packed_matmul_pallas(
        x2, codes, cids, psq.scales, psq.zeros, psq.bits,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return y[:m, :n].reshape(*lead, n)


def quantize_pack(
    w: jax.Array, scale: jax.Array, zero: jax.Array, bits: int,
    *, block: tuple[int, int] = (256, 512),
) -> jax.Array:
    """Fused quantize+pack. w: (R, C) -> (R, C//per) int8, C padded entries
    are quantized zeros (caller slices by logical shape)."""
    br, bc = block
    per = 8 // bits
    r, c = w.shape
    w2 = _pad_to(w, (br, bc))
    out = quantize_pack_pallas(
        w2, jnp.asarray(scale), jnp.asarray(zero), bits,
        br=br, bc=bc, interpret=_interpret(),
    )
    return out[:r, : (c + per - 1) // per]


def kmeans_assign_reduce(
    x: jax.Array, centroids: jax.Array, *, block: tuple[int, int] = (256, 512)
) -> tuple[jax.Array, jax.Array]:
    """Per-cluster (sum, count) over all elements of x (any shape)."""
    br, bc = block
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    cols = bc
    rows = -(-n // cols)
    pad = rows * cols - n
    x2 = jnp.pad(flat, (0, pad)).reshape(rows, cols)
    mask = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad)).reshape(rows, cols)
    x2 = _pad_to(x2, (br, bc))
    mask = _pad_to(mask, (br, bc))
    return kmeans_assign_reduce_pallas(
        x2, mask, centroids, k=centroids.shape[0],
        br=br, bc=bc, interpret=_interpret(),
    )


# Re-export oracles for test convenience.
oracle = ref
