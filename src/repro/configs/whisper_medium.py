"""whisper-medium [audio] — enc-dec, 24L each side, d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865, conv frontend STUBBED (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    encdec=True,
    frontend="audio",
    act="gelu",
    glu=False,
    rope_theta=1e4,
)
