"""rwkv6-3b [ssm] — Finch: 32L d_model=2560, attention-free, d_ff=8960
vocab=65536, data-dependent per-channel decay [arXiv:2404.05892; hf].
head size 64 -> 40 heads."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=None,
    d_ff=8960,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=32),
    act="relu",   # rwkv channel-mix uses relu^2; handled in ssm.py
    glu=False,
)
