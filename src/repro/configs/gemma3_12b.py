"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global sliding-window attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=240,
    d_ff=15360,
    vocab_size=262144,
    attn_pattern=("local",) * 5 + ("global",),
    window=1024,
    qk_norm=True,
    act="gelu",
    glu=True,
    rope_theta=1e6,
)
