"""Architecture + shape configuration dataclasses.

One ``ArchConfig`` per assigned architecture (``repro/configs/<id>.py``),
plus the paper's own Llama-3.2-1B family. Configs are plain frozen
dataclasses — hashable, so they ride through jit as static arguments.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0           # per-expert FFN width
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"        # "mamba2" | "rwkv6"
    d_state: int = 64           # mamba2 state dim N
    d_conv: int = 4             # causal conv width
    expand: int = 2             # d_inner = expand * d_model
    head_dim: int = 64          # SSM head dim P (mamba) / key dim (rwkv)
    n_groups: int = 1           # B/C groups (mamba2)
    chunk: int = 64             # chunked-scan block length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None           # default d_model // n_heads
    # attention flavor
    attn_pattern: tuple[str, ...] = ("global",)  # cycled over layers
    window: int = 0                       # sliding-window size (local)
    attn_chunk: int = 0                   # chunked attention (llama4)
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope: bool = False                   # qwen2-vl 3-D M-RoPE
    mrope_sections: tuple[int, int, int] = (2, 1, 1)  # t:h:w freq split ratio
    # mlp flavor
    act: str = "silu"                     # silu | gelu | sqrelu
    glu: bool = True
    # mixture of experts
    moe: MoEConfig | None = None
    # state-space / linear-attention
    ssm: SSMConfig | None = None
    shared_attn_every: int = 0            # zamba2: shared block cadence
    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: None | "audio" | "patch"
    frontend: str | None = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    remat_group: int = 1            # grouped activation checkpointing (train)
    dtype: str = "bfloat16"               # compute/storage dtype at scale

    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def hd(self) -> int:
        return self.head_dim or 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md skip table)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if not self.attn_pattern:
            return False
        # local/chunked patterns with at most sparse global layers
        n_local = sum(p in ("local", "chunked") for p in self.attn_pattern)
        return n_local >= len(self.attn_pattern) - 1 and n_local > 0

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer attention kind, cycling attn_pattern over n_layers."""
        pat = self.attn_pattern or ("global",)
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 4 if self.shared_attn_every else 3),
            d_model=128,
            d_ff=256,
            vocab_size=512,
            rope_theta=1e4,
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, min(self.n_kv_heads, 2))
            kw["head_dim"] = 32
        if self.moe is not None:
            # capacity_factor = E/k => capacity == token count: nothing ever
            # drops at smoke scale, so prefill/decode/teacher-forced paths
            # are bit-consistent (capacity dropping is exercised at prod
            # scale via the dry-run and in test_moe_capacity_drops).
            kw["moe"] = replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                d_expert=64, capacity_factor=8.0,
            )
        if self.ssm is not None:
            kw["ssm"] = replace(
                self.ssm, d_state=16, head_dim=16, chunk=8,
            )
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        if self.encdec:
            kw["n_enc_layers"] = 2
        if self.window:
            kw["window"] = 16
        if self.attn_chunk:
            kw["attn_chunk"] = 16
        kw["dtype"] = "float32"
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    def reduced(self) -> "ShapeConfig":
        return replace(
            self, seq_len=min(self.seq_len, 32), global_batch=min(self.global_batch, 2)
        )
