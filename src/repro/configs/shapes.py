"""The four assigned input-shape cells (per-arch applicability in DESIGN.md).

``train_*`` lowers ``train_step``; ``prefill_*`` lowers the batched prefill
``serve_step``; ``decode_*`` / ``long_*`` lower the single-new-token decode
``serve_step`` with a KV cache of ``seq_len``.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

ALL_SHAPES: tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). Only long_500k has skips (full-attention
    archs; see DESIGN.md §Shape-cell skips)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
