"""qwen2-vl-2b [vlm] — LM backbone only: 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936, M-RoPE (3-D positions); vision patch frontend
STUBBED (input_specs provides precomputed patch embeddings)
[arXiv:2409.12191; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    frontend="patch",
    act="silu",
    glu=True,
)
