"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf]. The Zamba2 shared transformer block (one set of
attention+MLP weights reused at a fixed cadence) is modeled with
``shared_attn_every=6`` → 6 application points over 38 Mamba2 layers; each
application point has its own KV cache (same weights, distinct activations).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(kind="mamba2", d_state=64, expand=2, head_dim=64, chunk=64),
    shared_attn_every=6,
    act="gelu",
    glu=True,
)
