"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) vocab=202048,
MoE 16 routed experts top-1 + 1 shared (d_expert=8192), 3:1 chunked-local :
global attention (chunk 8192), early-fusion multimodal (text path modeled)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    remat_group=2,
    vocab_size=202048,
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, d_expert=8192),
    attn_pattern=("chunked", "chunked", "chunked", "global"),
    attn_chunk=8192,
    act="silu",
    glu=True,
)
