"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, MoEConfig, ShapeConfig, SSMConfig
from repro.configs.shapes import ALL_SHAPES, SHAPES, applicable

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen3-0.6b": "qwen3_0p6b",
    "gemma3-12b": "gemma3_12b",
    "internlm2-20b": "internlm2_20b",
    "nemotron-4-15b": "nemotron4_15b",
    "whisper-medium": "whisper_medium",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "llama32-1b": "llama32_1b",   # the paper's model family
}

ASSIGNED_ARCHS: tuple[str, ...] = tuple(
    n for n in _MODULES if n != "llama32-1b"
)
ALL_ARCHS: tuple[str, ...] = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "ALL_SHAPES", "SHAPES", "applicable", "get_config",
    "ASSIGNED_ARCHS", "ALL_ARCHS",
]
