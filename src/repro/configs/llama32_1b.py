"""llama-3.2-1b [dense] — the paper's own evaluation model family:
16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256
[arXiv:2407.21783]. Used by the paper-faithful reproduction pipeline."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama32-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    act="silu",
    glu=True,
    rope_theta=5e5,
)
