"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) vocab=102400,
fine-grained MoE: 64 routed experts top-6 + 2 shared, d_expert=1408
[arXiv:2401.06066; hf]. (Real model's dense layer 0 folded into the
homogeneous MoE stack for scan-ability; see DESIGN.md.)"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    act="silu",
    glu=True,
)
