"""AdamW with mixed precision and ZeRO-1-ready state layout.

State: fp32 master weights + fp32 (m, v) — the classic layout whose
sharding is the ZeRO-1 win: model params stay replicated across ``data``
(fast forward/backward), while master/m/v are additionally sharded over
``data`` (see runtime/sharding.zero1_spec), cutting optimizer memory by
|data| and turning the param update into a reduce-scatter + all-gather
pattern under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import schedule as sched


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "warmup_cosine"

    def lr(self, step):
        fn: Callable = getattr(sched, self.schedule)
        return fn(step, peak_lr=self.peak_lr, warmup=self.warmup,
                  total=self.total_steps)


def init_opt_state(params: Any) -> dict:
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t
    )
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda x: x.astype(jnp.float32), params),
        "m": f32(params),
        "v": f32(params),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def _is_matrix(path) -> bool:
    # weight decay only on >=2-D weights (not norms/biases), standard practice
    return True


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any, opt: dict):
    """Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    lr = cfg.lr(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) if cfg.clip_norm \
        else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay * master if master.ndim >= 2 else 0.0
        new_master = master - lr * (delta + wd)
        return new_master, m, v, new_master.astype(p.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_master = treedef.flatten_up_to(opt["master"])
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(ma, g, m, v, p)
           for ma, g, m, v, p in zip(flat_master, flat_g, flat_m, flat_v, flat_p)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_params = treedef.unflatten([o[3] for o in out])
    new_opt = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_params, new_opt, {"lr": lr, "grad_norm": gnorm}
