"""Compressed gradient collectives with error feedback.

At 1000+ node scale the cross-pod (DCN) gradient reduction dominates step
time for DP-heavy meshes. ``compressed_psum_mean`` implements the classic
int8 error-feedback scheme as explicit per-shard collectives under
``shard_map``:

  1. residual-corrected gradient  g' = g + e   (error feedback carry)
  2. per-block int8 quantize (block=256, symmetric, max-abs scale)
  3. reduce-scatter of int8 payloads + fp32 block scales  — each hop moves
     ~25% of the fp32 bytes
  4. local fp32 reduction of the dequantized shards
  5. int8 all-gather of the reduced shard
  6. new residual  e' = g' − dequant(quant-roundtrip applied to g')

Error feedback makes the *accumulated* bias vanish: quantization error is
re-injected next step, so SGD/Adam trajectories track the uncompressed run
(property-tested in tests/test_compression.py). The collective-byte saving
is measured from lowered HLO in benchmarks/compression_bench.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

BLOCK = 256


def _quant_block(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8. x: (n,) padded to BLOCK multiple."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_block(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def quant_roundtrip(x: jax.Array) -> jax.Array:
    pad = (-x.size) % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    q, s = _quant_block(flat)
    out = _dequant_block(q, s)
    return out[: x.size].reshape(x.shape)


def compressed_psum_mean(
    flat_grad: jax.Array, axis_name: str
) -> jax.Array:
    """Mean over ``axis_name`` with int8 wire traffic (call inside
    shard_map). flat_grad: (n,) fp32, size divisible by BLOCK and by the
    axis size."""
    try:
        n_shards = jax.lax.axis_size(axis_name)
    except AttributeError:  # jax <= 0.4.x: constant-folds to a python int
        n_shards = jax.lax.psum(1, axis_name)
    q, s = _quant_block(flat_grad)
    nblk = q.shape[0]
    # reduce-scatter decomposition: all_to_all int8 chunks, local fp32 sum
    qs = q.reshape(n_shards, nblk // n_shards, BLOCK)
    ss = s.reshape(n_shards, nblk // n_shards, 1)
    q_x = jax.lax.all_to_all(qs, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)
    s_x = jax.lax.all_to_all(ss, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)
    local = jnp.sum(
        q_x.astype(jnp.float32) * s_x.astype(jnp.float32), axis=0
    )  # (nblk/n, BLOCK) fp32 partial sums, exact in fp32
    local = local / n_shards
    # re-quantize the reduced shard, all-gather int8 + scales
    lq, lscale = _quant_block(local.reshape(-1))
    gq = jax.lax.all_gather(lq, axis_name, axis=0, tiled=True)
    gs = jax.lax.all_gather(lscale, axis_name, axis=0, tiled=True)
    return _dequant_block(gq, gs)[: flat_grad.size]


def flatten_tree(tree: Any) -> tuple[jax.Array, Any, list, list]:
    flat, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [x.size for x in flat]
    shapes = [x.shape for x in flat]
    big = jnp.concatenate([x.reshape(-1).astype(jnp.float32) for x in flat])
    return big, treedef, sizes, shapes


def unflatten_tree(big: jax.Array, treedef, sizes, shapes) -> Any:
    outs, off = [], 0
    for sz, shp in zip(sizes, shapes):
        outs.append(big[off : off + sz].reshape(shp))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, outs)


def make_compressed_allreduce(mesh: Mesh, axis_name: str = "data"):
    """Returns f(per_shard_grads, err) -> (mean, new_err).

    per_shard_grads: pytree whose leaves are stacked per-shard gradients
    with leading dim == mesh.shape[axis_name] (the shard_map DP layout);
    err: same-structure error-feedback residual, PER SHARD (leading dim
    too) — each shard corrects its own compression error.
    Wire traffic per hop is int8 + fp32/BLOCK scales ≈ 26.6% of fp32.
    """
    try:
        shard_map = jax.shard_map  # top-level API in new jax
        smap_kw = {"check_vma": False}
    except AttributeError:  # jax <= 0.4.x
        from jax.experimental.shard_map import shard_map
        smap_kw = {"check_rep": False}

    n_ax = mesh.shape[axis_name]

    def allreduce(tree: Any, err: Any):
        big, treedef, sizes, shapes = flatten_tree(tree)      # (n_ax * n,)
        ebig, *_ = flatten_tree(err)
        n = big.size // n_ax
        pad = (-n) % (BLOCK * n_ax)
        big2 = (big + ebig).reshape(n_ax, n)
        big2 = jnp.pad(big2, ((0, 0), (0, pad)))

        def inner(g):
            g = g[0]  # (n+pad,) this shard's corrected gradient
            reduced = compressed_psum_mean(g, axis_name)
            new_err = g - quant_roundtrip(g)  # local quantization residual
            return reduced[None], new_err[None]

        reduced, new_err = shard_map(
            inner, mesh=mesh,
            in_specs=P(axis_name, None),
            out_specs=(P(None), P(axis_name, None)),
            **smap_kw,
        )(big2)
        reduced = reduced[0, : n]
        mean = jnp.tile(reduced, n_ax)[: big.size]
        new_err_flat = new_err[:, :n].reshape(-1)[: big.size]
        return (
            unflatten_tree(mean, treedef, sizes, shapes),
            unflatten_tree(new_err_flat, treedef, sizes, shapes),
        )

    return allreduce
