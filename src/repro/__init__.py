"""repro — SplitQuantV2 as a production-grade JAX/TPU framework."""
__version__ = "0.1.0"
