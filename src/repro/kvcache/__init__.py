"""Paged KV-cache subsystem: block-table page allocator + paged layout math.

``allocator`` is host-side bookkeeping (free list, refcounts, fragmentation
stats); ``paged`` is the device-side index math (scatter writes, logical
gather). The Pallas paged-attention decode kernel lives with the other
kernels in ``repro.kernels.paged_attention``.
"""
from repro.kvcache.allocator import OutOfPages, PageAllocator
from repro.kvcache.paged import logical_view, paged_write, pages_for

__all__ = [
    "OutOfPages",
    "PageAllocator",
    "logical_view",
    "paged_write",
    "pages_for",
]
