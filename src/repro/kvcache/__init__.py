"""Paged KV-cache subsystem: block-table page allocator + paged layout math.

``allocator`` is host-side bookkeeping (free list, refcounts, copy-on-write
accounting, fragmentation stats); ``paged`` is the device-side index math
(scatter writes, logical gather, page copies); ``prefix`` is the
prefix-sharing cache (full prompt pages -> shared read-only pages). The
Pallas paged-attention decode kernel lives with the other kernels in
``repro.kernels.paged_attention``.
"""
from repro.kvcache.allocator import OutOfPages, PageAllocator, PagePoolGroup
from repro.kvcache.paged import (
    copy_page,
    logical_view,
    paged_write,
    pages_for,
    read_pages,
    restore_rows,
    rewind,
    write_pages,
)
from repro.kvcache.prefix import PrefixIndex

__all__ = [
    "OutOfPages",
    "PageAllocator",
    "PagePoolGroup",
    "PrefixIndex",
    "copy_page",
    "logical_view",
    "paged_write",
    "pages_for",
    "read_pages",
    "restore_rows",
    "rewind",
    "write_pages",
]
