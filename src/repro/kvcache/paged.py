"""Paged KV-cache layout math (device side).

Layout: one pool of ``P`` fixed-size pages per layer, ``kv_pages`` shaped
``(2, P, page, KV, hd)`` (the leading 2 is K/V). A request's logical token
position ``t`` lives in logical page ``t // page`` at offset ``t % page``;
the per-slot ``page_table`` row maps logical page index -> physical page
id, so the flat physical index is::

    phys(b, t) = page_table[b, t // page] * page + t % page

Logical position == absolute token position, which is what keeps RoPE,
causal/sliding-window/chunked masks and the per-slot ``len`` contract
identical between the paged and contiguous cache layouts.

Pages may be SHARED read-only between slots (prefix sharing: several
page-table rows map different logical pages onto one physical page), but
writers require exclusive ownership (refcount 1 — see
``kvcache.allocator``): the scheduler copy-on-writes any shared page
before a slot scatters into it (``allocator.cow`` for the bookkeeping,
:func:`copy_page` for the device contents), so distinct slots never
scatter into the same physical page. Page-table entries beyond a slot's
allocated range may be stale/zero; reads clamp them and attention masks
positions ``>= len``, so stale pages are unreachable the same way stale
dense-cache rows are.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to back ``n_tokens`` logical positions."""
    return -(-max(n_tokens, 0) // page_size)


def rewind(cache_len: jax.Array, mask: jax.Array,
           new_len: jax.Array) -> jax.Array:
    """Rewind per-slot fill lengths: rows selected by ``mask`` (B,) bool
    take ``new_len``; others keep theirs.

    This is how speculative decoding UN-WRITES rejected draft tokens: the
    verify forward scattered KV for all k+1 fed positions, and a rejection
    pulls ``len`` back to the accepted count — the rejected positions
    become unreachable (attention masks keys ``>= len``) and the next wave
    overwrites them, exactly the invariant that makes recycled slots and
    stale dense rows safe. No page changes hands: reservation math is
    untouched, and every rewound position sits in a page the slot
    exclusively owns (the scheduler's COW guard ran before the write), so
    nothing is leaked or double-written."""
    return jnp.where(mask, new_len, cache_len).astype(jnp.int32)


def restore_rows(cache: dict, snap: dict, mask: jax.Array,
                 keys: list[str]) -> dict:
    """Restore snapshot rows of recurrent cache leaves for the batch
    slots selected by ``mask`` (B,) bool.

    Recurrent state cannot be un-written by a length rewind (it has no
    positional axis to mask), so speculative rollback restores a
    pre-write snapshot instead — for the slots that absorbed rejected
    tokens only. All recurrent leaves are laid out ``(L, B, ...)``
    (``models.model._RECURRENT_KEYS``); that batch-on-axis-1 convention
    lives HERE, shared by the target verifier and the drafter. Snapshots
    are plain references (jax arrays are immutable), so this is one
    ``where`` per leaf, no copies held."""
    out = dict(cache)
    for key in keys:
        leaf = cache[key]
        sel = mask.reshape((1, -1) + (1,) * (leaf.ndim - 2))
        out[key] = jnp.where(sel, snap[key], leaf)
    return out


def copy_page(pool: jax.Array, src: int, dst: int) -> jax.Array:
    """Copy one physical page's contents onto another (copy-on-write).

    ``pool`` is any per-layer page pool laid out ``(L, 2, P, page, KV,
    hd)`` (``pages`` or the zamba2 ``shared_pages``) — the copy spans all
    layers and both K/V planes of the page in one device op. Runs on the
    admission path (off the jitted decode/prefill hot loop), so it is a
    plain functional update, not a fused kernel."""
    return pool.at[:, :, dst].set(pool[:, :, src])


def read_pages(pool: jax.Array, ids) -> jax.Array:
    """Gather the contents of physical pages ``ids`` from a per-layer
    page pool laid out ``(L, 2, P, page, ...)`` -> ``(L, 2, n, page, ...)``.

    This is the spill path (preempt-to-disk): the scheduler snapshots a
    victim's pages to the host store *before* freeing them, so a later
    re-admission can reload contents instead of replaying the sequence
    through prefill. Like :func:`copy_page` it runs on the admission path,
    off the jitted hot loop. Shared (refcount > 1) pages read fine — the
    snapshot is a copy, not a claim."""
    idx = jnp.asarray(ids, jnp.int32)
    return jnp.take(pool, idx, axis=2)


def write_pages(pool: jax.Array, ids, values) -> jax.Array:
    """Scatter page contents back into physical pages ``ids`` of a pool
    laid out ``(L, 2, P, page, ...)`` (inverse of :func:`read_pages`).

    Restore-side of the spill tier: the target pages must be exclusively
    owned by the restoring slot (the scheduler allocates FRESH pages for a
    restore and never maps them into the prefix index), so no shared page
    is ever overwritten."""
    idx = jnp.asarray(ids, jnp.int32)
    return pool.at[:, :, idx].set(jnp.asarray(values, pool.dtype))


def paged_write(
    kv_pages: jax.Array,   # (2, P, page, KV, hd)
    k: jax.Array,          # (B, S, KV, hd)
    v: jax.Array,          # (B, S, KV, hd)
    page_table: jax.Array,  # (B, NP) int32 physical page ids
    starts: jax.Array,     # (B,) logical write offset per row
    seq_lens: jax.Array | None = None,  # (B,) valid new tokens (None => S)
) -> jax.Array:
    """Scatter new K/V tokens into their physical page slots.

    Row ``b`` writes its first ``seq_lens[b]`` tokens at logical positions
    ``starts[b] + j``; invalid positions (frozen rows, right-padding,
    out-of-table) map to an out-of-bounds flat index and are DROPPED by the
    scatter — the paged equivalent of the dense path's per-row masked
    ``dynamic_update_slice``. O(B*S) work: the pool is never traversed.
    """
    _, p_total, page, kvh, hd = kv_pages.shape
    b, s = k.shape[0], k.shape[1]
    np_max = page_table.shape[1]
    t = starts.astype(jnp.int32)[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    valid = t < np_max * page
    if seq_lens is not None:
        valid &= jnp.arange(s, dtype=jnp.int32)[None] < seq_lens.astype(jnp.int32)[:, None]
    logical = jnp.clip(t // page, 0, np_max - 1)
    phys_page = jnp.take_along_axis(page_table.astype(jnp.int32), logical, axis=1)
    flat_n = p_total * page
    phys = jnp.where(valid, phys_page * page + t % page, flat_n)  # OOB => drop
    idx = phys.reshape(b * s)
    kc = kv_pages[0].reshape(flat_n, kvh, hd).at[idx].set(
        k.astype(kv_pages.dtype).reshape(b * s, kvh, hd), mode="drop"
    )
    vc = kv_pages[1].reshape(flat_n, kvh, hd).at[idx].set(
        v.astype(kv_pages.dtype).reshape(b * s, kvh, hd), mode="drop"
    )
    return jnp.stack([kc, vc]).reshape(kv_pages.shape)


def logical_view(
    kv_pages: jax.Array,    # (2, P, page, KV, hd)
    page_table: jax.Array,  # (B, NP)
) -> tuple[jax.Array, jax.Array]:
    """Gather each row's logical K/V strip ``(B, NP*page, KV, hd)``.

    This is the interpret-mode / XLA reference data path: the gathered
    strip feeds the exact same attention math as the contiguous cache
    (positions ``>= len`` are masked identically), so paged and dense
    decoding are bit-identical. On TPU the paged-attention kernel reads
    pages directly in VMEM instead of materialising this gather in HBM.
    """
    _, p_total, page, _, _ = kv_pages.shape
    flat = kv_pages.reshape(2, p_total * page, *kv_pages.shape[3:])
    pt = jnp.clip(page_table.astype(jnp.int32), 0, p_total - 1)
    phys = (pt[:, :, None] * page
            + jnp.arange(page, dtype=jnp.int32)[None, None, :])
    phys = phys.reshape(page_table.shape[0], -1)  # (B, NP*page)
    return flat[0][phys], flat[1][phys]
