"""Prefix index: full pages of prompt token ids -> shared read-only KV pages.

Production serving fleets overwhelmingly share prompt prefixes (system
prompts, few-shot preambles). Without sharing, PR 3's paged scheduler
reserves every request's full page need independently — N requests with
the same 400-token system prompt commit the same prefix pages N times and
recompute the same prefill N times. This index makes the prefix pages a
CACHE: after a request's prompt is fully prefilled, each of its FULL
prompt pages is registered under a chain hash of the token ids it covers;
a later request whose prompt starts with the same tokens ``retain``s the
matched pages into its own page table and prefills only the unmatched
tail.

Keys are hash CHAINS, not per-page hashes: page ``j``'s key is the tuple
of digests of pages ``0..j``, so a match is always a contiguous prefix
(matching page ``j`` implies pages ``0..j-1`` matched too) and two prompts
that share page ``j``'s tokens but differ earlier never collide.

Ownership: the index holds ONE allocator reference per cached page, taken
at insert and released at eviction — cached pages survive the inserting
request's retirement (that is what makes it a cache, not borrowing).
``evict_for`` drops least-recently-used entries (with their chain
descendants — a child whose ancestor is gone is unreachable by ``match``
and would leak) when the admission path runs short of free pages;
``release_all`` drops everything (end-of-run accounting: the pool must
return to zero pages in use).

Granularity caveat: only FULL pages are shareable — a prefix is matched in
``page_size``-token units, so up to ``page_size - 1`` trailing shared
tokens are recomputed by the new request. The matched pages are read-only
(refcount > 1); the scheduler copy-on-writes before any write lands in one
(``allocator.cow`` + ``paged.copy_page``), which only triggers when a
prompt is matched IN FULL on a page boundary and its last token must be
re-run for logits.

Recurrent families (zamba2): attention KV pages alone do not capture a
prefix — ssm/conv state at the boundary is part of it. Entries can carry a
per-boundary ``state`` snapshot (host arrays of the recurrent cache rows
at exactly ``(j + 1) * page_size`` tokens, captured by the server when a
prefill wave ends on the boundary); ``match(need_state=True)`` only
accepts boundaries that have one, and strictly inside the prompt (the
rollback token re-run needs state at ``boundary - 1``, which no snapshot
covers).
"""
from __future__ import annotations

from collections import OrderedDict
import dataclasses
import hashlib
from typing import Any

import numpy as np

from repro.kvcache.allocator import PageAllocator


def _digest(tokens: np.ndarray) -> bytes:
    # 128-bit digests: prompt tokens are USER-CONTROLLED hash input, and a
    # collision would serve another request's KV pages as a false prefix
    # hit (cross-request cache poisoning) — 64 bits is birthday-attackable
    return hashlib.blake2b(
        np.ascontiguousarray(tokens, dtype=np.int64).tobytes(),
        digest_size=16,
    ).digest()


@dataclasses.dataclass
class _Entry:
    page: int                      # physical page id (index holds one ref)
    state: dict[str, Any] | None   # recurrent rows at the boundary, or None
    state_bytes: int = 0           # host bytes the snapshot pins (0 if None)


def _state_nbytes(state: dict[str, Any]) -> int:
    return sum(np.asarray(v).nbytes for v in state.values())


class PrefixIndex:
    """Chain-hash map from full prompt pages to shared physical pages.

    ``state_budget`` (bytes, 0 = unbounded) caps the TOTAL host memory the
    recurrent boundary-state snapshots may pin. Snapshots are a per-entry
    sidecar, not the entry itself: when the budget is exceeded, the
    least-recently-used entries lose their snapshot (``state = None``)
    while their page entry — and the KV reuse it enables for attention
    families — stays indexed. A recurrent-family ``match(need_state=True)``
    simply walks back to the deepest boundary that still has one (or
    misses and prefills in full), so budget pressure degrades hit DEPTH,
    never correctness. A single snapshot larger than the whole budget is
    refused outright."""

    def __init__(self, page_size: int, allocator: PageAllocator,
                 state_budget: int = 0):
        self.page_size = page_size
        self.alloc = allocator
        self.state_budget = state_budget
        # key = tuple of per-page digests for pages 0..j; LRU order
        self._entries: OrderedDict[tuple, _Entry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.inserted = 0
        self.evicted = 0
        self.state_bytes = 0       # snapshot bytes currently held
        self.states_evicted = 0    # snapshots dropped (budget or refused)

    # -- queries ------------------------------------------------------------

    @property
    def pages_held(self) -> int:
        """Allocator references currently held by the index (one/entry)."""
        return len(self._entries)

    def _chain(self, prompt: np.ndarray):
        """Yield (key, page_index) for every FULL page of ``prompt``."""
        key: tuple = ()
        for j in range(len(prompt) // self.page_size):
            key = key + (_digest(
                prompt[j * self.page_size:(j + 1) * self.page_size]
            ),)
            yield key, j

    def match(self, prompt: np.ndarray, *, need_state: bool = False,
              record: bool = True
              ) -> tuple[int, list[int], dict[str, Any] | None]:
        """Longest indexed prefix of ``prompt``, in whole pages.

        Returns ``(n_tokens, pages, state)``: the shared token count (a
        multiple of ``page_size``), the physical pages backing it (NOT yet
        retained — the caller retains once it commits to admission), and
        the boundary's recurrent-state snapshot (``need_state`` only).

        ``need_state`` restricts the match to boundaries carrying a
        snapshot and strictly inside the prompt; without it a full-prompt
        match is allowed (the caller rolls back one token and
        copy-on-writes the boundary page to recompute its logits).

        ``record=False`` makes the lookup a DRY RUN: no hit/miss counting
        and no LRU reordering — the admission path probes with it on every
        scheduler retry while blocked on the pool, then calls
        :meth:`record` once it actually commits (otherwise a request
        stalled for K steps would count K+1 hits and churn the LRU)."""
        pages: list[int] = []
        states: list[dict | None] = []
        for key, _j in self._chain(prompt):
            e = self._entries.get(key)
            if e is None:
                break
            pages.append(e.page)
            states.append(e.state)
        if need_state:
            # walk back to the deepest usable boundary: has a snapshot and
            # leaves at least one prompt token to prefill
            while pages and (
                states[-1] is None
                or len(pages) * self.page_size >= len(prompt)
            ):
                pages.pop()
                states.pop()
        n = len(pages) * self.page_size
        if record:
            self.record(prompt, n)  # ONE accounting path for both modes
        if not pages:
            return 0, [], None
        return n, pages, (states[-1] if need_state else None)

    def record(self, prompt: np.ndarray, n_tokens: int) -> None:
        """Commit a ``record=False`` match: count the hit/miss and touch
        the matched entries' LRU positions. Entries evicted between the
        probe and the commit (the caller's own ``evict_for``) are simply
        skipped — the caller retained their pages, so the reuse stands."""
        if n_tokens == 0:
            self.misses += 1
            return
        self.hits += 1
        self.hit_tokens += n_tokens
        for key, j in self._chain(prompt):
            if (j + 1) * self.page_size > n_tokens:
                break
            if key in self._entries:
                self._entries.move_to_end(key)

    # -- mutation -----------------------------------------------------------

    def insert(self, prompt: np.ndarray, pages: list[int],
               states: dict[int, dict[str, Any]] | None = None) -> int:
        """Register every full page of a COMPLETELY prefilled prompt.

        ``pages`` is the request's logical page list (shared prefix pages
        it retained at admission simply re-hit their existing entries —
        no double reference). ``states`` maps boundary token counts
        (``(j + 1) * page_size``) to recurrent-row snapshots; pages whose
        boundary lacks one are still indexed for KV-only (llama) matching.
        Returns the number of NEW entries created."""
        new = 0
        for key, j in self._chain(prompt):
            if key in self._entries:
                e = self._entries[key]
                if e.state is None:  # a later request computed the boundary
                    self._store_state(
                        e, (states or {}).get((j + 1) * self.page_size)
                    )
                self._entries.move_to_end(key)
                continue
            page = pages[j]
            self.alloc.retain([page])
            e = _Entry(page=page, state=None)
            self._entries[key] = e
            self._store_state(e, (states or {}).get((j + 1) * self.page_size))
            new += 1
        self.inserted += new
        return new

    def _store_state(self, entry: _Entry, state: dict[str, Any] | None):
        """Attach a boundary snapshot to ``entry`` under the size budget:
        over-budget storage drops snapshots from LRU entries first (the
        fresh one is hottest); a snapshot alone exceeding the budget is
        refused."""
        if state is None:
            return
        nbytes = _state_nbytes(state)
        if self.state_budget and nbytes > self.state_budget:
            self.states_evicted += 1  # refused at the door
            return
        entry.state = state
        entry.state_bytes = nbytes
        self.state_bytes += nbytes
        if not self.state_budget:
            return
        while self.state_bytes > self.state_budget:
            victim = next(
                (e for e in self._entries.values()
                 if e.state is not None and e is not entry),
                None,
            )
            if victim is None:
                break
            self._drop_state(victim)

    def _drop_state(self, entry: _Entry) -> None:
        if entry.state is None:
            return
        entry.state = None
        self.state_bytes -= entry.state_bytes
        entry.state_bytes = 0
        self.states_evicted += 1

    def evict_for(self, n_pages: int) -> bool:
        """Release LRU entries until ``n_pages`` can be allocated.

        Only entries whose eviction actually RETURNS their page to the
        pool are considered (a page some live request still retains stays
        live when the index drops its ref — evicting such entries would
        destroy cache value for zero gain; the transient pressure resolves
        at the requests' retirement instead), and only CHAIN LEAVES (an
        entry with descendants would orphan them — ``match`` walks from
        the root, so a child of a missing ancestor is unreachable and its
        page ref leaks; descendants become evictable themselves once the
        leaves below them go). Victims are picked LRU-first among the
        eligible, one page per eviction. Returns whether the allocation is
        now possible. O(entries^2) victim scan — fine at pool scale."""
        while not self.alloc.can_alloc(n_pages):
            victim = None
            for key in self._entries:  # LRU order
                if self.alloc.refcount(self._entries[key].page) != 1:
                    continue  # a live request still reads this page
                if any(k != key and k[:len(key)] == key
                       for k in self._entries):
                    continue  # not a leaf: evicting would orphan children
                victim = key
                break
            if victim is None:
                return False  # nothing evictable frees a page: keep the cache
            e = self._entries.pop(victim)
            self._drop_state(e)
            self.alloc.free([e.page])
            self.evicted += 1
        return True

    def audit(self) -> None:
        """Invariant check against the allocator; raises AssertionError.

        Every indexed page must still be LIVE in the allocator with at
        least the index's own reference — if a victim preemption had
        returned an index-held page to the free pool, the next prefix hit
        would retain a recycled page and serve another request's KV rows
        (use-after-free). The serving runtime calls this after every
        preemption: shared pages are never victim-released, they only
        lose the victim's reference. Also checks the snapshot-bytes
        ledger matches the entries' sidecars."""
        for key, e in self._entries.items():
            if self.alloc.refcount(e.page) < 1:
                raise AssertionError(
                    f"prefix entry (depth {len(key)}) holds freed page "
                    f"{e.page}")
        held = sum(e.state_bytes for e in self._entries.values())
        if held != self.state_bytes:
            raise AssertionError(
                f"state-bytes ledger {self.state_bytes} != sum of entry "
                f"sidecars {held}")

    def release_all(self) -> None:
        """Drop every cached reference (explicit cache teardown)."""
        while self._entries:
            _, e = self._entries.popitem(last=False)
            self._drop_state(e)
            self.alloc.free([e.page])
            self.evicted += 1

    # -- stats --------------------------------------------------------------

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "pages_held": self.pages_held,
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "inserted": self.inserted,
            "evicted": self.evicted,
            "states_held": sum(
                1 for e in self._entries.values() if e.state is not None
            ),
            "state_bytes": self.state_bytes,
            "states_evicted": self.states_evicted,
        }
