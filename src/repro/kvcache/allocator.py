"""Block-table page allocator for the paged KV cache.

Host-side bookkeeping for a fixed pool of KV pages: every request owns a
list of physical page ids that back its logical token positions
(``logical_page i`` of a request -> ``pages[i]``). The device never sees
this object — the server materialises the per-slot page table as an int32
array and passes it into the jitted prefill/decode functions.

Pages are reference-counted: ``alloc`` hands out pages at refcount 1,
``retain`` bumps a page shared between owners (prefix sharing — the device
write path assumes refcount 1 for pages being written), and ``free``
decrements, returning the page to the free list when the count reaches
zero. The free list is LIFO so recently-retired pages (hot in cache on a
real host) are reused first.

``cow`` is the copy-on-write bookkeeping half: an owner about to WRITE a
page calls it; a page at refcount 1 is returned unchanged (already the
exclusive writer), a shared page trades this owner's claim for a fresh
refcount-1 page (the caller copies the device contents and swaps its
page-table entry — see ``kvcache.paged.copy_page``).

Invariants (pinned by tests/test_kvcache_alloc.py):
* a live page is never handed out twice,
* ``free + in_use == total`` at all times,
* a page's refcount equals its owner count (shared pages have > 1),
* every page being written has refcount 1 (``cow`` restores this),
* freeing every owner returns the pool to zero pages in use (no leaks).
"""
from __future__ import annotations

from typing import Iterable


class OutOfPages(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free pool."""


class PageAllocator:
    def __init__(self, num_pages: int, base: int = 0):
        """``base`` offsets every page id by a constant: replica ``r`` of a
        data-parallel group owns global ids ``[r*n, (r+1)*n)`` of one shared
        pool array, so page-table entries written by different replicas
        never collide while each replica's accounting stays host-local."""
        if num_pages <= 0:
            raise ValueError(f"num_pages must be positive, got {num_pages}")
        if base < 0:
            raise ValueError(f"base must be non-negative, got {base}")
        self.num_pages = num_pages
        self.base = base
        self._free: list[int] = list(range(base + num_pages - 1, base - 1, -1))
        self._refs: dict[int, int] = {}
        self.peak_in_use = 0
        self.cow_copies = 0
        self.peak_shared = 0

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return 0 <= n <= len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    # -- mutation -----------------------------------------------------------

    def alloc(self, n: int) -> list[int]:
        """Allocate ``n`` pages at refcount 1; raises OutOfPages if short."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise OutOfPages(
                f"need {n} pages, only {len(self._free)} free "
                f"of {self.num_pages}"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def retain(self, pages: Iterable[int]) -> None:
        """Add one owner to already-live pages (copy-on-write sharing)."""
        for p in pages:
            if p not in self._refs:
                raise KeyError(f"retain of free page {p}")
            self._refs[p] += 1
        self.peak_shared = max(self.peak_shared, self.shared)

    def cow(self, page: int) -> tuple[int, bool]:
        """Make the caller the EXCLUSIVE writer of ``page``'s contents.

        Returns ``(page, False)`` when the caller already is (refcount 1).
        Otherwise allocates a fresh page, moves the caller's claim onto it
        (one ref dropped from the shared page) and returns
        ``(new_page, True)`` — the caller must then copy the device
        contents across and swap its page-table entry before writing.
        Raises :class:`OutOfPages` when the pool cannot supply the copy."""
        ref = self._refs.get(page)
        if ref is None:
            raise KeyError(f"cow of free page {page}")
        if ref == 1:
            return page, False
        [fresh] = self.alloc(1)
        self._refs[page] = ref - 1  # caller's claim moves to the fresh page
        self.cow_copies += 1
        return fresh, True

    def truncate(self, pages: list[int], keep: int) -> list[int]:
        """Release the TAIL of an owner's page list: drops one reference
        from each of ``pages[keep:]`` (shared pages survive under their
        other owners) and returns the kept prefix.

        This is the early-release half of speculative rollback: an
        owner whose logical high-water mark shrank permanently — e.g. the
        draft cache of a request that can no longer draft (the drafter is
        done one round before the target retires) — returns its unused
        tail to the pool without waiting for retirement. ``keep=0`` is a
        full release."""
        if keep < 0:
            raise ValueError(f"cannot keep {keep} pages")
        self.free(pages[keep:])
        return list(pages[:keep])

    def free(self, pages: Iterable[int]) -> int:
        """Drop one owner per page; pages at refcount 0 return to the pool.

        Returns how many pages were actually RETURNED to the free pool.
        Victim-preemption accounting relies on the distinction: a page
        shared with the prefix index (or another request) merely loses
        this owner's reference and stays live — shared pages are never
        victim-released, only private ones relieve pressure."""
        returned = 0
        for p in pages:
            ref = self._refs.get(p)
            if ref is None:
                raise KeyError(f"double free of page {p}")
            if ref == 1:
                del self._refs[p]
                self._free.append(p)
                returned += 1
            else:
                self._refs[p] = ref - 1
        return returned

    def audit(self) -> None:
        """Structural invariant check; raises AssertionError on corruption.

        Cheap enough (O(total pages)) to run after every preemption /
        growth event in the serving runtime and after every op in the
        property-test walk: free list has no duplicates and no live
        pages, every live page has refcount >= 1, every id is in range,
        and ``free + in_use == total`` holds exactly."""
        if len(set(self._free)) != len(self._free):
            raise AssertionError("free list contains duplicates")
        lo, hi = self.base, self.base + self.num_pages
        for p in self._free:
            if not lo <= p < hi:
                raise AssertionError(f"free page {p} out of range")
            if p in self._refs:
                raise AssertionError(f"page {p} is both free and live")
        for p, ref in self._refs.items():
            if not lo <= p < hi:
                raise AssertionError(f"live page {p} out of range")
            if ref < 1:
                raise AssertionError(f"live page {p} has refcount {ref}")
        if len(self._free) + len(self._refs) != self.num_pages:
            raise AssertionError(
                f"free ({len(self._free)}) + in_use ({len(self._refs)}) "
                f"!= total ({self.num_pages})")

    # -- stats --------------------------------------------------------------

    def fragmentation(self) -> float:
        """1 - (largest contiguous free run / free pages): 0 when the free
        pool is one run (or empty), approaching 1 as it shatters. Physical
        contiguity is irrelevant to correctness (the page table indirects
        every access) — this is a health metric for allocation locality."""
        if not self._free:
            return 0.0
        free = sorted(self._free)
        best = cur = 1
        for a, b in zip(free, free[1:]):
            cur = cur + 1 if b == a + 1 else 1
            best = max(best, cur)
        return 1.0 - best / len(free)

    @property
    def shared(self) -> int:
        """Pages currently owned by more than one owner."""
        return sum(1 for r in self._refs.values() if r > 1)

    def stats(self) -> dict:
        return {
            "total": self.num_pages,
            "free": self.free_pages,
            "in_use": self.in_use,
            "peak_in_use": self.peak_in_use,
            "shared": self.shared,
            "peak_shared": self.peak_shared,
            "cow_copies": self.cow_copies,
            "fragmentation": round(self.fragmentation(), 4),
        }


class PagePoolGroup:
    """Per-replica page pools over ONE device pool array.

    Data-parallel serving splits the physical pool into ``n_replicas``
    contiguous id ranges, one :class:`PageAllocator` each (replica ``r``
    owns global ids ``[r*n, (r+1)*n)``) — when the pool's PAGE dim is
    batch-sharded over the ``data`` mesh axis, a replica's pages, and all
    its COW/copy/rewind traffic, live on that replica's devices. Accounting
    stays host-side and replica-local; this object only routes.

    Allocation requests carry a ``replica``; id-taking operations (free /
    retain / cow / truncate / refcount) route by the page id itself.
    Aggregate queries (``in_use`` / ``stats()`` / ``audit()``) span all
    replicas, so single-pool callers and tests keep working unchanged for
    ``n_replicas == 1``."""

    def __init__(self, num_pages: int, n_replicas: int = 1):
        if n_replicas <= 0:
            raise ValueError(f"n_replicas must be positive, got {n_replicas}")
        if num_pages % n_replicas:
            raise ValueError(
                f"num_pages ({num_pages}) must divide evenly over "
                f"{n_replicas} replicas")
        self.num_pages = num_pages
        self.n_replicas = n_replicas
        self.per_replica = num_pages // n_replicas
        self.pools = [PageAllocator(self.per_replica, base=r * self.per_replica)
                      for r in range(n_replicas)]

    # -- routing ------------------------------------------------------------

    def replica_of(self, page: int) -> int:
        if not 0 <= page < self.num_pages:
            raise KeyError(f"page {page} out of range")
        return page // self.per_replica

    def pool(self, replica: int) -> PageAllocator:
        return self.pools[replica]

    def _by_replica(self, pages: Iterable[int]):
        buckets: dict[int, list[int]] = {}
        for p in pages:
            buckets.setdefault(self.replica_of(p), []).append(p)
        return buckets

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return sum(a.free_pages for a in self.pools)

    @property
    def in_use(self) -> int:
        return sum(a.in_use for a in self.pools)

    @property
    def shared(self) -> int:
        return sum(a.shared for a in self.pools)

    @property
    def peak_in_use(self) -> int:
        return sum(a.peak_in_use for a in self.pools)

    def can_alloc(self, n: int, replica: int = 0) -> bool:
        return self.pools[replica].can_alloc(n)

    def refcount(self, page: int) -> int:
        return self.pools[self.replica_of(page)].refcount(page)

    # -- mutation -----------------------------------------------------------

    def alloc(self, n: int, replica: int = 0) -> list[int]:
        return self.pools[replica].alloc(n)

    def retain(self, pages: Iterable[int]) -> None:
        for r, ps in self._by_replica(pages).items():
            self.pools[r].retain(ps)

    def cow(self, page: int) -> tuple[int, bool]:
        return self.pools[self.replica_of(page)].cow(page)

    def truncate(self, pages: list[int], keep: int) -> list[int]:
        # order-preserving: tail pages drop one ref in their own replica
        pages = list(pages)
        self.free(pages[keep:])
        return pages[:keep]

    def free(self, pages: Iterable[int]) -> int:
        return sum(self.pools[r].free(ps)
                   for r, ps in self._by_replica(pages).items())

    def audit(self) -> None:
        for a in self.pools:
            a.audit()

    def fragmentation(self) -> float:
        if not self.free_pages:
            return 0.0
        return sum(a.fragmentation() * a.free_pages
                   for a in self.pools) / self.free_pages

    def stats(self) -> dict:
        out = {
            "total": self.num_pages,
            "free": self.free_pages,
            "in_use": self.in_use,
            "peak_in_use": self.peak_in_use,
            "shared": self.shared,
            "peak_shared": sum(a.peak_shared for a in self.pools),
            "cow_copies": sum(a.cow_copies for a in self.pools),
            "fragmentation": round(self.fragmentation(), 4),
        }
        if self.n_replicas > 1:
            out["per_replica"] = [a.stats() for a in self.pools]
        return out
