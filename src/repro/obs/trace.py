"""Per-request lifecycle tracing for the serving stack.

Every request moves through ``queued -> admitted -> prefill(chunks) ->
decode/verify rounds -> retired | preempted (-> replay -> ...)``. The
:class:`Tracer` records one :class:`Span` per stage transition with
monotonic timestamps, so the operational numbers the paper's deployment
story needs fall out per request — TTFT (queued to first token), TPOT
(steady-state seconds per output token), queue wait, preemption/replay
overhead — plus per-request attribution of pages reserved and
prefix-cache hit tokens.

Span invariants (pinned by tests/test_obs.py):

* spans of one request are time-ordered (monotone start AND end times),
* the emitted-token counts over all spans sum to exactly ``len(out)``
  (every emitted token is attributed to the prefill wave, decode tick or
  verify round that produced it — no token is counted twice or lost),
* TTFT <= total latency; a preempted-and-restored request carries a
  ``replay`` span between its ``preempt`` and the prefill that restored
  it.

At retirement :meth:`Tracer.retire` folds the request's timings into the
registry histograms (``serve_ttft_seconds`` etc.), so the mergeable
aggregate and the exact per-request record come from one source.
:class:`NullTracer` is the no-op drop-in — tracing must never perturb
serving.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque


def now() -> float:
    """Monotonic timestamp — span arithmetic must survive clock steps."""
    return time.monotonic()


@dataclasses.dataclass
class Span:
    kind: str           # queued|admitted|prefill|decode|verify|preempt|
    #                     replay|retired
    t0: float
    t1: float
    emitted: int = 0    # tokens EMITTED by this span (sums to len(out))
    fed: int = 0        # prompt/replay tokens fed through prefill
    drafted: int = 0    # verify rounds: draft tokens proposed
    accepted: int = 0   # verify rounds: draft tokens that survived

    def as_dict(self) -> dict:
        d = {"kind": self.kind, "t0": self.t0, "t1": self.t1}
        for k in ("emitted", "fed", "drafted", "accepted"):
            v = getattr(self, k)
            if v:
                d[k] = v
        return d


class _Req:
    __slots__ = ("rid", "spans", "queued_t", "admitted_t", "retired_t",
                 "first_emit_t", "last_emit_t", "emitted", "status",
                 "replica", "prefix_hit_tokens", "pages_reserved",
                 "preemptions", "replay_tokens")

    def __init__(self, rid: int, t: float):
        self.rid = rid
        self.spans: list[Span] = [Span("queued", t, t)]
        self.queued_t = t
        self.admitted_t: float | None = None
        self.retired_t: float | None = None
        self.first_emit_t: float | None = None
        self.last_emit_t: float | None = None
        self.emitted = 0
        self.status = "queued"
        self.replica = 0
        self.prefix_hit_tokens = 0
        self.pages_reserved = 0
        self.preemptions = 0
        self.replay_tokens = 0


class Tracer:
    """Request-lifecycle recorder; one method call per server event."""

    def __init__(self):
        self._reqs: dict[int, _Req] = {}
        # token-granular (kind, seconds) latency observations for the SLO
        # loop: ("ttft", submit->first token) and ("tpot", inter-token
        # gap). Bounded so an unconsumed buffer (no SLO controller
        # attached) cannot grow past the window.
        self._live: deque = deque(maxlen=65536)

    @property
    def enabled(self) -> bool:
        return True

    def _req(self, rid: int, t: float | None = None) -> _Req:
        r = self._reqs.get(rid)
        if r is None:
            r = self._reqs[rid] = _Req(rid, now() if t is None else t)
        return r

    # -- lifecycle events ----------------------------------------------------

    def queued(self, rid: int, t: float | None = None) -> None:
        """``t`` backdates the queue entry (service front-end: the tenant
        queue wait belongs in TTFT, so submit time, not admission-queue
        entry, starts the clock)."""
        self._req(rid, t)

    def admitted(self, rid: int, *, replica: int = 0,
                 prefix_hit_tokens: int = 0, pages: int = 0) -> None:
        r = self._req(rid)
        t = now()
        if r.admitted_t is None:  # first admission ends the queue wait
            r.admitted_t = t
            r.prefix_hit_tokens = prefix_hit_tokens
        r.replica = replica
        r.pages_reserved = max(r.pages_reserved, pages)
        r.status = "active"
        r.spans.append(Span("admitted", t, t))

    def span(self, rid: int, kind: str, t0: float, t1: float, *,
             emitted: int = 0, fed: int = 0, drafted: int = 0,
             accepted: int = 0) -> None:
        """One prefill chunk / decode tick / verify round for ``rid``."""
        r = self._req(rid)
        r.spans.append(Span(kind, t0, t1, emitted=emitted, fed=fed,
                            drafted=drafted, accepted=accepted))
        r.emitted += emitted

    def emit(self, rid: int) -> None:
        """One token crossed to the caller (exact emission timestamp —
        span ends are wave-granular, this is token-granular)."""
        r = self._req(rid)
        t = now()
        if r.first_emit_t is None:
            r.first_emit_t = t
            self._live.append(("ttft", t - r.queued_t))
        else:
            self._live.append(("tpot", t - r.last_emit_t))
        r.last_emit_t = t

    def drain_observations(self) -> list[tuple[str, float]]:
        """Hand the buffered token-granular latency observations to the
        SLO loop and clear the buffer."""
        out = list(self._live)
        self._live.clear()
        return out

    def preempted(self, rid: int) -> None:
        r = self._req(rid)
        t = now()
        r.preemptions += 1
        r.status = "preempted"
        r.spans.append(Span("preempt", t, t))

    def replay(self, rid: int, tokens: int) -> None:
        """Re-admission of a preempted request: ``tokens`` prompt+emitted
        tokens will be re-prefilled to restore it."""
        r = self._req(rid)
        t = now()
        r.replay_tokens += tokens
        r.spans.append(Span("replay", t, t, fed=tokens))

    def retire(self, rid: int, status: str, registry=None) -> None:
        """Request finished (``ok``) or drained (``preempted``): close
        the trace and fold its timings into the registry histograms."""
        r = self._req(rid)
        t = now()
        r.retired_t = t
        r.status = status
        r.spans.append(Span("retired", t, t))
        if registry is None or not registry.enabled:
            return
        lbl = {"replica": r.replica}
        if r.admitted_t is not None:
            registry.histogram(
                "serve_queue_wait_seconds",
                "admission wait: queued to first admission",
            ).observe(r.admitted_t - r.queued_t, **lbl)
        if r.first_emit_t is not None:
            registry.histogram(
                "serve_ttft_seconds",
                "time to first token: queued to first emission",
            ).observe(r.first_emit_t - r.queued_t, **lbl)
        if (r.last_emit_t is not None and r.first_emit_t is not None
                and r.emitted > 1):
            registry.histogram(
                "serve_tpot_seconds",
                "steady-state seconds per output token",
            ).observe((r.last_emit_t - r.first_emit_t) / (r.emitted - 1),
                      **lbl)
        registry.histogram(
            "serve_request_latency_seconds",
            "queued to retirement",
        ).observe(t - r.queued_t, **lbl)

    # -- reads ---------------------------------------------------------------

    def request(self, rid: int) -> dict | None:
        r = self._reqs.get(rid)
        return None if r is None else self._describe(r)

    def _describe(self, r: _Req) -> dict:
        d = {
            "rid": r.rid, "status": r.status, "replica": r.replica,
            "emitted": r.emitted, "preemptions": r.preemptions,
            "replay_tokens": r.replay_tokens,
            "prefix_hit_tokens": r.prefix_hit_tokens,
            "pages_reserved": r.pages_reserved,
            "spans": [s.as_dict() for s in r.spans],
        }
        if r.admitted_t is not None:
            d["queue_wait_s"] = r.admitted_t - r.queued_t
        if r.first_emit_t is not None:
            d["ttft_s"] = r.first_emit_t - r.queued_t
        if (r.last_emit_t is not None and r.first_emit_t is not None
                and r.emitted > 1):
            d["tpot_s"] = ((r.last_emit_t - r.first_emit_t)
                           / (r.emitted - 1))
        if r.retired_t is not None:
            d["latency_s"] = r.retired_t - r.queued_t
        return d

    def requests(self) -> list[dict]:
        return [self._describe(r) for r in self._reqs.values()]

    def summary(self) -> dict:
        """Aggregate percentiles over retired requests — exact (from raw
        timestamps), unlike the bucket-resolution registry histograms."""
        done = [self._describe(r) for r in self._reqs.values()
                if r.retired_t is not None]
        out = {"requests": len(done)}
        for key in ("queue_wait_s", "ttft_s", "tpot_s", "latency_s"):
            vals = sorted(d[key] for d in done if key in d)
            if vals:
                out[key] = {
                    "n": len(vals),
                    "mean": sum(vals) / len(vals),
                    "p50": _pct(vals, 0.50),
                    "p95": _pct(vals, 0.95),
                    "p99": _pct(vals, 0.99),
                    "max": vals[-1],
                }
        return out


def _pct(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


class NullTracer(Tracer):
    """No-op tracer with the full :class:`Tracer` API."""

    @property
    def enabled(self) -> bool:
        return False

    def queued(self, rid, t=None):
        pass

    def admitted(self, rid, **kw):
        pass

    def span(self, rid, kind, t0, t1, **kw):
        pass

    def emit(self, rid):
        pass

    def drain_observations(self):
        return []

    def preempted(self, rid):
        pass

    def replay(self, rid, tokens):
        pass

    def retire(self, rid, status, registry=None):
        pass

    def request(self, rid):
        return None

    def requests(self):
        return []

    def summary(self):
        return {}
