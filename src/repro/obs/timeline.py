"""Per-tick scheduler timeline: the structured event log of a server run.

Replaces the server's old bare ``events: list[str]`` as the source of
truth for what the scheduler did and when. Every record is a plain dict —
monotonically sequenced (``seq``), stamped with the decode-tick clock
(``tick``, the same clock the fault injector fires on) and a monotonic
timestamp — carrying the wave type plus whatever scheduler state the
emitter sampled (active slots, pool free pages / fragmentation, spec
draft width, degraded flag, faults fired this tick). ``to_jsonl`` dumps
the buffer one JSON object per line (``--trace-out``).

The buffer is a RING: long serving runs must not grow host memory without
bound (the old string list did), so the ``cap`` newest records are kept
and ``dropped`` counts what fell off the front — exported as a metric and
asserted zero in the CI smokes, where the default cap is generous enough
that any drop means an event-volume bug.

Backward compatibility: :meth:`legacy_events` renders the records back
into the exact strings the old list held (``"prefill"``, ``"decode"``,
``"verify"``, ``"draft_prefill"``, ``"drain"``, ``"preempt:<rid>"``,
``"replay:<rid>"``) and ``BatchedServer.events`` is now a property over
it — existing tests and callers read the same strings from the new
source of truth.
"""
from __future__ import annotations

import json
import time
from collections import deque

DEFAULT_CAP = 100_000

# record kinds that existed in the old ``events`` string list, and how
# they rendered there; anything else is timeline-only detail. The spill
# tier's "spill"/"restore" render in the same "<kind>:<rid>" shape so
# ``server.events`` keeps telling the whole preemption story.
_LEGACY_PLAIN = ("prefill", "decode", "verify", "draft_prefill", "drain")
_LEGACY_RID = ("preempt", "replay", "spill", "restore")


class Timeline:
    """Ring-buffered structured event log for one server run."""

    def __init__(self, cap: int = DEFAULT_CAP):
        if cap < 0:
            raise ValueError(f"cap must be >= 0 (0 = unbounded), got {cap}")
        self.cap = cap
        self._buf: deque[dict] = deque(maxlen=cap or None)
        self.seq = 0          # records ever emitted (monotone)
        self.dropped = 0      # records that fell off the ring
        self.tick = -1        # decode-tick clock, set by the scheduler

    def set_tick(self, tick: int) -> None:
        self.tick = tick

    def emit(self, kind: str, **fields) -> None:
        rec = {"seq": self.seq, "tick": self.tick,
               "t": time.monotonic(), "kind": kind}
        rec.update(fields)
        if self.cap and len(self._buf) == self.cap:
            self.dropped += 1  # deque drops the oldest on append
        self._buf.append(rec)
        self.seq += 1

    # -- reads ---------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._buf)

    def records(self, kind: str | None = None) -> list[dict]:
        if kind is None:
            return list(self._buf)
        return [r for r in self._buf if r["kind"] == kind]

    def tail(self, n: int = 8) -> list[dict]:
        """The newest ``n`` records (stall diagnostics)."""
        return list(self._buf)[-n:]

    def legacy_events(self) -> list[str]:
        """The old ``server.events`` strings, rendered from the records."""
        out = []
        for r in self._buf:
            k = r["kind"]
            if k in _LEGACY_PLAIN:
                out.append(k)
            elif k in _LEGACY_RID:
                out.append(f"{k}:{r['rid']}")
        return out

    def to_jsonl(self, path) -> int:
        """Write one JSON object per line; returns records written. A
        ``meta`` head line carries the drop accounting so a consumer can
        tell a complete log from a ring that wrapped."""
        recs = self.records()
        with open(path, "w") as f:
            f.write(json.dumps({
                "kind": "meta", "events": self.seq,
                "dropped": self.dropped, "cap": self.cap,
            }) + "\n")
            for r in recs:
                f.write(json.dumps(r) + "\n")
        return len(recs)


def read_jsonl(path) -> tuple[dict, list[dict]]:
    """Load a ``to_jsonl`` dump back into (meta, records)."""
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if not lines or lines[0].get("kind") != "meta":
        raise ValueError(f"{path}: missing timeline meta head line")
    return lines[0], lines[1:]
