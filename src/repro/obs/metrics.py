"""Dependency-free metrics registry for the serving stack.

One :class:`Registry` per server run holds counters, gauges and histograms
(fixed log-spaced latency buckets), each optionally labeled — the serving
stack labels by ``replica`` (DP imbalance must be visible per replica),
and the registry itself carries constant labels (``family``, ``engine``)
stamped onto every exported series. The registry absorbs the ad-hoc stat
dicts the stack already produces (``PageAllocator.stats()``,
``PrefixIndex.stats()``, spec acceptance, resilience counters,
``FaultInjector.summary()``) behind two uniform read paths:

* :meth:`Registry.snapshot` — a plain nested dict for programmatic
  consumers (the stats builder, the bench, tests), and
* :meth:`Registry.to_prometheus` — the Prometheus text exposition format
  for scraping/files (``--metrics-out``), round-trippable through
  :func:`parse_prometheus` (which the CI smoke uses to assert the file
  actually parses).

Telemetry must never perturb serving: every operation here is a host-side
dict update, and :class:`NullRegistry` is a drop-in no-op with the same
API — the serving tests pin that greedy streams and compile counts are
bit-identical between the two.

A process-wide :func:`global_registry` exists for instrumentation that
has no server handle in scope (the kernel autotuner's cache hit/miss and
trial counters); exporters merge it in so one ``--metrics-out`` file
carries both.
"""
from __future__ import annotations

import json
import math
import re
import threading

# Fixed log-spaced latency buckets (seconds): 100 us doubling to ~52 s.
# Every histogram in the serving stack shares them so TTFT/TPOT/step-time
# distributions are comparable across runs and mergeable across replicas.
DEFAULT_TIME_BUCKETS: tuple[float, ...] = tuple(
    1e-4 * 2 ** i for i in range(20)
)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _labelkey(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


class _Family:
    """One named metric family; children are keyed by their label set."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self._children: dict[tuple, object] = {}

    def _child(self, labels: dict):
        key = _labelkey(labels)
        if key not in self._children:
            self._children[key] = self._new_child()
        return self._children[key]

    def series(self) -> list[tuple[dict, object]]:
        # list() first: the service's /metrics route exports from the
        # event-loop thread while the scheduler thread keeps writing, and
        # sorting a live dict view would see a mid-iteration resize
        return [(dict(k), v) for k, v in sorted(list(self._children.items()))]


class Counter(_Family):
    """Monotonically increasing count. ``inc(n, **labels)``."""

    kind = "counter"

    def _new_child(self):
        return 0.0

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        key = _labelkey(labels)
        self._children[key] = self._children.get(key, 0.0) + n

    def value(self, **labels) -> float:
        return self._children.get(_labelkey(labels), 0.0)

    def total(self) -> float:
        """Sum over every label set (the aggregate of a labeled family)."""
        return sum(self._children.values())


class Gauge(_Family):
    """Point-in-time value. ``set(v, **labels)``."""

    kind = "gauge"

    def _new_child(self):
        return 0.0

    def set(self, v: float, **labels) -> None:
        self._children[_labelkey(labels)] = float(v)

    def inc(self, n: float = 1, **labels) -> None:
        key = _labelkey(labels)
        self._children[key] = self._children.get(key, 0.0) + n

    def value(self, **labels) -> float:
        return self._children.get(_labelkey(labels), 0.0)

    def total(self) -> float:
        return sum(self._children.values())


class _HistValue:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets  # cumulative at export, raw here
        self.sum = 0.0
        self.count = 0


class Histogram(_Family):
    """Bucketed distribution over the shared log-spaced time buckets.

    ``observe(v, **labels)`` files ``v`` into its (non-cumulative) bucket;
    export produces the Prometheus cumulative ``_bucket{le=...}`` series
    plus ``_sum``/``_count``. :meth:`quantile` gives a bucket-resolution
    estimate (exact per-request percentiles come from the tracer, which
    keeps raw timestamps — histograms are the mergeable aggregate)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS):
        super().__init__(name, help)
        if list(buckets) != sorted(buckets) or len(set(buckets)) != len(
                buckets):
            raise ValueError(f"histogram {name}: buckets must be sorted "
                             f"and distinct")
        self.buckets = tuple(float(b) for b in buckets)

    def _new_child(self):
        return _HistValue(len(self.buckets) + 1)  # +1: the +Inf bucket

    def observe(self, v: float, **labels) -> None:
        h = self._child(labels)
        i = len(self.buckets)
        for j, b in enumerate(self.buckets):
            if v <= b:
                i = j
                break
        h.counts[i] += 1
        h.sum += v
        h.count += 1

    def quantile(self, q: float, **labels) -> float:
        """Bucket-upper-bound estimate of the ``q`` quantile (0..1)."""
        h = self._children.get(_labelkey(labels))
        if h is None or h.count == 0:
            return 0.0
        rank = q * h.count
        seen = 0
        for j, c in enumerate(h.counts):
            seen += c
            if seen >= rank and c:
                return (self.buckets[j] if j < len(self.buckets)
                        else self.buckets[-1])
        return self.buckets[-1]


# Quantiles summarized per histogram series in Registry.snapshot(): the
# stats builder and bench rows read p50/p90/p99 without re-deriving them
# from raw bucket counts at every call site.
SNAPSHOT_QUANTILES: tuple[float, ...] = (0.5, 0.9, 0.99)


def _series_quantiles(buckets: tuple[float, ...], h: "_HistValue") -> dict:
    """Bucket-resolution quantile summaries for one histogram series."""
    out = {}
    for q in SNAPSHOT_QUANTILES:
        if h.count == 0:
            out[f"p{int(q * 100)}"] = 0.0
            continue
        rank = q * h.count
        seen = 0
        val = buckets[-1]
        for j, c in enumerate(h.counts):
            seen += c
            if seen >= rank and c:
                val = buckets[j] if j < len(buckets) else buckets[-1]
                break
        out[f"p{int(q * 100)}"] = val
    return out


class Registry:
    """Named metric families with get-or-create accessors.

    ``const_labels`` are stamped onto every series at export (and into
    :meth:`snapshot`), so one scrape distinguishes the model family and
    engine without every instrumentation site threading them through."""

    def __init__(self, const_labels: dict | None = None):
        self.const_labels = dict(const_labels or {})
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(name, help, **kw)
                self._families[name] = fam
            elif not isinstance(fam, cls):
                raise TypeError(
                    f"metric {name} already registered as {fam.kind}")
            return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def merge(self, other: "Registry") -> None:
        """Fold ``other``'s series into this registry.

        Counters and histograms accumulate (bucket-wise for histograms —
        both sides must share bucket edges); gauges take ``other``'s value
        (last write wins, matching repeated ``set``). This is how
        quant-time metrics recorded into the global registry *before* a
        server exists surface in the service's per-run ``/metrics`` export
        without double-counting on repeated scrapes: the service merges
        once at startup, then exports with ``include_global=False`` — or
        callers simply re-merge into a fresh registry per export."""
        if not other.enabled:
            return
        with other._lock:
            fams = list(other._families.items())
        for name, fam in fams:
            if isinstance(fam, Histogram):
                mine = self.histogram(name, fam.help, buckets=fam.buckets)
                if mine.buckets != fam.buckets:
                    raise ValueError(
                        f"histogram {name}: bucket edges differ; refusing "
                        f"to merge misaligned distributions")
                for lbl, h in fam.series():
                    dst = mine._child(lbl)
                    for j, c in enumerate(h.counts):
                        dst.counts[j] += c
                    dst.sum += h.sum
                    dst.count += h.count
            elif isinstance(fam, Counter):
                mine = self.counter(name, fam.help)
                for lbl, v in fam.series():
                    mine.inc(v, **lbl)
            else:
                mine = self.gauge(name, fam.help)
                for lbl, v in fam.series():
                    mine.set(v, **lbl)

    # -- reads ---------------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return True

    def value(self, name: str, **labels) -> float:
        """One series' value (0.0 for an unknown name/label set)."""
        fam = self._families.get(name)
        if fam is None or isinstance(fam, Histogram):
            return 0.0
        return fam.value(**labels)

    def total(self, name: str) -> float:
        """Sum of a family over all label sets (0.0 when unknown)."""
        fam = self._families.get(name)
        if fam is None or isinstance(fam, Histogram):
            return 0.0
        return fam.total()

    def snapshot(self, include_global: bool = True) -> dict:
        """Plain-dict view of every family — the one read path the stats
        builder, the bench and the tests share. Histogram entries carry
        the shared bucket edges plus per-series (non-cumulative) counts,
        sum and count."""
        out: dict = {"const_labels": dict(self.const_labels), "metrics": {}}
        regs = [self]
        if include_global and self is not _global():
            regs.append(_global())
        for reg in regs:
            with reg._lock:  # concurrent scrape vs. family registration
                fams = sorted(reg._families.items())
            for name, fam in fams:
                if isinstance(fam, Histogram):
                    out["metrics"][name] = {
                        "type": fam.kind, "help": fam.help,
                        "buckets": list(fam.buckets),
                        "series": [
                            {"labels": lbl, "counts": list(h.counts),
                             "sum": h.sum, "count": h.count,
                             "quantiles": _series_quantiles(fam.buckets, h)}
                            for lbl, h in fam.series()
                        ],
                    }
                else:
                    out["metrics"][name] = {
                        "type": fam.kind, "help": fam.help,
                        "series": [{"labels": lbl, "value": v}
                                   for lbl, v in fam.series()],
                    }
        return out

    def to_prometheus(self, include_global: bool = True) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        regs = [self]
        if include_global and self is not _global():
            regs.append(_global())
        seen: set[str] = set()
        for reg in regs:
            with reg._lock:  # concurrent scrape vs. family registration
                fams = sorted(reg._families.items())
            for name, fam in fams:
                if name in seen:
                    continue
                seen.add(name)
                if fam.help:
                    lines.append(f"# HELP {name} {fam.help}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for lbl, v in fam.series():
                    labels = {**self.const_labels, **lbl}
                    if isinstance(fam, Histogram):
                        cum = 0
                        for j, b in enumerate((*fam.buckets, math.inf)):
                            cum += v.counts[j]
                            lines.append(
                                f"{name}_bucket"
                                f"{_fmt_labels({**labels, 'le': _fmt_value(b)})}"
                                f" {cum}")
                        lines.append(f"{name}_sum{_fmt_labels(labels)} "
                                     f"{_fmt_value(v.sum)}")
                        lines.append(f"{name}_count{_fmt_labels(labels)} "
                                     f"{v.count}")
                    else:
                        lines.append(
                            f"{name}{_fmt_labels(labels)} {_fmt_value(v)}")
        return "\n".join(lines) + "\n"

    def dump(self, path) -> str:
        """Write the Prometheus snapshot to ``path``; returns the text."""
        text = self.to_prometheus()
        with open(path, "w") as f:
            f.write(text)
        return text


class NullRegistry(Registry):
    """No-op registry with the full :class:`Registry` API.

    Instrumented code calls it unconditionally; nothing is recorded. The
    serving bit-identity test runs the same workload against this and the
    real registry and asserts identical streams and compile counts."""

    def __init__(self):
        super().__init__()
        self._null_counter = _NullMetric()

    @property
    def enabled(self) -> bool:
        return False

    def counter(self, name: str, help: str = ""):
        return self._null_counter

    def gauge(self, name: str, help: str = ""):
        return self._null_counter

    def histogram(self, name: str, help: str = "", buckets=None):
        return self._null_counter

    def merge(self, other: "Registry") -> None:
        pass

    def snapshot(self, include_global: bool = True) -> dict:
        return {"const_labels": {}, "metrics": {}}

    def to_prometheus(self, include_global: bool = True) -> str:
        return ""


class _NullMetric:
    kind = "null"
    buckets = DEFAULT_TIME_BUCKETS

    def inc(self, n=1, **labels):
        pass

    def set(self, v, **labels):
        pass

    def observe(self, v, **labels):
        pass

    def value(self, **labels):
        return 0.0

    def total(self):
        return 0.0

    def quantile(self, q, **labels):
        return 0.0

    def series(self):
        return []


_GLOBAL: Registry | None = None


def _global() -> Registry:
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = Registry()
    return _GLOBAL


def global_registry() -> Registry:
    """Process-wide registry for instrumentation with no server handle in
    scope (autotune cache hits/misses, trial counts). Merged into every
    per-run export so one ``--metrics-out`` file carries both."""
    return _global()


def reset_global_registry() -> None:
    """Drop the process-wide registry (test isolation)."""
    global _GLOBAL
    _GLOBAL = None


# ---------------------------------------------------------------------------
# Prometheus text parsing (CI: "the exported file must actually parse")
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)\s*$"
)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus exposition text into ``{name: [(labels, value)]}``.

    Strict on sample lines: anything that is neither a comment, blank, nor
    a well-formed ``name{labels} value`` line raises ValueError — this is
    the CI assertion that ``--metrics-out`` produced a scrapeable file."""
    out: dict[str, list[tuple[dict, float]]] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable metrics line {ln}: {line!r}")
        labels = dict(_LABEL_RE.findall(m.group("labels") or ""))
        raw = m.group("value")
        value = math.inf if raw == "+Inf" else float(raw)
        out.setdefault(m.group("name"), []).append((labels, value))
    return out


def summarize_series(snapshot: dict) -> str:
    """One-line-per-family human summary of a snapshot (debug helper)."""
    lines = []
    for name, fam in snapshot.get("metrics", {}).items():
        if fam["type"] == "histogram":
            n = sum(s["count"] for s in fam["series"])
            lines.append(f"{name}: histogram n={n}")
        else:
            lines.append(f"{name}: {json.dumps([s['value'] for s in fam['series']])}")
    return "\n".join(lines)
