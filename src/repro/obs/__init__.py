"""Observability layer for the serving stack.

One bundle — :class:`Observability` — carries the three telemetry
surfaces threaded through ``launch/serve.py`` and friends:

* ``registry`` (:mod:`repro.obs.metrics`): counters / gauges /
  histograms with labels, ``snapshot()`` and a Prometheus-text exporter;
* ``tracer`` (:mod:`repro.obs.trace`): per-request lifecycle spans
  (TTFT, TPOT, queue wait, preemption/replay overhead);
* ``timeline`` (:mod:`repro.obs.timeline`): the ring-buffered per-tick
  scheduler event log that replaced ``BatchedServer.events``.

``Observability.disabled()`` swaps registry and tracer for no-ops but
keeps a REAL timeline: the ``server.events`` compat shim and the drop
accounting must behave identically in both modes, and the bit-identity
test (tests/test_obs.py) pins that enabled vs. disabled telemetry
produces the same greedy streams and compile counts.
"""
from __future__ import annotations

from .metrics import (DEFAULT_TIME_BUCKETS, NullRegistry, Registry,
                      global_registry, parse_prometheus,
                      reset_global_registry)
from .timeline import DEFAULT_CAP, Timeline, read_jsonl
from .trace import NullTracer, Span, Tracer
from .profile import JaxProfile, StepTimer, compile_counts, timeit

__all__ = [
    "DEFAULT_CAP", "DEFAULT_TIME_BUCKETS", "JaxProfile", "NullRegistry",
    "NullTracer", "Observability", "Registry", "Span", "StepTimer",
    "Timeline", "Tracer", "compile_counts", "global_registry",
    "parse_prometheus", "read_jsonl", "reset_global_registry", "timeit",
]


class Observability:
    """The telemetry bundle a :class:`BatchedServer` owns."""

    def __init__(self, *, registry: Registry | None = None,
                 tracer: Tracer | None = None,
                 timeline: Timeline | None = None,
                 trace_cap: int = DEFAULT_CAP,
                 const_labels: dict | None = None):
        if registry is None:
            registry = Registry(const_labels=const_labels)
        self.registry = registry
        self.tracer = tracer if tracer is not None else Tracer()
        self.timeline = (timeline if timeline is not None
                         else Timeline(cap=trace_cap))
        self.step_timer = StepTimer(self.registry)

    @classmethod
    def disabled(cls, *, trace_cap: int = DEFAULT_CAP) -> "Observability":
        """No-op registry/tracer, real timeline (events shim keeps working)."""
        return cls(registry=NullRegistry(), tracer=NullTracer(),
                   timeline=Timeline(cap=trace_cap))

    @property
    def enabled(self) -> bool:
        return self.registry.enabled

    def dump_metrics(self, path) -> None:
        """Write the Prometheus-text snapshot (``--metrics-out``)."""
        self.registry.dump(path)

    def dump_trace(self, path) -> int:
        """Write the timeline JSONL (``--trace-out``); returns records."""
        return self.timeline.to_jsonl(path)
