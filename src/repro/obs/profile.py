"""Host-side profiling hooks: step timing, shared benchmark timing, jit
compile-count gauges, and the optional ``jax.profiler`` trace gate.

``timeit`` is THE timing helper for the repo — the autotuner and
``benchmarks/kernel_bench.py`` both use it, so a "winner" in the tune
cache and a bench row are measured the same way: warmup call(s) first
(compile excluded), ``jax.block_until_ready`` on every iteration's
output (async dispatch excluded), MEDIAN of k iterations (one GC pause
or interrupt can no longer crown the wrong block shape the way a mean
could).

``StepTimer`` wraps the serving loop's jitted seams (prefill / decode /
verify / draft): it blocks on the step's output and files the host wall
time into a per-seam histogram. Blocking is observational — jitted step
values are unchanged — and the timer is only installed when the registry
is live, so a ``NullRegistry`` run pays nothing (the bit-identity test
pins both configurations to the same streams and compile counts).

``compile_counts`` reads each jitted function's compilation-cache size in
one place — the source for the ``decode_compiles``-style stats the tests
pin AND the ``serve_jit_compiles`` gauges the registry exports, replacing
scattered manual ``_cache_size()`` bookkeeping.

``JaxProfile`` gates ``jax.profiler`` around N decode ticks
(``--jax-profile DIR``): tick-bounded so a long serve run produces a
readable trace of its steady state, not an unboundedly large one.
"""
from __future__ import annotations

import statistics
import time


def timeit(f, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall seconds of ``f(*args)`` over ``iters`` timed runs.

    Each run blocks on the output (``jax.block_until_ready``) so async
    dispatch cannot hide device time; ``warmup`` untimed runs first so
    compilation never pollutes the measurement."""
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(f(*args))
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


class StepTimer:
    """Per-seam wall timing for the serving loop's jitted device steps."""

    def __init__(self, registry, name: str = "serve_step_seconds",
                 help: str = "host wall seconds per jitted serving step "
                             "(block_until_ready)"):
        self.registry = registry
        self.enabled = registry is not None and registry.enabled
        self._hist = registry.histogram(name, help) if self.enabled else None
        self.totals: dict[str, float] = {}
        self.counts: dict[str, int] = {}

    def run(self, seam: str, fn):
        """Execute ``fn()``; when live, block on its output and record the
        wall time under ``seam``. Pass-through when disabled."""
        if not self.enabled:
            return fn()
        import jax

        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self._hist.observe(dt, seam=seam)
        self.totals[seam] = self.totals.get(seam, 0.0) + dt
        self.counts[seam] = self.counts.get(seam, 0) + 1
        return out

    def summary(self) -> dict:
        """Per-seam totals — the tick-time breakdown (where a decode
        tick's wall time actually went)."""
        return {
            seam: {"total_s": self.totals[seam],
                   "count": self.counts[seam],
                   "mean_s": self.totals[seam] / self.counts[seam]}
            for seam in sorted(self.totals)
        }


def compile_counts(**jitted) -> dict[str, int]:
    """Compilation-cache sizes of jitted functions, by seam name.

    The single read path for compile discipline: the stats builder turns
    these into both the pinned ``*_compiles`` stats and the
    ``serve_jit_compiles{step=...}`` gauges."""
    out = {}
    for name, fn in jitted.items():
        if fn is None:
            continue
        try:
            out[name] = int(fn._cache_size())
        except AttributeError:  # not a jitted function (e.g. a plain fn)
            out[name] = 0
    return out


class JaxProfile:
    """Tick-gated ``jax.profiler`` trace around the serving loop.

    Starts the profiler at the first decode tick and stops it after
    ``ticks`` more (or at run end, whichever comes first). Profiler
    availability is probed defensively: a missing/broken profiler import
    must degrade to a no-op, never take down serving."""

    def __init__(self, outdir: str, ticks: int = 8):
        if ticks < 1:
            raise ValueError(f"profile ticks must be >= 1, got {ticks}")
        self.outdir = str(outdir)
        self.ticks = ticks
        self.active = False
        self.done = False
        self._start_tick: int | None = None

    def on_tick(self, tick: int) -> None:
        if self.done:
            return
        if not self.active:
            try:
                import jax

                jax.profiler.start_trace(self.outdir)
            except Exception:
                self.done = True  # profiler unavailable: stay a no-op
                return
            self.active = True
            self._start_tick = tick
        elif tick - self._start_tick >= self.ticks:
            self.stop()

    def stop(self) -> None:
        if not self.active:
            self.done = True
            return
        try:
            import jax

            jax.profiler.stop_trace()
        except Exception:
            pass
        self.active = False
        self.done = True
