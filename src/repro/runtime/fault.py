"""Fault tolerance & elasticity: heartbeats, straggler detection, retrying
step execution, elastic re-meshing after device loss.

Multi-host reality on one container: the mechanisms are host-count-agnostic
(file-based heartbeats keyed by host id; pure functions over timing
records), unit-tested with fake clocks, and wired into launch/train.py.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import signal
import time
from typing import Callable


# ---------------------------------------------------------------------------
# Heartbeats + straggler detection
# ---------------------------------------------------------------------------


class Heartbeat:
    """File-based per-host heartbeat (works on any shared filesystem)."""

    def __init__(self, root: str | pathlib.Path, host_id: int):
        self.dir = pathlib.Path(root)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.path = self.dir / f"host_{host_id:05d}.json"

    def beat(self, step: int, step_time_s: float, now: float | None = None):
        rec = {
            "host": self.host_id, "step": step,
            "step_time_s": step_time_s, "time": now or time.time(),
        }
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(rec))
        tmp.rename(self.path)

    @staticmethod
    def read_all(root: str | pathlib.Path) -> list[dict]:
        out = []
        for p in pathlib.Path(root).glob("host_*.json"):
            try:
                out.append(json.loads(p.read_text()))
            except (json.JSONDecodeError, OSError):
                continue
        return out


@dataclasses.dataclass
class StragglerReport:
    stragglers: list[int]        # hosts slower than k x median step time
    dead: list[int]              # hosts with stale heartbeats
    median_step_time: float


def detect_stragglers(
    records: list[dict], *, now: float, slow_factor: float = 2.0,
    dead_after_s: float = 120.0,
) -> StragglerReport:
    """Median-based straggler + liveness classification.

    At 1000+ node scale this runs on host 0 every N steps; stragglers get
    flagged for the scheduler (checkpoint-evict-replace), dead hosts
    trigger elastic re-mesh (see :func:`elastic_mesh_shape`).
    """
    if not records:
        return StragglerReport([], [], 0.0)
    alive = [r for r in records if now - r["time"] <= dead_after_s]
    dead = [r["host"] for r in records if now - r["time"] > dead_after_s]
    times = sorted(r["step_time_s"] for r in alive)
    med = times[len(times) // 2] if times else 0.0
    stragglers = [
        r["host"] for r in alive
        if med > 0 and r["step_time_s"] > slow_factor * med
    ]
    return StragglerReport(sorted(stragglers), sorted(dead), med)


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------


def elastic_mesh_shape(
    n_devices: int, *, model_parallel: int, prefer_pods: int = 1
) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest usable (pod, data, model) mesh after losing devices.

    Keeps the TP degree fixed (param shardings stay valid) and shrinks the
    data axis to the largest whole multiple: checkpoint restore handles the
    resharding (ZeRO states move), the data loader re-slices by host.
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot sustain model_parallel={model_parallel}"
        )
    data = n_devices // model_parallel
    if prefer_pods > 1 and data % prefer_pods == 0:
        return ((prefer_pods, data // prefer_pods, model_parallel),
                ("pod", "data", "model"))
    return ((data, model_parallel), ("data", "model"))


# ---------------------------------------------------------------------------
# Retry + preemption
# ---------------------------------------------------------------------------


class PreemptionGuard:
    """SIGTERM → finish the current step, checkpoint, exit cleanly."""

    def __init__(self):
        self.requested = False
        self._prev = None

    def install(self):
        def handler(signum, frame):
            self.requested = True
        self._prev = signal.signal(signal.SIGTERM, handler)
        return self

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)


def run_with_retries(
    step_fn: Callable[[], None], *, max_retries: int = 3,
    on_failure: Callable[[int, Exception], None] | None = None,
    retriable: tuple[type[Exception], ...] = (RuntimeError, OSError),
    non_retriable: tuple[type[Exception], ...] | None = None,
    base_delay_s: float = 1.0,
):
    """Execute one step with bounded retries (transient XLA/runtime faults
    at scale: preempted collectives, flaky interconnect).

    ``non_retriable`` exceptions surface immediately even when they
    subclass a retriable type. The default excludes ``OutOfPages``: pool
    exhaustion is a RuntimeError but it is a *deterministic* resource
    condition — retrying it would spin through the backoff loop while the
    scheduler (which owns preemption/eviction relief) never hears about
    it. ``base_delay_s`` scales the exponential backoff; pass 0 in tests
    and chaos harnesses so injected transient faults retry instantly."""
    if non_retriable is None:
        from repro.kvcache.allocator import OutOfPages
        non_retriable = (OutOfPages,)
    for attempt in range(max_retries + 1):
        try:
            return step_fn()
        except non_retriable:
            raise
        except retriable as e:  # noqa: PERF203
            if attempt == max_retries:
                raise
            if on_failure is not None:
                on_failure(attempt, e)
            time.sleep(min(base_delay_s * (2.0 ** attempt), 30.0))
