"""PartitionSpec rule engine: DP / TP / SP / EP / ZeRO-1 over the
(pod, data, model) production mesh.

Parameters are matched by path substring (first rule wins). Conventions:

* TP (Megatron, train/analysis mode): attention/MLP in-projections
  column-parallel (out dim on ``model``), out-projections row-parallel (in
  dim on ``model``); vocab sharded on ``model`` for embed/unembed; MoE
  experts sharded on ``model`` (classic EP: the dispatch scatter/gather
  becomes the all-to-all).
* Serving ("exact TP", :func:`serve_param_specs`): every matched weight —
  including packed ``PackedSplitQTensor``/``PackedSplitQGroup`` code and
  cluster-id planes — shards its OUTPUT (last) dim over ``model`` while the
  per-shard (S, Z) LUTs stay replicated, and :func:`act_constraint`
  replicates matmul inputs/outputs over ``model``. Contraction dims are
  never sharded, so GSPMD only ever inserts value-exact all-gathers (no
  partial-sum all-reduces) and greedy streams stay bit-identical to the
  single-device path.
* DP: params replicated over ``pod``/``data``; the batch dim of inputs and
  caches shards over ``("pod", "data")``.
* ZeRO-1: optimizer master/m/v additionally shard over ``data`` on the
  largest still-unsharded axis (uneven sizes fine — GSPMD pads).
* SP: the residual stream is constrained to P(batch, "model", None) between
  blocks (sequence-parallel) via :func:`act_constraint`, an ambient-mesh
  no-op outside pjit.

All divisibility checks come from the mesh instance (or explicit
``n_model``/``n_data``) passed in — there is no module-global mesh state,
so two meshes of different shapes can coexist in one process.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (substring, spec-builder(shape, n_model) -> P). Checked in order.
# Leading L axis (stacked layers) is never sharded.
_RULES: list[tuple[str, Any]] = []


def _rule(substr):
    def deco(fn):
        _RULES.append((substr, fn))
        return fn
    return deco


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0 and n >= by


# pjit *argument* shardings require exact divisibility (unlike
# intermediates, which GSPMD pads) — every rule checks before sharding.
def _last_on_model(shape, nm):
    if _div(shape[-1], nm):
        return P(*([None] * (len(shape) - 1) + ["model"]))
    if len(shape) >= 2 and _div(shape[-2], nm):
        return P(*([None] * (len(shape) - 2) + ["model", None]))
    return P()


def _secondlast_on_model(shape, nm):
    if _div(shape[-2], nm):
        return P(*([None] * (len(shape) - 2) + ["model", None]))
    if _div(shape[-1], nm):
        return P(*([None] * (len(shape) - 1) + ["model"]))
    return P()


# --- embeddings / heads: vocab on model (fallback: d_model) -----------------
@_rule("embed/table")
def _(shape, nm):
    if _div(shape[0], nm):
        return P("model", None)
    if _div(shape[1], nm):
        return P(None, "model")  # whisper: 51865 vocab not 16-divisible
    return P()


@_rule("lm_head/w")
def _(shape, nm):
    if _div(shape[1], nm):
        return P(None, "model")
    if _div(shape[0], nm):
        return P("model", None)
    return P()


# --- MoE (before generic attn/mlp rules) -------------------------------------
@_rule("moe/router")
def _(shape, nm):
    return P()  # tiny + routing-critical: replicated


def _experts(shape, nm):
    # (L, E, D, F): EP over experts when E divides, else F on model
    if _div(shape[1], nm):
        return P(None, "model", None, None)
    return P(None, None, None, "model") if _div(shape[3], nm) else P()


@_rule("experts/w_up")
def _(shape, nm):
    return _experts(shape, nm)


@_rule("experts/w_gate")
def _(shape, nm):
    return _experts(shape, nm)


@_rule("experts/w_down")
def _(shape, nm):
    return _experts(shape, nm)


# --- attention ---------------------------------------------------------------
@_rule("attn/wq")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("attn/wk")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("attn/wv")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("attn/wo")
def _(shape, nm):
    return _secondlast_on_model(shape, nm)


# --- dense MLP ---------------------------------------------------------------
@_rule("w_gate")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("w_up")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("w_down")
def _(shape, nm):
    return _secondlast_on_model(shape, nm)


# --- mamba2 -------------------------------------------------------------------
@_rule("in_proj")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("out_proj")
def _(shape, nm):
    return _secondlast_on_model(shape, nm)


@_rule("conv_w")
def _(shape, nm):
    return _last_on_model(shape, nm)  # depthwise channels on model


@_rule("conv_b")
def _(shape, nm):
    return _last_on_model(shape, nm)


# --- rwkv6 --------------------------------------------------------------------
@_rule("cm_wk")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("cm_wv")
def _(shape, nm):
    return _secondlast_on_model(shape, nm)


@_rule("cm_wr")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("tmix/wr")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("tmix/wk")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("tmix/wv")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("tmix/wg")
def _(shape, nm):
    return _last_on_model(shape, nm)


@_rule("tmix/wo")
def _(shape, nm):
    return _secondlast_on_model(shape, nm)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            # GetAttrKey: fields of registered dataclasses — this is how the
            # packed containers (codes/cids/scales/zeros) show up in trees
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def mesh_dims(mesh: Mesh) -> tuple[int, int]:
    """(n_data_total incl. pod, n_model) of a concrete mesh instance."""
    n_data = 1
    for a in BATCH_AXES:
        n_data *= mesh.shape.get(a, 1)
    return n_data, mesh.shape.get("model", 1)


# Tensors above this size additionally shard over `data` (FSDP / ZeRO-3
# style): llama4-scout's 100B expert bank cannot live TP-sharded only.
FSDP_THRESHOLD = 2 * 1024**3  # elements


def _add_data_axis(spec: P, shape: tuple[int, ...], n_data: int) -> P:
    """Shard the largest data-axis-divisible unsharded dim over `data`."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if "data" in parts:  # FSDP already claimed the data axis
        return P(*parts)
    best, best_size = None, 1
    for i, (pt, s) in enumerate(zip(parts, shape)):
        if pt is None and s > best_size and _div(s, n_data):
            best, best_size = i, s
    if best is None:
        return P(*parts)
    parts[best] = "data"
    return P(*parts)


def param_spec(path: str, shape: tuple[int, ...], *,
               n_model: int, n_data: int) -> P:
    spec = None
    for substr, fn in _RULES:
        if substr in path:
            spec = fn(shape, n_model)
            break
    if spec is None:
        return P()  # norms, scalars, time_* vectors: replicated
    size = 1
    for s in shape:
        size *= s
    if size >= FSDP_THRESHOLD:
        spec = _add_data_axis(spec, shape, n_data)
    return spec


def param_specs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree mirroring a param (or abstract param) pytree,
    with divisibility checked against the given mesh instance."""
    nd, nm = mesh_dims(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef,
        [param_spec(_path_str(p), tuple(l.shape), n_model=nm, n_data=nd)
         for p, l in flat],
    )


def zero1_spec(spec: P, shape: tuple[int, ...], n_data: int) -> P:
    """Add 'data' sharding on the largest divisible unsharded dim (ZeRO-1)."""
    return _add_data_axis(spec, shape, n_data)


def opt_specs(params: Any, mesh: Mesh) -> dict:
    """Sharding spec tree for the AdamW state of ``params``."""
    nd, nm = mesh_dims(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    zflat = [
        zero1_spec(
            param_spec(_path_str(p), tuple(l.shape), n_model=nm, n_data=nd),
            tuple(l.shape), nd,
        )
        for p, l in flat
    ]
    ztree = jax.tree_util.tree_unflatten(treedef, zflat)
    return {"step": P(), "master": ztree, "m": ztree, "v": ztree}


# ---------------------------------------------------------------------------
# Serving ("exact TP") param specs — packed containers included
# ---------------------------------------------------------------------------

# Weight names whose LAST dim is the matmul output dim in serving. Sharding
# only output dims keeps every contraction local to a device: the all-gather
# GSPMD inserts to re-replicate the product is value-exact, unlike the
# partial-sum all-reduce a row-parallel (contraction-sharded) layout needs.
_SERVE_LAST = (
    "attn/wq", "attn/wk", "attn/wv", "attn/wo", "attn/wqkv",
    "w_gate", "w_up", "w_gateup", "w_down",
    "lm_head/w", "in_proj", "out_proj",
)
# Quantized container planes: codes/cids pack along N (the output dim), so
# they shard exactly like the dense weight; the k-entry (S, Z) LUTs are a
# few floats per member and stay replicated — each shard reads its own
# device-local code plane against a local LUT copy.
_PACKED_SHARDED = ("codes", "cids", "qcodes", "planes")
_PACKED_REPLICATED = ("scales", "zeros", "info", "meta")


def serve_param_spec(path: str, shape: tuple[int, ...], n_model: int) -> P:
    """Output-stationary spec for one (possibly packed-container) leaf."""
    leafname = path.rsplit("/", 1)[-1]
    if "embed/table" in path:
        # one-hot @ table: a vocab-sharded contraction is exact (all partial
        # rows are exact zeros), and vocab is the big dim — shard it.
        if _div(shape[0], n_model):
            return P("model", *([None] * (len(shape) - 1)))
        if _div(shape[-1], n_model):
            return P(*([None] * (len(shape) - 1) + ["model"]))
        return P()
    matched = any(s in path for s in _SERVE_LAST)
    if not matched:
        return P()  # norms, rwkv/moe (follow-on), conv, scalars: replicated
    if leafname in _PACKED_REPLICATED:
        return P()
    # dense weight or a packed codes/cids plane: both keep N last
    if _div(shape[-1], n_model):
        return P(*([None] * (len(shape) - 1) + ["model"]))
    return P()


def serve_param_specs(params: Any, mesh: Mesh) -> Any:
    """Bit-exact-TP spec tree for an ``as_executable()`` (or fp) param tree."""
    _, nm = mesh_dims(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef,
        [serve_param_spec(_path_str(p), tuple(l.shape), nm) for p, l in flat],
    )


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

BATCH_AXES = ("pod", "data")


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel mesh axes actually present (pod is optional)."""
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def batch_specs(batch_like: Any, n_batch_shards: int,
                axes: tuple[str, ...] = BATCH_AXES) -> Any:
    """Shard the leading (batch) dim over pod×data when exactly divisible."""
    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        if _div(b, n_batch_shards):
            return P(axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))
    return jax.tree.map(spec, batch_like)


def cache_specs_tree(cache_like: Any, *, long_context: bool,
                     axes: tuple[str, ...] = BATCH_AXES,
                     n_dp: int = 1, n_model: int = 1,
                     decode: bool = False) -> Any:
    """KV caches: batch over pod×data. The model-axis placement is the
    decode-critical choice:

    * decode: the cache SEQUENCE dim shards over `model` (context-parallel
      decode) — per-shard partial attention combines with tiny per-head
      collectives, and the cache is NEVER gathered. Sharding kv-heads (or
      head_dim when GQA heads don't divide the TP degree) instead makes
      GSPMD all-gather the entire cache every token (~107 GB/step at
      internlm2 decode_32k — measured, see EXPERIMENTS §Perf).
    * prefill: kv-heads over model (head_dim fallback) — queries attend
      densely anyway and the head-parallel layout writes without traffic.
    * batch-1 long-context decode: sequence over `data` too."""

    def _kv_dims(kv: int, hd: int):
        if _div(kv, n_model):
            return "model", None
        if _div(hd, n_model):
            return None, "model"
        return None, None

    def spec(path, leaf):
        name = _path_str(path).split("/")[-1]
        shp = leaf.shape
        if name in ("kv", "shared_kv") and leaf.ndim == 6:
            # (L, 2, B, S, KV, hd)
            _, _, b, s, kv, hd = shp
            if long_context:
                seq = "data" if _div(s, n_dp) else None
                if decode and _div(s // max(n_dp, 1), n_model):
                    return P(None, None, None, ("data", "model"), None, None)
                return P(None, None, None, seq, *(_kv_dims(kv, hd)))
            bsp = axes if _div(b, n_dp) else None
            if decode and _div(s, n_model):
                return P(None, None, bsp, "model", None, None)
            kvs, hds = _kv_dims(kv, hd)
            return P(None, None, bsp, None, kvs, hds)
        if name in ("cross_k", "cross_v") and leaf.ndim == 5:
            # (L, B, S, KV, hd)
            _, b, s, kv, hd = shp
            kvs, hds = _kv_dims(kv, hd)
            if decode and _div(s, n_model):
                kvs, hds = None, None
                bsp = axes if _div(b, n_dp) else None
                return P(None, bsp, "model", kvs, hds)
            bsp = axes if (_div(b, n_dp) and not long_context) else None
            return P(None, bsp, None, kvs, hds)
        if name in ("ssm", "wkv") and leaf.ndim == 5:
            # (L, B, H, N, P)
            _, b, h, _, _ = shp
            bsp = axes if (_div(b, n_dp) and not long_context) else None
            hsp = "model" if _div(h, n_model) else None
            return P(None, bsp, hsp, None, None)
        if name in ("conv", "shift_t", "shift_c") and leaf.ndim >= 3:
            # (L, B, K-1, C) / (L, B, D): channels on model
            ch = "model" if _div(shp[-1], n_model) else None
            b = shp[1]
            bsp = axes if (_div(b, n_dp) and not long_context) else None
            return P(None, bsp, *([None] * (leaf.ndim - 3)), ch)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


def serve_cache_specs(cache_like: Any, mesh: Mesh) -> Any:
    """Spec tree for a serving cache (paged or dense) on a (data, model) mesh.

    Everything batch-shards its slot dim over the data axes; the page pool's
    PAGE dim shards over data too, so each DP replica's pages — and its
    ``cow()``/``copy_page()``/``rewind`` traffic — are device-local. Nothing
    lands on ``model`` (the exact-TP serving layout replicates activations
    over ``model``, so a model-sharded cache would just bounce)."""
    n_dp, _ = mesh_dims(mesh)
    axes = dp_axes(mesh)

    def spec(path, leaf):
        name = _path_str(path).split("/")[-1]
        shp = tuple(leaf.shape)
        nd = len(shp)

        def bat(dim):
            if _div(shp[dim], n_dp):
                return P(*[axes if i == dim else None for i in range(nd)])
            return P(*([None] * nd))

        if name in ("pages", "shared_pages") and nd == 6:
            return bat(2)       # (L, 2, PAGES, page, KV, hd): pool over data
        if name in ("kv", "shared_kv") and nd == 6:
            return bat(2)       # (L, 2, B, S, KV, hd)
        if name in ("cross_k", "cross_v") and nd == 5:
            return bat(1)
        if name in ("ssm", "wkv") and nd == 5:
            return bat(1)
        if name in ("conv", "shift_t", "shift_c") and nd >= 3:
            return bat(1)
        if name == "page_table" and nd == 2:
            return bat(0)       # (B, pages_per_row)
        if name == "len" and nd == 1:
            return bat(0)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


# ---------------------------------------------------------------------------
# Activation constraints (SP / exact-TP) — ambient mesh context
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def sharding_hints(mesh: Mesh, exact_tp: bool = False):
    """Ambient mesh for :func:`act_constraint`.

    ``exact_tp=True`` switches to the serving contract: matmul inputs and
    outputs replicate over ``model`` (only exact all-gathers, bit-identical
    streams) and kernel autotuning keys by the per-shard output width
    (:func:`tp_shards`)."""
    prev = (getattr(_CTX, "mesh", None), getattr(_CTX, "exact", False))
    _CTX.mesh, _CTX.exact = mesh, exact_tp
    try:
        yield
    finally:
        _CTX.mesh, _CTX.exact = prev


def tp_shards() -> int:
    """TP degree the current trace shards matmul outputs over (1 = none).

    Kernel wrappers divide their N by this to key the autotune cache by the
    per-shard matmul shape a device actually runs."""
    if not getattr(_CTX, "exact", False):
        return 1
    mesh = getattr(_CTX, "mesh", None)
    return mesh.shape.get("model", 1) if mesh is not None else 1


def _batch_axes_of(mesh: Mesh):
    return BATCH_AXES if "pod" in mesh.axis_names else ("data",)


def act_constraint(x: jax.Array, kind: str) -> jax.Array:
    """Constrain intermediate activations; no-op without ambient mesh.

    Train/analysis kinds: "residual" (B, S, D) -> sequence-parallel
    P(batch, model, None); "logits" (B, S, V) -> vocab on model; plus
    heads/tokens2d/expert_buf/heads5 (see body). Under ``exact_tp`` serving
    hints, "residual"/"logits"/"matmul_io" pin batch-over-data with
    everything else replicated (value-exact collectives only) and the
    remaining kinds are no-ops.
    """
    mesh = getattr(_CTX, "mesh", None)
    if mesh is None:
        return x
    batch = _batch_axes_of(mesh)
    n_model = mesh.shape.get("model", 1)
    if getattr(_CTX, "exact", False):
        if kind not in ("residual", "logits", "matmul_io"):
            return x
        n_dp, _ = mesh_dims(mesh)
        if x.ndim < 1:
            return x
        bdim = batch if _div(x.shape[0], n_dp) else None
        spec = P(bdim, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    if kind == "residual" and x.ndim == 3:
        bdim = batch if x.shape[0] > 1 else None
        spec = P(bdim, "model", None) if x.shape[1] > 1 else P(bdim, None, None)
    elif kind == "logits" and x.ndim == 3:
        spec = P(batch if x.shape[0] > 1 else None, None, "model")
    elif kind == "heads" and x.ndim == 4:
        # (B, S, H, hd): shard heads over model when they divide
        if x.shape[2] % n_model:
            return x
        bdim = batch if x.shape[0] > 1 else None
        spec = P(bdim, None, "model", None)
    elif kind == "tokens2d" and x.ndim == 2:
        # (T, D) flattened token stream (MoE dispatch/combine): keep fully
        # sharded over data x model so the combine lowers to reduce-scatter
        # instead of a full all-reduce of (T, D)
        if x.shape[0] % (mesh.shape.get("data", 1) * n_model):
            return x
        spec = P((*batch, "model"), None)
    elif kind == "expert_buf" and x.ndim == 3:
        # (E, C, D): experts over model (EP)
        if x.shape[0] % n_model:
            return x
        spec = P("model", None, None)
    elif kind == "heads5" and x.ndim == 5:
        # (B, n_chunks, Q, H, hd): stacked q-chunk layout — pin the head
        # sharding so the scan xs don't bounce through replication
        if x.shape[3] % n_model:
            return x
        bdim = batch if x.shape[0] > 1 else None
        spec = P(bdim, None, None, "model", None)
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# MeshPlan — one serving run's placement plan on one mesh instance
# ---------------------------------------------------------------------------


class MeshPlan:
    """Placement plan binding one ``BatchedServer`` run to one (data, model)
    mesh: canonical NamedShardings for params / cache / host batch arrays,
    plus the trace-time hints context. Holds no global state — two plans on
    two meshes coexist."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.axes = dp_axes(mesh)
        self.n_data, self.n_model = mesh_dims(mesh)

    def hints(self):
        return sharding_hints(self.mesh, exact_tp=True)

    def ns(self, spec_tree: Any) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    def put_params(self, params: Any) -> tuple[Any, Any]:
        """(device_put tree, sharding tree) under the exact-TP serve rules."""
        shd = self.ns(serve_param_specs(params, self.mesh))
        return jax.device_put(params, shd), shd

    def cache_shardings(self, cache: Any) -> Any:
        return self.ns(serve_cache_specs(cache, self.mesh))

    def put_cache(self, cache: Any, shardings: Any) -> Any:
        """(Re-)commit a cache tree to its canonical shardings.

        device_put on an already-matching leaf is a no-op; after host-side
        eager edits (page-table upload, COW page copies, snapshot installs)
        it restores the canonical layout so jitted-call input shardings stay
        byte-stable and decode compiles exactly once."""
        return jax.tree.map(jax.device_put, cache, shardings)

    def put_batch(self, arr: Any) -> jax.Array:
        """Host array -> device, leading dim over data when divisible."""
        a = np.asarray(arr)
        if a.ndim and _div(a.shape[0], self.n_data):
            spec = P(self.axes, *([None] * (a.ndim - 1)))
        else:
            spec = P(*([None] * a.ndim))
        return jax.device_put(a, NamedSharding(self.mesh, spec))

    def put_replicated(self, arr: Any) -> jax.Array:
        a = np.asarray(arr)
        return jax.device_put(
            a, NamedSharding(self.mesh, P(*([None] * a.ndim))))
