"""PartitionSpec rule engine: DP / TP / SP / EP / ZeRO-1 over the
(pod, data, model) production mesh.

Parameters are matched by path substring (first rule wins). Conventions:

* TP (Megatron): attention/MLP in-projections column-parallel (out dim on
  ``model``), out-projections row-parallel (in dim on ``model``); vocab
  sharded on ``model`` for embed/unembed; MoE experts sharded on ``model``
  (classic EP: the dispatch scatter/gather becomes the all-to-all).
* DP: params replicated over ``pod``/``data``; the batch dim of inputs and
  caches shards over ``("pod", "data")``.
* ZeRO-1: optimizer master/m/v additionally shard over ``data`` on the
  largest still-unsharded axis (uneven sizes fine — GSPMD pads).
* SP: the residual stream is constrained to P(batch, "model", None) between
  blocks (sequence-parallel) via :func:`act_constraint`, an ambient-mesh
  no-op outside pjit.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (substring, spec-builder(shape) -> P). Checked in order.
# Leading L axis (stacked layers) is never sharded.
_RULES: list[tuple[str, Any]] = []


def _rule(substr):
    def deco(fn):
        _RULES.append((substr, fn))
        return fn
    return deco


# pjit *argument* shardings require exact divisibility (unlike
# intermediates, which GSPMD pads) — every rule checks before sharding.
N_MODEL = 16  # production TP degree; overridden via set_mesh_dims
N_DATA = 16


def set_mesh_dims(n_data: int, n_model: int):
    """Configure divisibility checks for the active mesh (called by steps)."""
    global N_MODEL, N_DATA
    N_MODEL, N_DATA = n_model, n_data


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0 and n >= by


def _last_on_model(shape):
    if _div(shape[-1], N_MODEL):
        return P(*([None] * (len(shape) - 1) + ["model"]))
    if len(shape) >= 2 and _div(shape[-2], N_MODEL):
        return P(*([None] * (len(shape) - 2) + ["model", None]))
    return P()


def _secondlast_on_model(shape):
    if _div(shape[-2], N_MODEL):
        return P(*([None] * (len(shape) - 2) + ["model", None]))
    if _div(shape[-1], N_MODEL):
        return P(*([None] * (len(shape) - 1) + ["model"]))
    return P()


# --- embeddings / heads: vocab on model (fallback: d_model) -----------------
@_rule("embed/table")
def _(shape):
    if _div(shape[0], N_MODEL):
        return P("model", None)
    if _div(shape[1], N_MODEL):
        return P(None, "model")  # whisper: 51865 vocab not 16-divisible
    return P()


@_rule("lm_head/w")
def _(shape):
    if _div(shape[1], N_MODEL):
        return P(None, "model")
    if _div(shape[0], N_MODEL):
        return P("model", None)
    return P()


# --- MoE (before generic attn/mlp rules) -------------------------------------
@_rule("moe/router")
def _(shape):
    return P()  # tiny + routing-critical: replicated


def _experts(shape):
    # (L, E, D, F): EP over experts when E divides, else F on model
    if _div(shape[1], N_MODEL):
        return P(None, "model", None, None)
    return P(None, None, None, "model") if _div(shape[3], N_MODEL) else P()


@_rule("experts/w_up")
def _(shape):
    return _experts(shape)


@_rule("experts/w_gate")
def _(shape):
    return _experts(shape)


@_rule("experts/w_down")
def _(shape):
    return _experts(shape)


# --- attention ---------------------------------------------------------------
@_rule("attn/wq")
def _(shape):
    return _last_on_model(shape)


@_rule("attn/wk")
def _(shape):
    return _last_on_model(shape)


@_rule("attn/wv")
def _(shape):
    return _last_on_model(shape)


@_rule("attn/wo")
def _(shape):
    return _secondlast_on_model(shape)


# --- dense MLP ---------------------------------------------------------------
@_rule("w_gate")
def _(shape):
    return _last_on_model(shape)


@_rule("w_up")
def _(shape):
    return _last_on_model(shape)


@_rule("w_down")
def _(shape):
    return _secondlast_on_model(shape)


# --- mamba2 -------------------------------------------------------------------
@_rule("in_proj")
def _(shape):
    return _last_on_model(shape)


@_rule("out_proj")
def _(shape):
    return _secondlast_on_model(shape)


@_rule("conv_w")
def _(shape):
    return _last_on_model(shape)  # depthwise channels on model


@_rule("conv_b")
def _(shape):
    return _last_on_model(shape)


# --- rwkv6 --------------------------------------------------------------------
@_rule("cm_wk")
def _(shape):
    return _last_on_model(shape)


@_rule("cm_wv")
def _(shape):
    return _secondlast_on_model(shape)


@_rule("cm_wr")
def _(shape):
    return _last_on_model(shape)


@_rule("tmix/wr")
def _(shape):
    return _last_on_model(shape)


@_rule("tmix/wk")
def _(shape):
    return _last_on_model(shape)


@_rule("tmix/wv")
def _(shape):
    return _last_on_model(shape)


@_rule("tmix/wg")
def _(shape):
    return _last_on_model(shape)


@_rule("tmix/wo")
def _(shape):
    return _secondlast_on_model(shape)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


# Tensors above this size additionally shard over `data` (FSDP / ZeRO-3
# style): llama4-scout's 100B expert bank cannot live TP-sharded only.
FSDP_THRESHOLD = 2 * 1024**3  # elements


def _add_data_axis(spec: P, shape: tuple[int, ...]) -> P:
    """Shard the largest data-axis-divisible unsharded dim over `data`."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if "data" in parts:  # FSDP already claimed the data axis
        return P(*parts)
    best, best_size = None, 1
    for i, (pt, s) in enumerate(zip(parts, shape)):
        if pt is None and s > best_size and _div(s, N_DATA):
            best, best_size = i, s
    if best is None:
        return P(*parts)
    parts[best] = "data"
    return P(*parts)


def param_spec(path: str, shape: tuple[int, ...]) -> P:
    spec = None
    for substr, fn in _RULES:
        if substr in path:
            spec = fn(shape)
            break
    if spec is None:
        return P()  # norms, scalars, time_* vectors: replicated
    size = 1
    for s in shape:
        size *= s
    if size >= FSDP_THRESHOLD:
        spec = _add_data_axis(spec, shape)
    return spec


def param_specs(params: Any) -> Any:
    """PartitionSpec pytree mirroring a param (or abstract param) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [param_spec(_path_str(p), tuple(l.shape)) for p, l in flat]
    )


def zero1_spec(spec: P, shape: tuple[int, ...]) -> P:
    """Add 'data' sharding on the largest divisible unsharded dim (ZeRO-1)."""
    return _add_data_axis(spec, shape)


def opt_specs(params: Any) -> dict:
    """Sharding spec tree for the AdamW state of ``params``."""
    pspecs = param_specs(params)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    zflat = [
        zero1_spec(param_spec(_path_str(p), tuple(l.shape)), tuple(l.shape))
        for p, l in flat
    ]
    ztree = jax.tree_util.tree_unflatten(treedef, zflat)
    return {"step": P(), "master": ztree, "m": ztree, "v": ztree}


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

BATCH_AXES = ("pod", "data")


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel mesh axes actually present (pod is optional)."""
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def batch_specs(batch_like: Any, n_batch_shards: int,
                axes: tuple[str, ...] = BATCH_AXES) -> Any:
    """Shard the leading (batch) dim over pod×data when exactly divisible."""
    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        if _div(b, n_batch_shards):
            return P(axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))
    return jax.tree.map(spec, batch_like)


def cache_specs_tree(cache_like: Any, *, long_context: bool,
                     axes: tuple[str, ...] = BATCH_AXES,
                     n_dp: int = 1, decode: bool = False) -> Any:
    """KV caches: batch over pod×data. The model-axis placement is the
    decode-critical choice:

    * decode: the cache SEQUENCE dim shards over `model` (context-parallel
      decode) — per-shard partial attention combines with tiny per-head
      collectives, and the cache is NEVER gathered. Sharding kv-heads (or
      head_dim when GQA heads don't divide the TP degree) instead makes
      GSPMD all-gather the entire cache every token (~107 GB/step at
      internlm2 decode_32k — measured, see EXPERIMENTS §Perf).
    * prefill: kv-heads over model (head_dim fallback) — queries attend
      densely anyway and the head-parallel layout writes without traffic.
    * batch-1 long-context decode: sequence over `data` too."""

    def _kv_dims(kv: int, hd: int):
        if _div(kv, N_MODEL):
            return "model", None
        if _div(hd, N_MODEL):
            return None, "model"
        return None, None

    def spec(path, leaf):
        name = _path_str(path).split("/")[-1]
        shp = leaf.shape
        if name in ("kv", "shared_kv") and leaf.ndim == 6:
            # (L, 2, B, S, KV, hd)
            _, _, b, s, kv, hd = shp
            if long_context:
                seq = "data" if _div(s, N_DATA) else None
                seq_m = None
                if decode and _div(s // max(N_DATA, 1), N_MODEL):
                    return P(None, None, None, ("data", "model"), None, None)
                return P(None, None, None, seq, *(_kv_dims(kv, hd)))
            bsp = axes if _div(b, n_dp) else None
            if decode and _div(s, N_MODEL):
                return P(None, None, bsp, "model", None, None)
            kvs, hds = _kv_dims(kv, hd)
            return P(None, None, bsp, None, kvs, hds)
        if name in ("cross_k", "cross_v") and leaf.ndim == 5:
            # (L, B, S, KV, hd)
            _, b, s, kv, hd = shp
            kvs, hds = _kv_dims(kv, hd)
            if decode and _div(s, N_MODEL):
                kvs, hds = None, None
                bsp = axes if _div(b, n_dp) else None
                return P(None, bsp, "model", kvs, hds)
            bsp = axes if (_div(b, n_dp) and not long_context) else None
            return P(None, bsp, None, kvs, hds)
        if name in ("ssm", "wkv") and leaf.ndim == 5:
            # (L, B, H, N, P)
            _, b, h, _, _ = shp
            bsp = axes if (_div(b, n_dp) and not long_context) else None
            hsp = "model" if _div(h, N_MODEL) else None
            return P(None, bsp, hsp, None, None)
        if name in ("conv", "shift_t", "shift_c") and leaf.ndim >= 3:
            # (L, B, K-1, C) / (L, B, D): channels on model
            ch = "model" if _div(shp[-1], N_MODEL) else None
            b = shp[1]
            bsp = axes if (_div(b, n_dp) and not long_context) else None
            return P(None, bsp, *([None] * (leaf.ndim - 3)), ch)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


# ---------------------------------------------------------------------------
# Activation constraints (SP) — ambient mesh context
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def sharding_hints(mesh: Mesh):
    _CTX.mesh = mesh
    try:
        yield
    finally:
        _CTX.mesh = None


def act_constraint(x: jax.Array, kind: str) -> jax.Array:
    """Constrain intermediate activations; no-op without ambient mesh.

    kinds: "residual" (B, S, D) -> sequence-parallel P(batch, model, None);
           "logits" (B, S, V) -> vocab on model.
    """
    mesh = getattr(_CTX, "mesh", None)
    if mesh is None:
        return x
    batch = BATCH_AXES if "pod" in mesh.axis_names else ("data",)
    n_model = mesh.shape.get("model", 1)
    if kind == "residual" and x.ndim == 3:
        bdim = batch if x.shape[0] > 1 else None
        spec = P(bdim, "model", None) if x.shape[1] > 1 else P(bdim, None, None)
    elif kind == "logits" and x.ndim == 3:
        spec = P(batch if x.shape[0] > 1 else None, None, "model")
    elif kind == "heads" and x.ndim == 4:
        # (B, S, H, hd): shard heads over model when they divide
        if x.shape[2] % n_model:
            return x
        bdim = batch if x.shape[0] > 1 else None
        spec = P(bdim, None, "model", None)
    elif kind == "tokens2d" and x.ndim == 2:
        # (T, D) flattened token stream (MoE dispatch/combine): keep fully
        # sharded over data x model so the combine lowers to reduce-scatter
        # instead of a full all-reduce of (T, D)
        if x.shape[0] % (mesh.shape.get("data", 1) * n_model):
            return x
        spec = P((*batch, "model"), None)
    elif kind == "expert_buf" and x.ndim == 3:
        # (E, C, D): experts over model (EP)
        if x.shape[0] % n_model:
            return x
        spec = P("model", None, None)
    elif kind == "heads5" and x.ndim == 5:
        # (B, n_chunks, Q, H, hd): stacked q-chunk layout — pin the head
        # sharding so the scan xs don't bounce through replication
        if x.shape[3] % n_model:
            return x
        bdim = batch if x.shape[0] > 1 else None
        spec = P(bdim, None, None, "model", None)
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
