"""Deterministic fault injection for the serving runtime.

A seeded :class:`FaultInjector` is threaded through the server's
allocator / prefill / decode / verify seams and fires faults at chosen
decode ticks, so chaos tests can force the exact failure they want to
study and assert recovery is EXACT (preempted-and-restored greedy
streams bit-identical to the uninterrupted run, zero page leaks).

Plan syntax — comma-separated entries, each ``kind[.seam]@when``::

    oop@tick7              force pool exhaustion at decode tick 7
                           (the server preempts a victim)
    fail@tick3             transient step failure (TransientFault) at
                           tick 3, retried by run_with_retries
    fail.decode@tick3      same, but only at the decode seam
    slow@tick5             inject latency at tick 5
    fail@p0.05             probabilistic: fire with prob 0.05 per
                           consult, from the injector's seeded rng

Tick entries are single-shot: they fire once at the first matching
consult and are then spent. Probability entries persist and draw from a
``numpy`` Generator seeded at construction — the whole fault schedule is
a pure function of (plan, seed, consult order), which is what makes
chaos runs replayable.

Seams: ``prefill`` / ``decode`` / ``verify`` step calls consult
:meth:`on_step` (slow + fail kinds); the page-growth path consults
:meth:`take("oop")`. Injected transient failures are safe to retry
because every device step is a pure jitted function over an immutable
cache pytree — re-running it cannot double-apply a write.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

_KINDS = ("oop", "fail", "slow")
SEAMS = ("prefill", "decode", "verify")


class TransientFault(RuntimeError):
    """Injected transient step failure — retriable by design."""


@dataclasses.dataclass
class _Entry:
    kind: str               # "oop" | "fail" | "slow"
    seam: str | None        # None = any seam of that kind
    tick: int               # -1 for probability entries
    prob: float = 0.0
    spent: bool = False

    def spec(self) -> str:
        where = self.kind if self.seam is None else f"{self.kind}.{self.seam}"
        when = f"p{self.prob}" if self.tick < 0 else f"tick{self.tick}"
        return f"{where}@{when}"


def parse_plan(plan: str) -> list[_Entry]:
    entries = []
    for raw in plan.split(","):
        raw = raw.strip()
        if not raw:
            continue
        try:
            where, when = raw.split("@", 1)
            kind, _, seam = where.partition(".")
            if kind not in _KINDS:
                raise ValueError(f"unknown fault kind {kind!r}")
            if seam and seam not in SEAMS:
                raise ValueError(f"unknown seam {seam!r}")
            if when.startswith("tick"):
                entries.append(_Entry(kind, seam or None, int(when[4:])))
            elif when.startswith("p"):
                p = float(when[1:])
                if not 0.0 <= p <= 1.0:
                    raise ValueError(f"probability out of range: {p}")
                entries.append(_Entry(kind, seam or None, -1, prob=p))
            else:
                raise ValueError(f"expected tickN or pF, got {when!r}")
        except ValueError as e:
            raise ValueError(f"bad fault plan entry {raw!r}: {e}") from None
    return entries


class FaultInjector:
    """Seeded, plan-driven fault source. See module docstring for syntax."""

    def __init__(self, plan: str = "", *, seed: int = 0,
                 slow_s: float = 0.01, registry=None):
        self.entries = parse_plan(plan)
        self.slow_s = slow_s
        self.tick = -1          # set by the server before each decode round
        self.fired: list[str] = []
        self.registry = registry  # optional obs registry (set by the server)
        self._rng = np.random.default_rng(seed)

    def set_tick(self, tick: int) -> None:
        self.tick = tick

    def take(self, kind: str, seam: str | None = None) -> bool:
        """Consume one matching fault for the current tick, if any."""
        for e in self.entries:
            if e.kind != kind or e.spent:
                continue
            if e.seam is not None and seam is not None and e.seam != seam:
                continue
            if e.tick >= 0:
                if e.tick != self.tick:
                    continue
                e.spent = True
            elif not (self._rng.random() < e.prob):
                continue
            self.fired.append(f"{e.spec()}:tick{self.tick}")
            if self.registry is not None:
                self.registry.counter(
                    "faults_injected_total", "chaos faults fired, by kind",
                ).inc(kind=kind)
            return True
        return False

    def on_step(self, seam: str) -> None:
        """Fail/slow hook wrapped around one device step (see serve.py)."""
        if self.take("slow", seam):
            time.sleep(self.slow_s)
        if self.take("fail", seam):
            raise TransientFault(
                f"injected {seam} failure at tick {self.tick}")

    def summary(self) -> dict:
        return {
            "plan": [e.spec() for e in self.entries],
            "fired": list(self.fired),
            "pending": sum(1 for e in self.entries
                           if e.tick >= 0 and not e.spent),
        }
