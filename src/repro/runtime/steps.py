"""Jitted, mesh-sharded step builders: the bridge between the model zoo and
the launcher/dry-run.

``build_train_step``/``build_serve_step`` return (jitted_fn, in_specs,
out_specs) with NamedShardings resolved against a concrete mesh. The same
builders serve the real trainer (CPU smoke / examples) and the dry-run
(lower+compile only).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import batch_shards
from repro.models.model import Model, build_model
from repro.optim import adamw
from repro.runtime import sharding as shd


def _ns(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_params(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_opt(model: Model):
    params = abstract_params(model)
    return jax.eval_shape(adamw.init_opt_state, params)


def build_train_step(
    model: Model, mesh: Mesh, opt_cfg: adamw.AdamWConfig, shape: ShapeConfig
):
    """Returns (step_fn, (params_shd, opt_shd, batch_shd), out_shardings)."""
    aparams = abstract_params(model)
    pspecs = shd.param_specs(aparams, mesh)
    ospecs = shd.opt_specs(aparams, mesh)
    batch_abs = model.input_specs(shape)
    bspecs = shd.batch_specs(batch_abs, batch_shards(mesh), shd.dp_axes(mesh))

    def train_step(params, opt, batch):
        with shd.sharding_hints(mesh):
            (loss, metrics), grads = jax.value_and_grad(
                model.train_loss, has_aux=True
            )(params, batch)
            params, opt, opt_metrics = adamw.apply_updates(
                opt_cfg, params, grads, opt
            )
        return params, opt, {**metrics, **opt_metrics}

    params_shd = _ns(mesh, pspecs)
    opt_shd = _ns(mesh, ospecs)
    batch_shd = _ns(mesh, bspecs)
    metrics_shd = None  # replicated by default
    fn = jax.jit(
        train_step,
        in_shardings=(params_shd, opt_shd, batch_shd),
        out_shardings=(params_shd, opt_shd, metrics_shd),
        donate_argnums=(0, 1),
    )
    return fn, (params_shd, opt_shd, batch_shd), (params_shd, opt_shd)


def build_serve_step(model: Model, mesh: Mesh, shape: ShapeConfig):
    """Prefill (kind=prefill) or single-token decode (kind=decode).

    Returns (step_fn, (params_shd, batch/token_shd, cache_shd), out desc).
    """
    cfg = model.cfg
    aparams = abstract_params(model)
    pspecs = shd.param_specs(aparams, mesh)
    params_shd = _ns(mesh, pspecs)
    long_ctx = shape.kind == "decode" and shape.global_batch < batch_shards(mesh)
    cache_abs = model.cache_specs(shape)
    cspecs = shd.cache_specs_tree(cache_abs, long_context=long_ctx,
                                  axes=shd.dp_axes(mesh),
                                  n_dp=batch_shards(mesh),
                                  n_model=mesh.shape.get("model", 1),
                                  decode=shape.kind == "decode")
    cache_shd = _ns(mesh, cspecs)
    batch_abs = model.input_specs(shape)
    bspecs = shd.batch_specs(batch_abs, batch_shards(mesh), shd.dp_axes(mesh))
    batch_shd = _ns(mesh, bspecs)
    n_model = mesh.shape.get("model", 1)
    vocab_ax = "model" if cfg.vocab_size % n_model == 0 else None
    b_ax = shd.dp_axes(mesh) if shape.global_batch % batch_shards(mesh) == 0 \
        and shape.global_batch >= batch_shards(mesh) else None
    logits_shd = NamedSharding(mesh, P(b_ax, None, vocab_ax))

    if shape.kind == "prefill":

        def serve_step(params, batch, cache):
            with shd.sharding_hints(mesh):
                return model.prefill(params, batch, cache)

    else:

        def serve_step(params, batch, cache):
            with shd.sharding_hints(mesh):
                return model.decode_step(params, batch["tokens"], cache)

    fn = jax.jit(
        serve_step,
        in_shardings=(params_shd, batch_shd, cache_shd),
        out_shardings=(logits_shd, cache_shd),
        donate_argnums=(2,),
    )
    return fn, (params_shd, batch_shd, cache_shd), (logits_shd, cache_shd)


def lower_cell(
    arch_cfg: ArchConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    opt_cfg: adamw.AdamWConfig | None = None,
):
    """Lower (not run) one (arch x shape) cell on a mesh: the dry-run unit."""
    model = build_model(arch_cfg)
    if shape.kind == "train":
        fn, (pshd, oshd, bshd), _ = build_train_step(
            model, mesh, opt_cfg or adamw.AdamWConfig(), shape
        )
        aparams = abstract_params(model)
        aopt = abstract_opt(model)
        abatch = model.input_specs(shape)
        return fn.lower(aparams, aopt, abatch)
    fn, (pshd, bshd, cshd), _ = build_serve_step(model, mesh, shape)
    aparams = abstract_params(model)
    abatch = model.input_specs(shape)
    acache = model.cache_specs(shape)
    return fn.lower(aparams, abatch, acache)
