"""Serving resilience: victim policy, replay restore, stall diagnostics,
and spec-decode degradation tracking.

The serving runtime (``launch/serve.py``) survives KV-pool pressure by
PREEMPTING a victim request — releasing its pages and re-admitting it
later by replaying prompt + emitted tokens through the ordinary prefill
path — instead of dying with an unhandled ``OutOfPages``. The pieces that
make that policy provable and debuggable live here, free of any server
state so they unit-test in isolation.

Deadlock-freedom argument (why on-demand page growth cannot wedge):

* admission validates that ONE request's end-to-end page need fits the
  whole pool, so a lone request can always finish;
* the OLDEST live request (smallest admission ``seq_no``) is always
  growth-exempt: :func:`pick_victim` never selects it, and when it needs pages
  the scheduler may preempt every other live request and evict every
  prefix-cache entry not retained by the oldest itself (entries it does
  retain are, by prefix contiguity, backed by pages it already owns);
* after that relief the pool holds only the oldest request's pages, and
  its remaining need fits by the admission bound — so the oldest always
  advances, retires, and promotes a new oldest. Forward progress is a
  strictly decreasing chain, never a cycle.

Victim order: lowest ``priority`` first, then youngest-by-emitted-tokens
(least work lost to replay), then latest-admitted. Replay is exact for
greedy streams: the replayed tokens re-enter through prefill (pinned
bit-identical to decode by the serving tests), and the final emitted
token is re-fed by the next decode step rather than re-sampled.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class SlotDiag:
    """One live slot's state at a stall, printable from the exception."""
    slot: int
    rid: int
    seq_len: int        # tokens the prefill path feeds (prompt or replay)
    fed: int            # tokens already prefilled
    emitted: int
    max_new: int
    pages_held: int
    pages_pending: int  # pages still needed to finish (pending reservation)

    def describe(self) -> str:
        return (f"slot {self.slot}: rid={self.rid} seq={self.fed}/"
                f"{self.seq_len} emitted={self.emitted}/{self.max_new} "
                f"pages={self.pages_held}+{self.pages_pending}pending")


class SchedulerStall(RuntimeError):
    """The scheduler made no progress while slots were live.

    Replaces the old bare ``RuntimeError("scheduler stalled with live
    slots")``: the message now carries every live slot's request id,
    prefill/emit progress, pages held and pending reservation (plus the
    pool's free-page count), so a stall is debuggable from the exception
    text alone. Reachable by design with ``--page-growth
    --no-preemption`` when the pool exhausts and nothing can retire."""

    def __init__(self, slots: list[SlotDiag], free_pages: int | None = None,
                 recent: list[dict] | None = None):
        self.slots = slots
        self.free_pages = free_pages
        self.recent = recent or []  # newest scheduler-timeline records
        pool = "" if free_pages is None else f" ({free_pages} pages free)"
        tail = ""
        if self.recent:
            tail = " | recent: " + ", ".join(
                f"t{r.get('tick', '?')}:{r.get('kind', '?')}"
                for r in self.recent
            )
        super().__init__(
            "scheduler stalled with live slots" + pool + ": "
            + "; ".join(d.describe() for d in slots) + tail
        )


def pick_victim(live: Iterable[tuple[int, object]], exempt_seq: int):
    """Choose the preemption victim among ``(slot, request)`` pairs.

    The request with ``seq_no == exempt_seq`` (the oldest live — the
    growth-exempt anchor of the deadlock-freedom argument above) is never
    picked. Order: lowest ``priority`` first, then fewest emitted tokens
    (youngest — cheapest replay), then latest-admitted. Returns the
    ``(slot, request)`` pair or ``None`` when only the exempt remains."""
    pool = [(i, r) for i, r in live if r.seq_no != exempt_seq]
    if not pool:
        return None
    return min(pool, key=lambda ir: (ir[1].priority, len(ir[1].out),
                                     -ir[1].seq_no))


def replay_sequence(prompt: np.ndarray, out: list[int]) -> np.ndarray:
    """Token sequence that restores a preempted request exactly.

    Prompt plus all emitted tokens EXCEPT the last: re-prefilling it
    rebuilds the cache to the pre-preemption fill length (positions,
    masks and recurrent state all recomputed by the ordinary prefill
    contract), and the final emitted token is then re-fed by the next
    decode step — no token is ever sampled twice, so greedy streams are
    bit-identical and sampled streams consume no extra rng draws."""
    if not out:
        return np.asarray(prompt, np.int32)
    return np.concatenate([np.asarray(prompt, np.int32),
                           np.asarray(out[:-1], np.int32)])


class AcceptanceWindow:
    """Trailing drafted-token acceptance record driving spec fallback.

    Records one 0/1 outcome per drafted token. Once the window is full
    and the acceptance rate sits below ``floor``, :meth:`degraded`
    reports True and the server decodes that request plainly for the
    round instead of paying draft forwards that verification keeps
    rejecting. Each degraded round :meth:`age`\\ s the oldest sample out,
    so the window eventually under-fills and drafting re-probes — the
    fallback is bounded, not a permanent switch-off."""

    def __init__(self, floor: float, window: int):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.floor = floor
        self.window = window
        self._hist: deque[int] = deque(maxlen=window)

    def record(self, drafted: int, accepted: int) -> None:
        for j in range(drafted):
            self._hist.append(1 if j < accepted else 0)

    def degraded(self) -> bool:
        if self.floor <= 0.0 or len(self._hist) < self.window:
            return False
        return sum(self._hist) / len(self._hist) < self.floor

    def age(self) -> None:
        """One degraded round passed: forget the oldest outcome."""
        if self._hist:
            self._hist.popleft()

    @property
    def rate(self) -> float:
        return sum(self._hist) / max(len(self._hist), 1)
