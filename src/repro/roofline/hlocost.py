"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — with
scan-over-layers (and chunked-attention / SSM chunk scans) that undercounts
FLOPs by 30-8000×. The optimized HLO text, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on every scan-derived
while op. This module parses the HLO text, builds the computation call
graph, propagates trip-count multipliers (while bodies ×n, fusions/calls
×1), and accumulates:

* flops   — exact 2·M·N·K for dot/convolution (from shapes +
            dot_dimension_numbers), ~1 flop/element for arithmetic and
            transcendental elementwise ops,
* bytes   — Σ (operand + result bytes) per top-level op, fusions counted at
            the call site only (XLA's own convention),
* collective bytes — per-kind, same loop multipliers (a collective inside
            the layer scan really does run L times).

Validated against ``cost_analysis()`` on loop-free modules (they agree) and
against hand-counted scans (tests/test_roofline.py).
"""
from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(pred|[us]\d+|bf16|f16|f32|f64|c64|c128|token)\[([\d,]*)\]")
# op line: %name = <shape-or-tuple> opcode(%a, %b, ...), attrs
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+?))\s+"
    r"([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*\S.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:body|calls|to_apply|condition)=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
}
ELEMENTWISE_XFLOP = {  # transcendental: count a few flops each
    "exponential": 4, "log": 4, "tanh": 6, "rsqrt": 2, "sqrt": 2,
    "power": 6, "logistic": 6, "sine": 4, "cosine": 4, "erf": 6,
    "exponential-minus-one": 4, "log-plus-one": 4, "cbrt": 4, "atan2": 8,
}
COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES.get(dt, 4)
    return elems, nbytes


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str  # operands + attrs tail of the line


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(1), [])
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            cur.ops.append(Op(mo.group(1), mo.group(2), mo.group(3), mo.group(4)))
    return comps


def _multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Propagate loop trip counts down the call graph."""
    entry = None
    for name in comps:
        # ENTRY computation: jax modules name it 'main' (or first parsed)
        if name.startswith("main") or entry is None:
            if name.startswith("main"):
                entry = name
    if entry is None:
        entry = next(iter(comps))
    mult: dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # call edges: (callee, factor) per caller
    edges: dict[str, list[tuple[str, float]]] = {n: [] for n in comps}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                trip = 1.0
                mt = _TRIP_RE.search(op.rest)
                if mt:
                    trip = float(mt.group(1))
                for mc in _CALL_ATTR_RE.finditer(op.rest):
                    callee = mc.group(1)
                    factor = trip if f"body=%{callee}" in op.rest or \
                        f"body={callee}" in op.rest else 1.0
                    edges[comp.name].append((callee, factor))
            else:
                for mc in _CALL_ATTR_RE.finditer(op.rest):
                    edges[comp.name].append((mc.group(1), 1.0))
    # propagate (call graph is a DAG)
    import collections

    indeg = collections.Counter()
    for caller, es in edges.items():
        for callee, _ in es:
            indeg[callee] += 1
    queue = [n for n in comps if indeg[n] == 0]
    seen = set()
    while queue:
        n = queue.pop()
        if n in seen:
            continue
        seen.add(n)
        for callee, factor in edges.get(n, []):
            if callee in mult:
                mult[callee] += mult[n] * factor
                indeg[callee] -= 1
                if indeg[callee] <= 0:
                    queue.append(callee)
    return mult


def _dot_flops(op: Op, shapes: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(op.shape)
    # contraction size from the lhs operand shape + contracting dims
    operands = re.findall(r"%([\w.\-]+)", op.rest)
    k = 1
    mc = _CONTRACT_RE.search(op.rest)
    if mc and operands:
        lhs_shape = shapes.get(operands[0], "")
        dims = _dims(lhs_shape)
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(dims):
                k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, shapes: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(op.shape)
    operands = re.findall(r"%([\w.\-]+)", op.rest)
    kernel_elems = 1
    if len(operands) >= 2:
        kernel_elems, _ = _shape_elems_bytes(shapes.get(operands[1], ""))
    # rough: 2 * out * (kernel / out_channels); fall back to 2*out*kernel_el
    return 2.0 * out_elems * max(kernel_elems, 1) ** 0.5  # conservative


# ops whose operand/result traffic survives even under perfect fusion —
# the TPU-target "ideal fusion" memory lower bound
_MATERIALIZING = {
    "dot", "convolution", "fusion", "reduce", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "sort", "transpose",
    "reshape",  # layout-changing reshapes copy on TPU; cheap ones fold
}


@dataclasses.dataclass
class LoopAwareCost:
    flops: float = 0.0
    bytes_min: float = 0.0       # ideal-fusion HBM traffic (roofline term)
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    dot_flops: float = 0.0
    elementwise_flops: float = 0.0
    # (kind, result_bytes, loop_multiplier, attr_tail) per collective op —
    # consumed by roofline.analysis for wire-byte/axis classification
    collective_ops: list = dataclasses.field(default_factory=list)

    def to_json(self):
        d = dataclasses.asdict(self)
        d.pop("collective_ops", None)
        return d


FUSED_MARKER = "fused_kernel"


def analyze(hlo: str) -> LoopAwareCost:
    """See module docstring. Ops whose metadata op_name contains
    ``fused_kernel`` (emitted by jax.named_scope at trace time) are treated
    as one hand-written Pallas kernel: FLOPs count normally, HBM bytes only
    at the region boundary (operands produced outside / results consumed
    outside). This models kernels the CPU backend cannot lower (flash
    attention — see kernels/flash_attention.py) without faking the HLO."""
    comps = parse_module(hlo)
    mult = _multipliers(comps)
    # global shape table (op name -> shape string); names unique per module
    shapes: dict[str, str] = {}
    in_region: dict[str, bool] = {}
    consumers: dict[str, list[str]] = {}
    for comp in comps.values():
        for op in comp.ops:
            shapes[op.name] = op.shape
            in_region[op.name] = FUSED_MARKER in op.rest
            for ref in re.findall(r"%([\w.\-]+)", op.rest):
                consumers.setdefault(ref, []).append(op.name)

    # identify fusion-called computations: bytes counted at call site only
    fused: set[str] = set()
    fusion_callee: dict[str, str] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                for mc in _CALL_ATTR_RE.finditer(op.rest):
                    fused.add(mc.group(1))
                    fusion_callee[op.name] = mc.group(1)
            if op.opcode in ("reduce", "scatter", "sort", "map",
                             "reduce-window", "select-and-scatter"):
                for mc in _CALL_ATTR_RE.finditer(op.rest):
                    fused.add(mc.group(1))  # to_apply bodies: skip entirely

    # fusions made only of dtype-conversion / data-movement ops are CPU
    # bf16-emulation artifacts (TPU computes bf16 natively): zero bytes
    _TRIVIAL = {
        "convert", "copy", "bitcast", "broadcast", "reshape", "transpose",
        "parameter", "tuple", "get-tuple-element", "constant", "slice",
        "concatenate", "pad", "iota",
    }
    trivial_fused = {
        name for name in fused
        if name in comps and comps[name].ops
        and all(o.opcode in _TRIVIAL for o in comps[name].ops)
    }

    cost = LoopAwareCost()
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        in_fused_comp = comp.name in fused
        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "while", "call", "custom-call", "copy",
                      "copy-start", "copy-done", "after-all", "partition-id"):
                if oc != "custom-call":
                    continue
            out_elems, out_bytes = _shape_elems_bytes(op.shape)
            # --- flops ---
            if oc == "dot":
                f = _dot_flops(op, shapes)
                cost.dot_flops += m * f
                cost.flops += m * f
            elif oc == "convolution":
                f = _conv_flops(op, shapes)
                cost.dot_flops += m * f
                cost.flops += m * f
            elif oc in ELEMENTWISE_1FLOP:
                cost.elementwise_flops += m * out_elems
                cost.flops += m * out_elems
            elif oc in ELEMENTWISE_XFLOP:
                f = ELEMENTWISE_XFLOP[oc] * out_elems
                cost.elementwise_flops += m * f
                cost.flops += m * f
            elif oc == "reduce":
                cost.elementwise_flops += m * out_elems * 2
                cost.flops += m * out_elems * 2
            elif oc == "fusion":
                pass  # inner ops counted via the fused computation
            # --- bytes: top-level ops only (not inside fused comps) ---
            if not in_fused_comp and oc not in COLLECTIVES:
                if in_region.get(op.name, False):
                    # inside a hand-fused kernel region: boundary traffic only
                    operand_bytes = 0
                    for name in re.findall(r"%([\w.\-]+)", op.rest):
                        if name in shapes and not in_region.get(name, False):
                            _, bts = _shape_elems_bytes(shapes[name])
                            operand_bytes += bts
                    cons = consumers.get(op.name, [])
                    escapes = (not cons) or any(
                        not in_region.get(c, False) for c in cons
                    )
                    bb = operand_bytes + (out_bytes if escapes else 0)
                    cost.bytes_accessed += m * bb
                    cost.bytes_min += m * bb
                elif oc in ("dynamic-slice", "gather"):
                    # reads only the slice, not the whole operand (charging
                    # the full KV-cache stack per layer-scan iteration
                    # inflated decode memory terms ~1000x)
                    bb = 2.0 * out_bytes
                    cost.bytes_accessed += m * bb
                    cost.bytes_min += m * bb
                elif oc in ("dynamic-update-slice", "scatter"):
                    # in-place update: read+write the update region only
                    upd_bytes = out_bytes
                    refs = re.findall(r"%([\w.\-]+)", op.rest)
                    if len(refs) >= 2 and refs[1] in shapes:
                        _, upd_bytes = _shape_elems_bytes(shapes[refs[1]])
                    bb = 2.0 * upd_bytes
                    cost.bytes_accessed += m * bb
                    cost.bytes_min += m * bb
                elif oc == "fusion" and fusion_callee.get(op.name) in trivial_fused:
                    pass  # dtype-emulation fusion: free on TPU
                else:
                    operand_bytes = 0
                    same_as_result = 0
                    for name in re.findall(r"%([\w.\-]+)", op.rest):
                        if name in shapes:
                            _, bts = _shape_elems_bytes(shapes[name])
                            operand_bytes += bts
                            if oc == "fusion" and shapes[name] == op.shape:
                                same_as_result += bts
                    if oc == "fusion" and same_as_result:
                        # loop-carried buffer updated in place (XLA aliases
                        # while carries): charge only the distinct operands
                        operand_bytes -= same_as_result
                        bb = operand_bytes
                    else:
                        bb = out_bytes + operand_bytes
                    cost.bytes_accessed += m * bb
                    if oc in _MATERIALIZING:
                        cost.bytes_min += m * bb
            # --- collectives ---
            base = oc.replace("-start", "")
            if base in COLLECTIVES and not oc.endswith("-done"):
                cost.collective_bytes[base] = (
                    cost.collective_bytes.get(base, 0.0) + m * out_bytes
                )
                cost.collective_ops.append((base, out_bytes, m, op.rest))
    return cost
