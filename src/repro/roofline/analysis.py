"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = Σ_hops per-chip collective bytes / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis: we parse the optimized HLO text and sum operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, weighting each by the algorithm's per-chip wire factor on its mesh axis
(ring all-reduce moves 2·(n-1)/n × bytes, all-gather/reduce-scatter
(n-1)/n ×, all-to-all (n-1)/n ×, permute 1×). Ops whose replica groups
cross the ``pod`` axis are charged to the slower DCN-class link.
"""
from __future__ import annotations

import dataclasses
import json
import re

# TPU v5e per-chip constants (assignment-fixed)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (intra-pod)
DCN_BW = 25e9                # bytes/s (pod axis)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\)|\S+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(pred|[us]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size_and_stride(line: str) -> tuple[int, int]:
    """Rough (participants per group, index stride) from replica_groups.

    Stride 1 groups = contiguous device ids = minor (model) axis; large
    strides = major axes (data / pod). Used to classify ICI vs DCN."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        return gsize, 1
    m = _GROUPS_RE.search(line)
    if not m:
        return 1, 1
    first = m.group(1).split("}")[0].strip("{} ")
    ids = [int(x) for x in first.split(",") if x.strip().isdigit()]
    if len(ids) < 2:
        return max(1, len(ids)), 1
    return len(ids), abs(ids[1] - ids[0])


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int = 0            # logical operand bytes, summed over ops
    wire_bytes_ici: float = 0.0     # per-chip wire bytes on ICI links
    wire_bytes_dcn: float = 0.0     # per-chip wire bytes crossing pods
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0


def collectives_from_ops(ops: list, n_devices: int, pod_stride: int = 256
                         ) -> CollectiveStats:
    """CollectiveStats from loop-aware (kind, bytes, mult, attrs) records
    (see roofline.hlocost)."""
    stats = CollectiveStats()
    for kind, nbytes, mult, rest in ops:
        nbytes = nbytes * mult
        if nbytes == 0:
            continue
        gsize, stride = _group_size_and_stride(rest)
        n = max(gsize, 1)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif kind in ("all-gather", "all-to-all"):
            wire = (n - 1) / n * nbytes
        elif kind == "reduce-scatter":
            wire = float(n - 1) * nbytes
        else:
            wire = float(nbytes)
        stats.total_bytes += int(nbytes)
        crosses_pod = stride >= pod_stride or (gsize * stride > pod_stride)
        if crosses_pod and n_devices > pod_stride:
            stats.wire_bytes_dcn += wire
        else:
            stats.wire_bytes_ici += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0) + int(nbytes)
        stats.count += 1
    return stats


def parse_collectives(hlo_text: str, n_devices: int, pod_stride: int = 256
                      ) -> CollectiveStats:
    """Sum collective traffic from optimized HLO text (NOT loop-aware — use
    collectives_from_ops with hlocost for scan-heavy modules)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if "-done" in line:
            continue
        shape_str = m.group(2)
        nbytes = _shape_bytes(shape_str)
        if nbytes == 0:
            continue
        gsize, stride = _group_size_and_stride(line)
        n = max(gsize, 1)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif kind in ("all-gather", "all-to-all"):
            # AG result shape is the full gathered buffer; A2A result equals
            # its input; per-chip wire is (n-1)/n of that buffer
            wire = (n - 1) / n * nbytes
        elif kind == "reduce-scatter":
            # result shape is the scattered shard (input/n): wire is
            # (n-1)/n of the *input* = (n-1) x result bytes
            wire = float(n - 1) * nbytes
        else:  # collective-permute
            wire = float(nbytes)
        stats.total_bytes += nbytes
        crosses_pod = stride >= pod_stride or (gsize * stride > pod_stride)
        if crosses_pod and n_devices > pod_stride:
            stats.wire_bytes_dcn += wire
        else:
            stats.wire_bytes_ici += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0) + nbytes
        stats.count += 1
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_gflops: float            # PER-DEVICE (SPMD module cost_analysis)
    hlo_bytes: float             # PER-DEVICE HBM traffic
    coll: CollectiveStats        # per-device collective schedule
    model_flops: float           # 6·N·D useful-compute reference (global)
    peak_flops: float = PEAK_FLOPS
    per_device_peak_bytes: int = 0

    @property
    def t_compute(self) -> float:
        return self.hlo_gflops * 1e9 / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return (self.coll.wire_bytes_ici / ICI_BW
                + self.coll.wire_bytes_dcn / DCN_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """max(term)/sum(terms): 1.0 = perfectly overlapped single
        bottleneck; low = time smeared across non-overlapping terms."""
        ts = [self.t_compute, self.t_memory, self.t_collective]
        tot = sum(ts)
        return max(ts) / tot if tot > 0 else 0.0

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × devices) — catches remat/redundancy."""
        return self.model_flops / max(
            self.hlo_gflops * 1e9 * self.n_devices, 1.0
        )

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU for this schedule: useful FLOPs per
        device-second at the roofline = model_flops / (n_dev × max-term ×
        peak)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.n_devices * t * self.peak_flops)

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "hlo_gflops": self.hlo_gflops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.coll.total_bytes,
            "wire_ici": self.coll.wire_bytes_ici,
            "wire_dcn": self.coll.wire_bytes_dcn,
            "coll_by_kind": self.coll.by_kind,
            "coll_count": self.coll.count,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
            "per_device_peak_bytes": self.per_device_peak_bytes,
        }


def count_params(abstract_params) -> int:
    import jax

    return sum(
        int(x.size) for x in jax.tree.leaves(abstract_params)
    )


def model_flops_estimate(cfg, shape, n_params: int, active_params: int) -> float:
    """6·N·D (train) / 2·N·D (forward-only), N = active params."""
    n = active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n * tokens


def active_params(cfg, abstract_params) -> int:
    """Parameters touched per token (MoE: shared + top_k experts only)."""
    import jax

    total = 0
    flat = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", ""))) for p in path
        )
        sz = int(leaf.size)
        if cfg.moe is not None and "experts/" in name:
            sz = sz * cfg.moe.top_k // cfg.moe.n_experts
        total += sz
    return total


def summarize(cells: list[Roofline]) -> str:
    hdr = (
        "| arch | shape | mesh | t_comp(ms) | t_mem(ms) | t_coll(ms) | "
        "bottleneck | useful | coll GB |\n|---|---|---|---|---|---|---|---|---|"
    )
    rows = [hdr]
    for r in cells:
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_compute*1e3:.2f} | "
            f"{r.t_memory*1e3:.2f} | {r.t_collective*1e3:.2f} | "
            f"{r.bottleneck} | {r.useful_fraction:.2f} | "
            f"{r.coll.total_bytes/1e9:.2f} |"
        )
    return "\n".join(rows)
