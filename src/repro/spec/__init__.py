"""Speculative decoding: INT4 SplitQuant drafter + batched verify/rollback.

``drafter`` runs the packed INT4 executable for k draft tokens per request
over its own paged KV cache; ``verify`` scores all k+1 positions in one
target-model forward (the chunked-prefill scatter contract) and rolls
rejected tokens back without leaking a page; ``policy`` is the host-side
acceptance math — greedy (bit-identical to target-only decoding) and
standard rejection sampling (distribution-preserving).
"""
from repro.spec.drafter import Drafter
from repro.spec.policy import (
    accept_greedy,
    accept_speculative,
    shaped_probs,
)
from repro.spec.verify import SpecStats, Verifier

__all__ = [
    "Drafter",
    "SpecStats",
    "Verifier",
    "accept_greedy",
    "accept_speculative",
    "shaped_probs",
]
