"""INT4 draft model: the packed SplitQuant executable as a drafter.

SplitQuantV2's result — GPU-free INT4 quantization that tracks the fp
model's outputs — is exactly the property a *draft* model needs for
self-speculative decoding: the packed executable streams 6 bits/weight
through the fused Pallas kernels (a fraction of the target's decode
bandwidth) and proposes k tokens per round that the fp target verifies in
ONE batched forward. The drafter here is a miniature paged server:

* its own paged KV cache over its own :class:`PageAllocator` pool (sized
  dense-equivalent by default so draft admission can never fail once
  target admission succeeded) — the draft cache never aliases target
  pages, and the DRAFT pool must also return to zero in use (a leaked
  draft page is as real a leak as a target one),
* slot-aligned with the target server: slot ``i`` of the draft cache
  serves the same request as slot ``i`` of the target cache,
* a ``valid`` watermark per slot — the number of COMMITTED tokens
  (prompt + emitted) whose KV the draft cache holds. Every round starts
  with a catch-up chunk feeding ``committed[valid:]`` (normally just the
  token the last verification emitted) through ``model.verify_step`` to
  get the first draft distribution, then greedy/sampled decode steps for
  the remaining drafts.

Rollback mirrors the verifier: rejected drafts rewind the draft
``cache["len"]`` to the committed watermark; recurrent families restore
the post-catch-up snapshot (state at exactly ``committed`` tokens) and
let the NEXT round's catch-up chunk re-feed the accepted drafts — which
bounds the catch-up width at ``k + 1`` so the chunk forward never
recompiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import PagePoolGroup, pages_for
from repro.kvcache.paged import restore_rows, rewind
from repro.models.model import _RECURRENT_KEYS, reset_slots
from repro.spec.policy import shaped_probs


class Drafter:
    """Paged draft-model runner, slot-aligned with a BatchedServer."""

    def __init__(self, model, params, slots: int, max_len: int, *,
                 page_size: int, width: int, num_pages: int | None = None,
                 plan=None, registry=None):
        # under a mesh plan (runtime.sharding.MeshPlan) the draft pool is
        # split per DP replica exactly like the target's, and the packed
        # draft weights shard under the same exact-TP rules
        self._plan = plan
        n_rep = plan.n_data if plan is not None else 1
        if plan is not None:
            params, _ = plan.put_params(params)
        self.params = params
        self.slots = slots
        self.page_size = page_size
        self.width = width  # catch-up chunk width == speculate + 1
        pages_per_row = pages_for(max_len, page_size)
        self.num_pages = num_pages or slots * pages_per_row
        if self.num_pages % n_rep:
            raise ValueError(
                f"draft num_pages ({self.num_pages}) must divide over "
                f"the mesh's {n_rep} data replicas")
        self._slots_per_rep = slots // n_rep
        self.cache = model.init_paged_cache(
            slots, max_len, page_size=page_size, num_pages=self.num_pages
        )
        self.alloc = PagePoolGroup(self.num_pages, n_rep)
        self._table = np.zeros((slots, pages_per_row), np.int32)
        self._dirty = False
        self._pages: list[list[int]] = [[] for _ in range(slots)]
        self.valid = np.zeros((slots,), np.int32)  # committed tokens cached
        self._recurrent = [k for k in _RECURRENT_KEYS if k in self.cache]
        self._snap: dict = {}
        self._round: dict[int, tuple[int, int]] = {}  # slot -> (C, kk)
        self.forwards = 0
        self.registry = registry  # optional obs registry (set by the server)

        if plan is not None:
            self._cache_shd = plan.cache_shardings(self.cache)
            self.cache = plan.put_cache(self.cache, self._cache_shd)
            jit = lambda f: jax.jit(f, out_shardings=(None, self._cache_shd))
        else:
            self._cache_shd = None
            jit = jax.jit

        # private closures: see Verifier — sharing the raw model functions
        # with the server's jits would pool their compile counts. With a
        # plan, the exact-TP hints are entered inside the traced bodies.
        def _decode_fn(params, tokens, cache, active):
            if plan is not None:
                with plan.hints():
                    return model.decode_step(params, tokens, cache,
                                             active=active)
            return model.decode_step(params, tokens, cache, active=active)

        def _chunk_fn(params, tokens, lengths, cache):
            if plan is not None:
                with plan.hints():
                    return model.verify_step(params, tokens, lengths, cache)
            return model.verify_step(params, tokens, lengths, cache)

        self._decode = jit(_decode_fn)
        self._chunk = jit(_chunk_fn)

        def _prefill_fn(params, tokens, lengths, fresh, starts, cache):
            cache = reset_slots(cache, fresh, starts)
            if plan is not None:
                with plan.hints():
                    return model.prefill(
                        params, {"tokens": tokens, "lengths": lengths}, cache
                    )
            return model.prefill(
                params, {"tokens": tokens, "lengths": lengths}, cache
            )

        self._prefill = jit(_prefill_fn)

    # -- bookkeeping --------------------------------------------------------

    def _fwd(self, kind: str) -> None:
        """One draft-model forward of ``kind`` (prefill|chunk|decode)."""
        self.forwards += 1
        if self.registry is not None:
            self.registry.counter(
                "spec_draft_forwards_total",
                "draft-model forwards, by step kind",
            ).inc(kind=kind)

    def compiles(self) -> dict:
        return {
            "prefill": self._prefill._cache_size(),
            "chunk": self._chunk._cache_size(),
            "decode": self._decode._cache_size(),
        }

    def admit(self, slot: int, n_tokens: int) -> None:
        """Reserve draft pages for a request needing ``n_tokens`` KV rows
        (the draft high-water mark — one row less than the target's, the
        final emitted token is never fed to the drafter). Pages come from
        the slot's own DP replica pool, mirroring the target server."""
        self._pages[slot] = self.alloc.alloc(
            pages_for(n_tokens, self.page_size),
            slot // self._slots_per_rep,
        )
        self._table[slot, : len(self._pages[slot])] = self._pages[slot]
        self._dirty = True
        self.valid[slot] = 0

    def release(self, slot: int) -> None:
        """Free the slot's draft pages (idempotent). Called as soon as a
        request can no longer draft — one round BEFORE target retirement —
        via ``allocator.truncate``: the draft KV's useful length dropped
        to zero while the target's is still live."""
        self._pages[slot] = self.alloc.truncate(self._pages[slot], 0)
        self.valid[slot] = 0

    def _put(self, arr):
        if self._plan is None:
            return jnp.asarray(arr)
        return self._plan.put_batch(arr)

    def _sync_table(self):
        if self._dirty:
            self.cache = dict(self.cache)
            self.cache["page_table"] = jnp.asarray(self._table)
            self._dirty = False
        if self._plan is not None:
            # re-commit to the canonical shardings after host edits so the
            # jitted draft calls never see drifted input layouts
            self.cache = jax.tree.map(jax.device_put, self.cache,
                                      self._cache_shd)

    # -- prompt prefill (mirrors the server's waves) ------------------------

    def prefill_wave(self, tokens: np.ndarray, lengths: np.ndarray,
                     fresh: np.ndarray, fed_after: dict[int, int]) -> None:
        """One batched prefill wave into the draft cache. The server
        builds the arrays exactly as for the target wave — except the
        drafter always starts at position 0 (it holds no shared prefix
        pages, so a target-side prefix hit still prefills the DRAFT cache
        in full). Logits are discarded: the first emitted token comes from
        the target's prefill. ``fed_after`` maps the wave's slots to their
        prompt-token watermark after this wave; other slots keep theirs."""
        self._sync_table()
        _, self.cache = self._prefill(
            self.params, self._put(tokens), self._put(lengths),
            self._put(fresh), self._put(np.zeros((self.slots,), np.int32)),
            self.cache,
        )
        self._fwd("prefill")
        for slot, fed in fed_after.items():
            self.valid[slot] = fed

    # -- drafting -----------------------------------------------------------

    def draft_round(self, jobs: list[tuple[int, np.ndarray, int]], *,
                    sampling: dict, rngs: dict[int, np.random.Generator],
                    ) -> tuple[dict[int, list[int]], dict[int, np.ndarray]]:
        """Propose drafts for ``jobs`` = [(slot, committed_tokens, kk)].

        Returns ``(drafts, qdists)``: per slot the kk drafted token ids
        and (sampling mode only) the (kk, V) shaped distributions each was
        drawn from — the ``q`` the rejection sampler needs. Greedy mode
        drafts the draft-model argmax and returns no distributions."""
        greedy = sampling["temperature"] <= 0.0
        self._drain_backlog(jobs)
        tokens = np.zeros((self.slots, self.width), np.int32)
        lengths = np.zeros((self.slots,), np.int32)
        self._round = {}
        for slot, committed, kk in jobs:
            w = len(committed) - int(self.valid[slot])
            if not 1 <= w <= self.width:
                raise AssertionError(
                    f"draft catch-up width {w} out of [1, {self.width}] "
                    f"(slot {slot})"
                )
            tokens[slot, :w] = committed[self.valid[slot]:]
            lengths[slot] = w
            self._round[slot] = (len(committed), kk)
        self._sync_table()
        logits, self.cache = self._chunk(
            self.params, self._put(tokens), self._put(lengths),
            self.cache,
        )
        self._fwd("chunk")
        # snapshot recurrent state at exactly the committed watermark:
        # restore-on-rejection re-enters the next round from here, so the
        # catch-up width stays <= accepted + 1 <= width
        self._snap = {k: self.cache[k] for k in self._recurrent}
        # greedy drafts only need token ids: argmax on device, transfer
        # (slots, width) ints instead of full-vocab logits rows
        rows = np.asarray(jnp.argmax(logits, -1) if greedy else logits)
        drafts: dict[int, list[int]] = {}
        qdists: dict[int, list[np.ndarray]] = {}
        for slot, committed, kk in jobs:
            row = rows[slot, int(lengths[slot]) - 1]
            drafts[slot] = [self._pick(slot, row, greedy, sampling, rngs,
                                       qdists)]
        step = 1
        while True:
            live = [(s, c, kk) for s, c, kk in jobs if kk > step]
            if not live:
                break
            feed = np.zeros((self.slots, 1), np.int32)
            active = np.zeros((self.slots,), bool)
            for slot, _, _ in live:
                feed[slot, 0] = drafts[slot][-1]
                active[slot] = True
            logits, self.cache = self._decode(
                self.params, self._put(feed), self.cache,
                active=self._put(active),
            )
            self._fwd("decode")
            rows = np.asarray(jnp.argmax(logits, -1) if greedy else logits)
            for slot, _, _ in live:
                drafts[slot].append(self._pick(slot, rows[slot, 0], greedy,
                                               sampling, rngs, qdists))
            step += 1
        qarr = {s: np.stack(v) for s, v in qdists.items()}
        return drafts, qarr

    def _drain_backlog(self, jobs: list[tuple[int, np.ndarray, int]]) -> None:
        """Pre-feed committed tokens when a slot's catch-up backlog
        exceeds the chunk width.

        Degraded rounds (spec fallback under pool pressure or a low
        acceptance window) emit tokens WITHOUT consulting the drafter, so
        ``committed - valid`` can grow far beyond ``width`` by the time
        drafting resumes. Those tokens are permanently committed — they
        are drained through extra catch-up chunks (the same jitted
        function, so no recompile) whose logits are discarded, advancing
        the watermark until one ordinary chunk of 1..width remains."""
        while True:
            pend = {s: len(c) - int(self.valid[s]) for s, c, _ in jobs}
            for s, p in pend.items():
                if p < 1:
                    raise AssertionError(
                        f"draft slot {s} watermark beyond committed ({p})")
            if all(p <= self.width for p in pend.values()):
                return
            tokens = np.zeros((self.slots, self.width), np.int32)
            lengths = np.zeros((self.slots,), np.int32)
            for slot, committed, _ in jobs:
                p = pend[slot]
                if p <= self.width:
                    continue
                w = min(self.width, p - self.width)  # leave 1..width behind
                start = int(self.valid[slot])
                tokens[slot, :w] = committed[start:start + w]
                lengths[slot] = w
                self.valid[slot] = start + w
            self._sync_table()
            _, self.cache = self._chunk(
                self.params, self._put(tokens), self._put(lengths),
                self.cache,
            )
            self._fwd("chunk")

    def _pick(self, slot, row, greedy, sampling, rngs, qdists) -> int:
        """One draft token from ``row``: the device-argmaxed token id in
        greedy mode, the full logits row (shaped + sampled from the
        request's own stream, distribution recorded for the rejection
        sampler) otherwise."""
        if greedy:
            return int(row)
        q = shaped_probs(row, **sampling)
        qdists.setdefault(slot, []).append(q)
        return int(rngs[slot].choice(q.size, p=q))

    # -- rollback -----------------------------------------------------------

    def finish_round(self, accepted: dict[int, int]) -> None:
        """Reconcile the draft cache with the verifier's verdicts:
        ``accepted[slot] = m`` drafts survived. The committed watermark
        advances to ``C + min(m, kk - 1)`` (draft ``kk`` is proposed but
        never fed, so its KV is not cached); recurrent slots whose state
        absorbed a rejected draft (``m < kk - 1``) restore the
        post-catch-up snapshot and fall back to ``C`` — the next catch-up
        chunk re-feeds their accepted drafts."""
        restore = np.zeros((self.slots,), bool)
        touched = np.zeros((self.slots,), bool)
        new_valid = self.valid.copy()
        for slot, m in accepted.items():
            committed, kk = self._round[slot]
            touched[slot] = True
            if self._recurrent and m < kk - 1:
                restore[slot] = True
                new_valid[slot] = committed
            else:
                new_valid[slot] = committed + min(m, kk - 1)
        self.cache = dict(self.cache)
        if restore.any():
            self.cache = restore_rows(self.cache, self._snap,
                                      jnp.asarray(restore), self._recurrent)
        self.cache["len"] = rewind(
            self.cache["len"], jnp.asarray(touched), jnp.asarray(new_valid)
        )
        self.valid = new_valid
        self._round = {}
