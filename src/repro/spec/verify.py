"""Target-side verification: one batched multi-token forward + rollback.

The verifier turns k drafted tokens into one target forward: row ``i``
feeds ``[last_emitted, d_1 .. d_kk]`` as a prefill-style chunk (per-row
``lengths`` — the chunked-prefill scatter contract), and
``model.verify_step`` returns the logits at EVERY fed position, i.e. the
target distribution after the context, after draft 1, ..., after draft
kk. Acceptance happens host-side (``spec.policy``); what lives here is
the cache bookkeeping that makes rejection safe:

* positional KV: the verify forward wrote all ``kk + 1`` positions, but a
  rejection means only ``m + 1`` of them are real. Un-writing is a LENGTH
  update, not a data wipe — ``kvcache.paged.rewind`` pulls the per-slot
  ``cache["len"]`` back to ``base + m + 1`` and the rejected positions
  become unreachable exactly like stale KV in a recycled slot (attention
  masks ``k >= len``; the next wave overwrites them — every touched page
  is exclusively owned, the scheduler's COW guard ran before the write).

* recurrent state (zamba2 ssm/conv rows): state cannot be length-masked —
  after the verify forward it has absorbed the rejected drafts. The
  verifier snapshots the recurrent leaves before scoring (free: jax
  arrays are immutable, a snapshot is a reference), and on rejection
  restores the slot's rows, rewinds ``len`` to ``base``, and re-runs the
  ACCEPTED tokens (``m + 1 <= kk + 1``, same jitted verify fn — no new
  compile) to rebuild state; the KV re-writes are idempotent. Slots whose
  drafts all survived keep their post-verify state untouched.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache.paged import restore_rows, rewind


@dataclasses.dataclass
class SpecStats:
    """Speculation counters for one server run."""
    k: int = 0
    rounds: int = 0
    drafted: int = 0            # draft tokens proposed
    accepted: int = 0           # draft tokens that survived verification
    emitted: int = 0            # decode-path tokens emitted by spec rounds
    target_forwards: int = 0    # verify + recompute forwards (target model)
    recompute_forwards: int = 0  # recurrent-state rebuilds after rejection
    draft_forwards: int = 0     # drafter forwards (catch-up + decode steps)
    degraded_rounds: int = 0    # per-request rounds decoded plainly instead
    #                             of drafting (pool pressure or acceptance
    #                             below the configured floor)

    def summary(self) -> dict:
        fwd = max(self.target_forwards, 1)
        return {
            "k": self.k,
            "rounds": self.rounds,
            "drafted": self.drafted,
            "accepted": self.accepted,
            "degraded_rounds": self.degraded_rounds,
            "acceptance_rate": self.accepted / max(self.drafted, 1),
            "emitted": self.emitted,
            "target_forwards": self.target_forwards,
            "recompute_forwards": self.recompute_forwards,
            "draft_forwards": self.draft_forwards,
            # the speculative figure of merit: > 1.0 means each target
            # forward emitted more than one token on average
            "emitted_per_target_forward": self.emitted / fwd,
            "target_forwards_per_token": (
                self.target_forwards / max(self.emitted, 1)
            ),
        }


class Verifier:
    """Jitted multi-token scoring + leakage-free rollback for one cache."""

    def __init__(self, model, params, recurrent_keys: list[str], plan=None,
                 cache_shd=None, registry=None):
        self.params = params
        self._recurrent = list(recurrent_keys)
        self._plan = plan
        self._cache_shd = cache_shd
        self.registry = registry  # optional obs registry (set by the server)
        self.last_logits0 = None  # host (B, V) position-0 logits rows, kept
        #                           only when score() is asked (quality probe)

        # private closure: jit caches are keyed by the wrapped function, so
        # wrapping model.verify_step directly would share a compile count
        # with the drafter's catch-up chunk and muddy the compile stats.
        # Under a mesh plan the exact-TP hints are entered inside the trace
        # and the cache output is pinned to its canonical shardings, so the
        # rollback's nested re-verify never registers a second signature.
        def _verify_fn(params, tokens, lengths, cache):
            if plan is not None:
                with plan.hints():
                    return model.verify_step(params, tokens, lengths, cache)
            return model.verify_step(params, tokens, lengths, cache)

        if plan is not None and cache_shd is not None:
            self._verify = jax.jit(_verify_fn,
                                   out_shardings=(None, cache_shd))
        else:
            self._verify = jax.jit(_verify_fn)

    def _put(self, arr):
        if self._plan is None:
            return jnp.asarray(arr)
        return self._plan.put_batch(arr)

    @property
    def compiles(self) -> int:
        return self._verify._cache_size()

    def score(self, cache: dict, tokens: np.ndarray, lengths: np.ndarray,
              greedy: bool = False, keep_logits0: bool = False):
        """Run the verify forward. Returns ``(scores, new_cache,
        snapshot)`` — the snapshot holds the pre-verify recurrent leaves
        for :meth:`rollback` (empty for attention-only families).

        ``scores`` is the full ``(B, S, V)`` logits host array for
        sampling, but greedy acceptance only compares token ids: with
        ``greedy`` the argmax runs ON DEVICE and only ``(B, S)`` ints
        cross to the host — the verify-wave analogue of the serve path's
        device-argmax decode (full-vocab rows at production V would
        otherwise dominate the round).

        ``keep_logits0`` stashes the position-0 logits rows (the target
        distribution after the last emitted token) on
        ``self.last_logits0`` for the serve path's quality probe — a
        host transfer off the already-computed forward, never an extra
        trace or device call, so greedy streams and compile counts are
        untouched."""
        snap = {k: cache[k] for k in self._recurrent}
        logits, cache = self._verify(
            self.params, self._put(tokens), self._put(lengths), cache
        )
        if self.registry is not None:
            self.registry.counter(
                "spec_verify_forwards_total",
                "target-model verify forwards (incl. rollback recompute)",
            ).inc()
        if keep_logits0:
            self.last_logits0 = np.asarray(logits[:, 0])
        scores = np.asarray(jnp.argmax(logits, -1) if greedy else logits)
        return scores, cache, snap

    def rollback(
        self,
        cache: dict,
        snap: dict,
        base: np.ndarray,       # (B,) pre-verify cache lens
        new_lens: np.ndarray,   # (B,) post-acceptance lens (base + m + 1)
        rejected: np.ndarray,   # (B,) bool: slot kept fewer tokens than fed
        tokens: np.ndarray,     # (B, S) the verify wave's token rows
    ) -> dict:
        """Rewind rejected slots so the cache holds exactly the accepted
        sequence. Attention KV rewinds by length; recurrent families
        restore the snapshot and recompute the accepted chunk."""
        if not rejected.any():
            return cache
        if self._recurrent:
            sel = jnp.asarray(rejected)
            cache = restore_rows(cache, snap, sel, self._recurrent)
            # rewind to base, then re-feed the accepted tokens (the first
            # new_lens - base columns of the verify rows) to rebuild state
            cache["len"] = rewind(cache["len"], sel, jnp.asarray(base))
            if self._cache_shd is not None:
                # eager restore/rewind results may carry drifted shardings
                cache = jax.tree.map(jax.device_put, cache, self._cache_shd)
            relens = np.where(rejected, new_lens - base, 0).astype(np.int32)
            _, cache = self._verify(
                self.params, self._put(tokens), self._put(relens), cache
            )
            if self.registry is not None:
                self.registry.counter(
                    "spec_verify_forwards_total",
                    "target-model verify forwards (incl. rollback "
                    "recompute)",
                ).inc()
        else:
            cache = dict(cache)
            cache["len"] = rewind(
                cache["len"], jnp.asarray(rejected), jnp.asarray(new_lens)
            )
        return cache
