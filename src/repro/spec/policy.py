"""Speculative-decoding acceptance policies (host-side, pure numpy).

Two verification modes against the per-position target distributions that
one batched verify forward produces:

* ``accept_greedy`` — deterministic: accept drafted tokens while they
  equal the target argmax, emit the target argmax at the first mismatch
  (or at the bonus position when every draft survives). The emitted
  sequence is BIT-IDENTICAL to target-only greedy decoding no matter how
  good or bad the drafter is — speculation only changes how many target
  forwards it takes to produce it.

* ``accept_speculative`` — standard rejection sampling (Leviathan et al.
  2023; Chen et al. 2023): draft token ``x ~ q`` is accepted with
  probability ``min(1, p(x)/q(x))``; on rejection the emitted token is
  drawn from the residual ``norm(max(p - q, 0))``; if every draft is
  accepted a bonus token is drawn from the target's next-position
  distribution. The marginal distribution of each emitted token is
  EXACTLY ``p`` — the target model's own sampling distribution — which is
  what makes speculative decoding a latency optimization and not an
  accuracy trade (pinned by tests/test_spec.py: empirical acceptance
  equals ``sum(min(p, q))`` and the emitted-token marginal matches ``p``).

Both policies compare SHAPED distributions: :func:`shaped_probs` applies
the same temperature -> top-k -> top-p transform the server's
``sample_token`` draws from, because rejection sampling is only correct
when ``q`` is the distribution the draft was actually sampled from and
``p`` the distribution the target would have sampled from.
"""
from __future__ import annotations

import numpy as np


def shaped_probs(
    logits: np.ndarray,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> np.ndarray:
    """(V,) sampling distribution after temperature/top-k/top-p shaping.

    ``temperature <= 0`` collapses to the greedy one-hot (argmax mass 1) —
    the distribution greedy "sampling" draws from. This is the single
    source of truth for logit shaping: ``launch.serve.sample_token`` draws
    from exactly this distribution, so draft/target comparisons in the
    acceptance policies see the same support and mass the sampler does."""
    logits = np.asarray(logits, np.float64)
    if temperature <= 0.0:
        probs = np.zeros(logits.shape[-1], np.float64)
        probs[int(np.argmax(logits))] = 1.0
        return probs
    logits = logits / temperature
    if 0 < top_k < logits.size:
        kth = np.partition(logits, -top_k)[-top_k]
        logits = np.where(logits < kth, -np.inf, logits)
    logits = logits - logits.max()
    probs = np.exp(logits)
    probs /= probs.sum()
    if top_p < 1.0:
        order = np.argsort(-probs)
        cum = np.cumsum(probs[order])
        # minimal prefix whose mass reaches top_p (always >= 1 token)
        cut = int(np.searchsorted(cum, top_p)) + 1
        nucleus = np.zeros_like(probs)
        nucleus[order[:cut]] = probs[order[:cut]]
        probs = nucleus / nucleus.sum()
    return probs


def accept_greedy(
    drafts: list[int],
    target_argmax: np.ndarray,  # (k+1,) target argmax token ids
) -> tuple[int, int]:
    """Greedy verification. Returns ``(n_accepted, emitted_token)``.

    ``target_argmax[j]`` is the target's greedy token AFTER the context
    plus drafts ``0..j-1`` (argmaxed ON DEVICE — greedy verification
    never needs the full logits rows on the host); the emitted token is
    always ``target_argmax[n_accepted]`` — the correction at the first
    mismatch, or the free bonus token when all ``k`` drafts matched."""
    m = 0
    for d in drafts:
        if int(d) != int(target_argmax[m]):
            break
        m += 1
    return m, int(target_argmax[m])


def accept_speculative(
    drafts: list[int],
    draft_probs: np.ndarray,    # (k, V) shaped draft distributions
    target_probs: np.ndarray,   # (k+1, V) shaped target distributions
    rng: np.random.Generator,
) -> tuple[int, int]:
    """Rejection-sample the drafts against the target distributions.

    Returns ``(n_accepted, emitted_token)``. The emitted token comes from
    the residual ``norm(max(p - q, 0))`` at the first rejection, or from
    ``target_probs[k]`` (the bonus position) when every draft survives —
    the construction that makes each emitted token an exact sample from
    the target distribution. Draws come from ``rng`` — the caller passes
    the request's own seeded stream so speculation stays deterministic per
    (seed, rid) and independent of batch slots and admission order."""
    for j, d in enumerate(drafts):
        d = int(d)
        p, q = float(target_probs[j][d]), float(draft_probs[j][d])
        # d was sampled from q so q[d] > 0; guard anyway for callers
        # feeding externally produced drafts
        ratio = 1.0 if q <= 0.0 and p > 0.0 else min(1.0, p / max(q, 1e-300))
        if rng.random() < ratio:
            continue
        residual = np.maximum(target_probs[j] - draft_probs[j], 0.0)
        total = residual.sum()
        if total <= 0.0:
            # p == q everywhere: any residual draw is measure-zero; fall
            # back to the target distribution itself (still exact)
            residual, total = target_probs[j], target_probs[j].sum()
        return j, int(rng.choice(residual.size, p=residual / total))
    k = len(drafts)
    return k, int(rng.choice(target_probs[k].size, p=target_probs[k]))
