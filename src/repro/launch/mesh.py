"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
overrides the host device count and everything else must see 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (data, model) or 2x16x16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (reduced test meshes, elastic re-meshes)."""
    return jax.make_mesh(shape, axes)


def batch_shards(mesh) -> int:
    """Number of shards the batch dim is split into (pod x data)."""
    n = mesh.shape.get("data", 1)
    n *= mesh.shape.get("pod", 1)
    return n
