import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Quantized-decode dry-run (Cell C of §Perf): lower the REAL packed
engine path — ``model.decode_step`` over an ``as_executable()`` tree of
``PackedSplitQTensor``/``PackedSplitQGroup`` containers — on the production
mesh, under the same exact-TP serve shardings ``BatchedServer --mesh``
executes with (``runtime.sharding.serve_param_specs`` +
``sharding_hints(exact_tp=True)``).

Nothing here is modeled: the lowered HLO contains the engine's in-graph
dequant + matmul exactly as serving runs it (codes/cids planes sharded on
the output dim, per-shard (S, Z) LUT reads replicated), the cache follows
the serving contract (per-slot ``len: (B,)``, slot dim batch-sharded over
``data``), and the per-shard autotuned block dispatch is the one
``tp_shards()`` keys inside the trace. Weight HBM traffic per decode step
drops from bf16 (16 bit/wt) to 6 bit/wt.

    PYTHONPATH=src python -m repro.launch.qserve_dryrun --arch internlm2-20b
"""
import argparse
import json
import pathlib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args(argv)

    import jax

    from repro.configs import SHAPES, get_config
    from repro.core.apply import restructure
    from repro.core.policy import QuantPolicy
    from repro.engine.autotune import choose_block
    from repro.launch.mesh import make_production_mesh
    from repro.models.attention import flash_fusion
    from repro.models.model import build_model
    from repro.roofline import analysis as roof
    from repro.roofline import hlocost
    from repro.runtime import sharding as shd
    from repro.runtime import steps

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    model = build_model(cfg)
    mesh = make_production_mesh()
    n_data, n_model = shd.mesh_dims(mesh)
    policy = QuantPolicy(bits=4, packed=True)

    aparams = steps.abstract_params(model)
    # Abstract executable tree via the production engine path — grouped
    # fused QKV / gate+up launches, exactly what the server jits.
    qparams_abs = jax.eval_shape(
        lambda p: restructure(p, policy).as_executable(group=True), aparams
    )

    def serve_step(qparams, tokens, cache):
        # hints entered INSIDE the traced body (trace-time capture), same
        # as BatchedServer's decode closure: exact-TP act_constraints plus
        # per-shard autotune keys via tp_shards()
        with shd.sharding_hints(mesh, exact_tp=True):
            return model.decode_step(qparams, tokens, cache)

    abatch = model.input_specs(shape)
    acache = model.cache_specs(shape)
    qpspecs = shd.serve_param_specs(qparams_abs, mesh)
    cspecs = shd.serve_cache_specs(acache, mesh)
    bspecs = shd.batch_specs(abatch, n_data, shd.dp_axes(mesh))
    ns = lambda t: jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )

    with mesh, flash_fusion(True):
        fn = jax.jit(
            serve_step,
            in_shardings=(ns(qpspecs), ns(bspecs["tokens"]), ns(cspecs)),
            donate_argnums=(2,),
        )
        lowered = fn.lower(qparams_abs, abatch["tokens"], acache)
        compiled = lowered.compile()

    lac = hlocost.analyze(compiled.as_text())
    coll = roof.collectives_from_ops(lac.collective_ops, mesh.size,
                                     pod_stride=1 << 30)
    n_params = roof.count_params(aparams)

    # The engine execution plan this lowering dispatched: block choices for
    # each distinct quantized matmul at its PER-SHARD shape (batch over
    # `data`, projection N over `model`) — the same division tp_shards()
    # applies inside the trace, suitable for seeding SPLITQ_TUNE_CACHE.
    m_dec = max(1, shape.global_batch // n_data)  # decode: 1 token/sequence
    h, kv, hd, d, ff = (cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model,
                        cfg.d_ff)
    proj_shapes = {
        "wqkv": (d, (h * hd + 2 * kv * hd) // n_model),
        "wo": (h * hd // n_model, d),
        "w_gateup": (d, 2 * ff // n_model),
        "w_down": (ff // n_model, d),
    }
    engine_blocks = {
        name: list(choose_block(m_dec, k_, n_, policy.bits))
        for name, (k_, n_) in proj_shapes.items()
    }
    rec = {
        "arch": args.arch, "shape": args.shape,
        "mesh": f"{n_data}x{n_model}",
        "variant": "splitquantv2-int4-packed-decode",
        "status": "ok",
        "lowered": "engine-path decode_step (packed executables, "
                   "exact-TP serve shardings)",
        "cache_contract": "per-slot len (B,), per-row KV write offsets",
        "n_params": n_params,
        "t_compute_s": lac.flops / roof.PEAK_FLOPS,
        "t_memory_s": lac.bytes_min / roof.HBM_BW,
        "t_collective_s": (coll.wire_bytes_ici / roof.ICI_BW
                           + coll.wire_bytes_dcn / roof.DCN_BW),
        "bytes_min": lac.bytes_min,
        "coll_by_kind": coll.by_kind,
        "weight_bytes_bf16_per_dev": n_params * 2 / n_model,
        "weight_bytes_packed_per_dev": n_params * 6 / 8 / n_model,
        "engine_blocks": engine_blocks,
        "quant_launches_per_block": {"grouped": 4, "ungrouped": 7},
    }
    mem = compiled.memory_analysis()
    rec["per_device_peak_bytes"] = int(
        mem.argument_size_in_bytes + mem.temp_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes
    )
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    p = out / f"{args.arch}__{args.shape}__qserve.json"
    p.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: v for k, v in rec.items()
                      if not isinstance(v, dict)}, indent=2))


if __name__ == "__main__":
    main()
