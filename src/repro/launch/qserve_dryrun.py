import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Quantized-decode dry-run (Cell C of §Perf): lower serve_step with
SplitQuantV2 INT4 weights stored PACKED in the graph (int8 code/cid planes
as params), dequantized inside the ``fused_kernel`` scope right before each
matmul — modeling kernels/splitq_packed.py (dequant in VMEM). Weight HBM
traffic per decode step drops from bf16 (16 bit/wt) to 6 bit/wt.

The quantized tree is built through the SAME engine path production serving
uses (``restructure(...).as_executable()``, abstract via eval_shape), and
the record now carries the engine's autotuned block dispatch + grouped
launch accounting so the dry-run mirrors the real packed execution plan.
The lowered decode step uses the serving cache contract: per-slot
``cache["len"]: (B,)`` with per-row KV write offsets — the same HLO shape
continuous batching runs, so the modeled bytes/step match production.

    PYTHONPATH=src python -m repro.launch.qserve_dryrun --arch internlm2-20b
"""
import argparse
import json
import pathlib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config
    from repro.core.apply import restructure
    from repro.core.policy import QuantPolicy
    from repro.engine.autotune import choose_block
    from repro.launch.mesh import make_production_mesh
    from repro.models.attention import flash_fusion
    from repro.models.model import build_model
    from repro.roofline import analysis as roof
    from repro.roofline import hlocost
    from repro.runtime import sharding as shd
    from repro.runtime import steps
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    model = build_model(cfg)
    mesh = make_production_mesh()
    steps._configure(mesh)
    policy = QuantPolicy(bits=4, packed=True)

    aparams = steps.abstract_params(model)
    # Abstract executable tree via the production engine path (ungrouped so
    # the modeled materialization keeps the per-projection param layout).
    qparams_abs = jax.eval_shape(
        lambda p: restructure(p, policy).as_executable(group=False), aparams
    )

    def materialize(qparams):
        def deq(leaf):
            w = (jax.vmap(lambda t: t.dequantize())(leaf)
                 if leaf.codes.ndim >= 3 else leaf.dequantize())
            return w.astype(jnp.bfloat16)

        return jax.tree_util.tree_map(
            lambda l: deq(l) if hasattr(l, "dequantize") else l,
            qparams, is_leaf=lambda x: hasattr(x, "dequantize"),
        )

    def serve_step(qparams, batch, cache):
        with shd.sharding_hints(mesh):
            from repro.models.attention import _flash_scope

            with _flash_scope():
                params = materialize(qparams)
            return model.decode_step(params, batch["tokens"], cache)

    abatch = model.input_specs(shape)
    acache = model.cache_specs(shape)
    cspecs = shd.cache_specs_tree(acache, long_context=False,
                                  axes=shd.dp_axes(mesh),
                                  n_dp=mesh.shape["data"], decode=True)
    bspecs = shd.batch_specs(abatch, mesh.shape["data"], shd.dp_axes(mesh))

    # simple spec: shard every packed plane on its largest divisible dim
    def pack_spec(leaf):
        parts = [None] * leaf.ndim
        best, size = None, 0
        for i, s in enumerate(leaf.shape):
            if s % 16 == 0 and s > size:
                best, size = i, s
        if best is not None:
            parts[best] = "model"
        return P(*parts)

    qpspecs = jax.tree.map(pack_spec, qparams_abs)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))

    with mesh, flash_fusion(True):
        fn = jax.jit(
            serve_step,
            in_shardings=(ns(qpspecs), ns(bspecs), ns(cspecs)),
            donate_argnums=(2,),
        )
        lowered = fn.lower(qparams_abs, abatch, acache)
        compiled = lowered.compile()

    lac = hlocost.analyze(compiled.as_text())
    coll = roof.collectives_from_ops(lac.collective_ops, mesh.size,
                                     pod_stride=1 << 30)
    n_params = roof.count_params(aparams)

    # Engine execution plan for this decode shape: grouped launches and the
    # autotuned block dispatch for each distinct quantized matmul, computed
    # on PER-DEVICE shapes (batch sharded over `data`, projection N over
    # `model`) — these are the shapes the kernel actually sees, suitable
    # for seeding SPLITQ_TUNE_CACHE.
    n_data = mesh.shape["data"]
    n_model = mesh.shape["model"]
    m_dec = max(1, shape.global_batch // n_data)  # decode: 1 token/sequence
    h, kv, hd, d, ff = (cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model,
                        cfg.d_ff)
    proj_shapes = {
        "wqkv": (d, (h * hd + 2 * kv * hd) // n_model),
        "wo": (h * hd // n_model, d),
        "w_gateup": (d, 2 * ff // n_model),
        "w_down": (ff // n_model, d),
    }
    engine_blocks = {
        name: list(choose_block(m_dec, k_, n_, policy.bits))
        for name, (k_, n_) in proj_shapes.items()
    }
    rec = {
        "arch": args.arch, "shape": args.shape, "mesh": "16x16",
        "variant": "splitquantv2-int4-packed-decode",
        "status": "ok",
        "cache_contract": "per-slot len (B,), per-row KV write offsets",
        "n_params": n_params,
        "t_compute_s": lac.flops / roof.PEAK_FLOPS,
        "t_memory_s": lac.bytes_min / roof.HBM_BW,
        "t_collective_s": (coll.wire_bytes_ici / roof.ICI_BW
                           + coll.wire_bytes_dcn / roof.DCN_BW),
        "bytes_min": lac.bytes_min,
        "coll_by_kind": coll.by_kind,
        "weight_bytes_bf16_per_dev": n_params * 2 / 16,
        "weight_bytes_packed_per_dev": n_params * 6 / 8 / 16,
        "engine_blocks": engine_blocks,
        "quant_launches_per_block": {"grouped": 4, "ungrouped": 7},
    }
    mem = compiled.memory_analysis()
    rec["per_device_peak_bytes"] = int(
        mem.argument_size_in_bytes + mem.temp_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes
    )
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    p = out / f"{args.arch}__{args.shape}__qserve.json"
    p.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: v for k, v in rec.items()
                      if not isinstance(v, dict)}, indent=2))


if __name__ == "__main__":
    main()
