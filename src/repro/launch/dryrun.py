import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh(es) and extract memory / cost / collective-schedule data.

The two lines above MUST stay the first statements of this module — jax
locks the device count at first init, and only the dry-run is allowed to
see 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                   # everything
  ... --arch gemma3-12b --shape train_4k --mesh single           # one cell
  ... --reduced --devices 4                                      # CI smoke
  ... --out experiments/dryrun                                   # JSON dir

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` containing
memory_analysis, cost_analysis FLOPs/bytes, per-kind collective bytes and
the derived roofline terms (consumed by benchmarks/roofline_table.py and
EXPERIMENTS.md).
"""
import argparse
import json
import pathlib
import sys
import time
import traceback


def _build_mesh(which: str, reduced_devices: int | None):
    import jax
    from repro.launch.mesh import make_mesh, make_production_mesh

    if reduced_devices:
        if which == "multi":
            return make_mesh((2, reduced_devices // 4, 2), ("pod", "data", "model")), f"{2}x{reduced_devices//4}x2"
        return make_mesh((reduced_devices // 2, 2), ("data", "model")), f"{reduced_devices//2}x2"
    if which == "multi":
        return make_production_mesh(multi_pod=True), "2x16x16"
    return make_production_mesh(multi_pod=False), "16x16"


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             out_dir: pathlib.Path, reduced: bool = False,
             reduced_devices: int | None = None,
             fused_attn: bool = False) -> dict:
    import jax
    from repro.configs import SHAPES, applicable, get_config
    from repro.optim.adamw import AdamWConfig
    from repro.roofline import analysis as roof
    from repro.runtime import steps

    cfg = get_config(arch_name)
    shape = SHAPES[shape_name]
    if reduced:
        cfg = cfg.reduced()
        shape = shape.reduced()
    ok, reason = applicable(get_config(arch_name), SHAPES[shape_name])
    rec: dict = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "reduced": reduced,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh, mesh_desc = _build_mesh(mesh_kind, reduced_devices)
    rec["mesh_desc"] = mesh_desc
    rec["fused_attn"] = fused_attn
    n_dev = mesh.size
    t0 = time.time()
    import contextlib

    from repro.models.attention import flash_fusion

    fuse_ctx = flash_fusion(True) if fused_attn else contextlib.nullcontext()
    with mesh, fuse_ctx:
        lowered = steps.lower_cell(cfg, shape, mesh, AdamWConfig())
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k, 0) or 0)
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
        }
        per_dev_bytes = (
            rec["memory_analysis"]["argument_size_in_bytes"]
            + rec["memory_analysis"]["temp_size_in_bytes"]
            + rec["memory_analysis"]["output_size_in_bytes"]
            - rec["memory_analysis"]["alias_size_in_bytes"]
        )
        rec["per_device_peak_bytes"] = int(per_dev_bytes)

        # XLA's module-level cost_analysis counts while bodies once — keep
        # it for reference, but use the loop-aware HLO cost model for the
        # roofline terms (see roofline/hlocost.py).
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # jax <= 0.4.x: one dict per computation
            cost = cost[0] if cost else {}
        rec["cost_analysis_xla"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        from repro.roofline import hlocost

        hlo = compiled.as_text()
        lac = hlocost.analyze(hlo)
        flops = lac.flops
        # roofline memory term uses the ideal-fusion bound (TPU target
        # fuses elementwise chains the CPU-backend HLO leaves unfused);
        # the pessimistic unfused number is kept alongside.
        bytes_accessed = lac.bytes_min
        rec["cost_analysis"] = {
            "flops": flops, "bytes_min": lac.bytes_min,
            "bytes_unfused": lac.bytes_accessed,
            "dot_flops": lac.dot_flops,
            "elementwise_flops": lac.elementwise_flops,
        }
        pod_stride = 256 if mesh_kind == "multi" else 1 << 30
        coll = roof.collectives_from_ops(
            lac.collective_ops, n_dev, pod_stride=pod_stride
        )

    from repro.models.model import build_model
    from repro.runtime.steps import abstract_params

    aparams = abstract_params(build_model(cfg))
    n_params = roof.count_params(aparams)
    act = roof.active_params(cfg, aparams)
    rec["n_params"] = n_params
    rec["active_params"] = act
    mf = roof.model_flops_estimate(cfg, shape, n_params, act)

    rl = roof.Roofline(
        arch=arch_name, shape=shape_name, mesh=mesh_desc, n_devices=n_dev,
        hlo_gflops=flops / 1e9, hlo_bytes=bytes_accessed, coll=coll,
        model_flops=mf, per_device_peak_bytes=rec["per_device_peak_bytes"],
    )
    rec.update(rl.to_json())
    rec["status"] = "ok"
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--reduced", action="store_true", help="CI smoke mode")
    ap.add_argument("--devices", type=int, default=None,
                    help="reduced device count (with --reduced)")
    ap.add_argument("--fused-attn", action="store_true",
                    help="model the flash-attention Pallas kernel in the "
                         "roofline (fused_kernel region accounting)")
    args = ap.parse_args(argv)

    from repro.configs import ALL_SHAPES, ASSIGNED_ARCHS

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}"
                if args.fused_attn:
                    tag += "__fused"
                path = out_dir / f"{tag}.json"
                try:
                    rec = run_cell(arch, shape, mesh_kind, out_dir,
                                   reduced=args.reduced,
                                   reduced_devices=args.devices,
                                   fused_attn=args.fused_attn)
                except Exception as e:  # noqa: BLE001 — report, keep sweeping
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "status": "error", "error": repr(e),
                        "traceback": traceback.format_exc(),
                    }
                    failures += 1
                path.write_text(json.dumps(rec, indent=2))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (
                        f" compile={rec.get('compile_s')}s"
                        f" mem/dev={rec.get('per_device_peak_bytes', 0)/2**30:.2f}GiB"
                        f" bottleneck={rec.get('bottleneck')}"
                    )
                elif status == "skipped":
                    extra = f" ({rec['reason']})"
                else:
                    extra = f" {rec['error']}"
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    if failures:
        print(f"[dryrun] {failures} cell(s) FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
