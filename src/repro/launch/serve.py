"""Batched serving driver with SplitQuantV2 quantized weights.

    PYTHONPATH=src python -m repro.launch.serve --arch llama32-1b --reduced \
        --bits 4 --engine packed --batch 4 --prompt-len 16 --gen 8 \
        --paged --page-size 16 --prefill-chunk 32

Continuous batching: a request queue is packed into fixed batch slots. The
KV cache keeps a PER-SLOT fill length (``cache["len"]: (B,)``), so every
slot decodes at its own position against its own keys; finished sequences
are replaced between decode steps by **batched in-place prefill** waves
that write new prompts straight into the live cache (rows of ongoing
requests are frozen via per-row ``seq_lens``). Prompts are right-padded to
power-of-two buckets, so slot swaps compile once per bucket instead of once
per distinct prompt length, and the decode step never recompiles at all.

``--paged`` swaps the per-slot contiguous KV strips for the PAGED cache
(``repro.kvcache``): attention KV lives in a shared pool of fixed-size
pages, each request owns exactly the pages its prompt+generation needs, and
the scheduler admits by FREE-PAGE BUDGET instead of reserving
``batch × max_len`` up front — one long request no longer dictates the
memory bill for the whole batch. ``--prefill-chunk N`` additionally splits
long prompts into N-token waves interleaved with decode steps, so a giant
prompt doesn't stall ongoing decodes (works for dense caches too).

Sampling: greedy argmax by default; ``--temperature/--top-k/--top-p`` turn
on seeded stochastic sampling (host-side, reproducible via ``--seed``).
``BatchedServer.run(requests, on_token=...)`` streams tokens to the caller
as they decode.

``--engine`` selects how quantized weights execute:
  fake    dequantized dense weights (the paper's fake-quant evaluation)
  packed  6-bit packed storage streamed through the fused Pallas kernels
          with grouped QKV / gate+up launches (4 quantized matmul launches
          per block instead of 7) — the real deployment path
  planes  paper-faithful 3-plane storage through the fused k-plane kernel
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import PageAllocator, pages_for
from repro.models.model import reset_slots


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,)
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    fed: int = 0                # prompt tokens already prefilled (chunked)
    pages: list = dataclasses.field(default_factory=list)  # owned page ids
    kv_reserved_bytes: int = 0  # KV bytes reserved for this request


def sample_token(
    logits: np.ndarray,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
    rng: np.random.Generator | None = None,
) -> int:
    """One token from a (V,) logits row. ``temperature <= 0`` is greedy
    argmax (the deterministic default the serving tests pin); otherwise
    temperature -> top-k filter -> top-p nucleus -> seeded draw."""
    logits = np.asarray(logits, np.float64)
    if temperature <= 0.0:
        return int(np.argmax(logits))
    if rng is None:
        rng = np.random.default_rng()
    logits = logits / temperature
    if 0 < top_k < logits.size:
        kth = np.partition(logits, -top_k)[-top_k]
        logits = np.where(logits < kth, -np.inf, logits)
    logits = logits - logits.max()
    probs = np.exp(logits)
    probs /= probs.sum()
    if top_p < 1.0:
        order = np.argsort(-probs)
        cum = np.cumsum(probs[order])
        # minimal prefix whose mass reaches top_p (always >= 1 token)
        cut = int(np.searchsorted(cum, top_p)) + 1
        nucleus = np.zeros_like(probs)
        nucleus[order[:cut]] = probs[order[:cut]]
        probs = nucleus / nucleus.sum()
    return int(rng.choice(probs.size, p=probs))


def _bucket(n: int, minimum: int) -> int:
    """Next power of two >= max(n, minimum)."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


class BatchedServer:
    """Fixed-slot continuous batching over a decode_step function.

    Slot-swap contract: every prefill wave is ONE batched call into the
    live cache — rows starting a fresh request are reset (``reset_slots``),
    rows mid-prompt continue at their own ``len``, ongoing/finished rows
    are frozen (``lengths == 0``) — and the per-slot cache length makes
    every subsequent step position each request correctly regardless of
    its neighbours.

    Paged mode: attention KV pages are reserved per request at admission
    (``ceil((prompt + gen - 1) / page_size)`` pages — deadlock-free: a
    request that is admitted can always finish) and freed at retirement;
    the scheduler admits while the free-page budget lasts. ``max_len``
    bounds one REQUEST (the page-table width), not the pool — the pool is
    ``num_pages`` and can be far below ``slots × max_len``.

    Chunked prefill: ``prefill_chunk > 0`` feeds prompts in chunk-sized
    waves; ``run`` alternates one prefill wave with one decode step so
    ongoing requests keep emitting tokens while a long prompt loads.
    """

    def __init__(self, model, params, batch_slots: int, max_len: int,
                 bucket_min: int = 8, *, paged: bool = False,
                 page_size: int = 16, num_pages: int | None = None,
                 prefill_chunk: int = 0, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0, seed: int = 0):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.bucket_min = bucket_min
        self.paged = paged
        self.prefill_chunk = prefill_chunk
        self.sampling = {"temperature": temperature, "top_k": top_k,
                         "top_p": top_p}
        self._rng = np.random.default_rng(seed)
        self._on_token: Callable | None = None
        self.active: list[Request | None] = [None] * batch_slots
        self.buckets_used: list[int] = []
        self.events: list[str] = []  # "prefill" / "decode" op trace

        if paged:
            self.page_size = page_size
            pages_per_row = pages_for(max_len, page_size)
            self.num_pages = num_pages or batch_slots * pages_per_row
            self.cache = model.init_paged_cache(
                batch_slots, max_len, page_size=page_size,
                num_pages=self.num_pages,
            )
            self.alloc = PageAllocator(self.num_pages)
            self._table = np.zeros((batch_slots, pages_per_row), np.int32)
            self._table_dirty = False  # host table diverged from device copy
            pool_bytes = sum(
                v.nbytes for k, v in self.cache.items()
                if k in ("pages", "shared_pages")
            )
            self._page_bytes = pool_bytes // self.num_pages
        else:
            self.alloc = None
            self.cache = model.init_cache(batch_slots, max_len)
            kv_bytes = sum(
                v.nbytes for k, v in self.cache.items()
                if k in ("kv", "shared_kv")
            )
            # contiguous strips reserve max_len rows per slot up front
            self._kv_row_bytes = kv_bytes // batch_slots

        self._decode = jax.jit(model.decode_step)

        def _prefill_fn(params, tokens, lengths, fresh, cache):
            cache = reset_slots(cache, fresh)
            return model.prefill(
                params, {"tokens": tokens, "lengths": lengths}, cache
            )

        self._prefill = jax.jit(_prefill_fn)

    # -- sampling / streaming -----------------------------------------------

    def _pick_tokens(self, logits) -> Callable[[int], int]:
        """Per-slot token chooser from device logits (B, 1, V). Greedy mode
        argmaxes ON DEVICE and transfers B ints; stochastic sampling needs
        the full logits rows on the host (B x V, off the hot path)."""
        if self.sampling["temperature"] <= 0.0:
            toks = np.asarray(jnp.argmax(logits[:, 0], -1))
            return lambda i: int(toks[i])
        rows = np.asarray(logits[:, 0])
        return lambda i: sample_token(rows[i], **self.sampling,
                                      rng=self._rng)

    def _emit(self, req: Request, tok: int):
        req.out.append(tok)
        req.done = len(req.out) >= req.max_new
        if self._on_token is not None:
            self._on_token(req, tok)

    # -- slot management ----------------------------------------------------

    def _sync_table(self):
        """Re-upload the page table only when admission/retirement changed
        it — steady-state decode keeps the device copy (it rides through
        every jitted call unchanged in the cache pytree)."""
        if self.paged and self._table_dirty:
            self.cache["page_table"] = jnp.asarray(self._table)
            self._table_dirty = False

    def _fill_slots(self, pending: list[Request]) -> int:
        """Admit waiting requests into free slots, then run one prefill
        wave. Returns the number of requests admitted (0 when the free-page
        budget is exhausted — callers wait for retirements)."""
        free = [i for i in range(self.slots) if self.active[i] is None]
        n = min(len(free), len(pending))
        if not n:
            return 0
        # validate BEFORE mutating active/pending: a rejected request must
        # not strand its wave-mates admitted-but-never-prefilled
        for r in pending[:n]:
            if len(r.prompt) == 0:
                # lengths==0 means "frozen slot": an empty prompt would
                # skip the slot reset and decode the previous occupant
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.max_new < 1:
                # max_new == 0 would under-reserve (prompt - 1 rows) while
                # prefill still writes the full prompt — in paged mode the
                # tail would scatter into a page owned by a live neighbour
                raise ValueError(f"request {r.rid}: max_new must be >= 1")
            # prefill writes len(prompt) KV rows, decode max_new-1 more
            need = len(r.prompt) + r.max_new - 1
            if need > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + gen "
                    f"{r.max_new} needs {need} cache rows > "
                    f"max_len={self.max_len}"
                )
            if self.paged and pages_for(need, self.page_size) > self.num_pages:
                raise ValueError(
                    f"request {r.rid}: needs "
                    f"{pages_for(need, self.page_size)} pages > pool size "
                    f"{self.num_pages}"
                )
        admitted = 0
        for i in free[:n]:
            req = pending[0]
            if self.paged:
                need = pages_for(len(req.prompt) + req.max_new - 1,
                                 self.page_size)
                if not self.alloc.can_alloc(need):
                    break  # budget exhausted: the rest wait for retirements
                req.pages = self.alloc.alloc(need)
                self._table[i, : len(req.pages)] = req.pages
                self._table_dirty = True
                req.kv_reserved_bytes = len(req.pages) * self._page_bytes
            else:
                req.kv_reserved_bytes = self._kv_row_bytes
            pending.pop(0)
            self.active[i] = req
            admitted += 1
        if admitted:
            self._prefill_wave()
        return admitted

    def _retire(self, i: int, req: Request, done: list[Request]):
        done.append(req)
        self.active[i] = None
        if self.paged:
            self.alloc.free(req.pages)
            self._table[i] = 0  # cosmetic: stale ids are unreachable anyway
            self._table_dirty = True

    def _prefill_wave(self) -> bool:
        """ONE batched prefill advancing every mid-prompt row by one chunk
        (the whole remaining prompt when ``prefill_chunk == 0``). Rows whose
        prompt completes get their first token sampled from this wave's
        logits at their own last real position."""
        rows = [(i, r) for i, r in enumerate(self.active)
                if r is not None and r.fed < len(r.prompt)]
        if not rows:
            return False
        chunk = self.prefill_chunk or self.max_len
        sizes = {i: min(chunk, len(r.prompt) - r.fed) for i, r in rows}
        lb = min(_bucket(max(sizes.values()), self.bucket_min), self.max_len)
        self.buckets_used.append(lb)
        tokens = np.zeros((self.slots, lb), np.int32)
        lengths = np.zeros((self.slots,), np.int32)
        fresh = np.zeros((self.slots,), bool)
        for i, r in rows:
            c = sizes[i]
            tokens[i, :c] = r.prompt[r.fed : r.fed + c]
            lengths[i] = c
            fresh[i] = r.fed == 0
            r.fed += c
        self._sync_table()
        logits, self.cache = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(fresh), self.cache,
        )
        self.events.append("prefill")
        pick = self._pick_tokens(logits)
        for i, r in rows:
            if r.fed == len(r.prompt):
                self._emit(r, pick(i))
        return True

    def step(self) -> bool:
        """One decode step for all decode-ready slots; finished, empty and
        mid-prefill slots are masked out (no cache write, no length
        advance)."""
        tokens = np.zeros((self.slots, 1), np.int32)
        active = np.zeros((self.slots,), bool)
        for i, r in enumerate(self.active):
            if (r is not None and not r.done and r.out
                    and r.fed == len(r.prompt)):
                tokens[i, 0] = r.out[-1]
                active[i] = True
        if not active.any():
            return False
        self._sync_table()
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            active=jnp.asarray(active),
        )
        self.events.append("decode")
        pick = self._pick_tokens(logits)
        for i, r in enumerate(self.active):
            if active[i]:
                self._emit(r, pick(i))
        return True

    def run(self, requests: list[Request],
            on_token: Callable[[Request, int], None] | None = None) -> dict:
        """Serve ``requests`` to completion. ``on_token(request, token)``
        streams each decoded token to the caller as it is sampled."""
        self._on_token = on_token
        pending = list(requests)
        done: list[Request] = []
        steps = 0
        t0 = time.time()
        try:
            while True:
                # retire finished slots — including requests whose single
                # token came straight from the previous prefill wave
                for i, r in enumerate(self.active):
                    if r is not None and r.done:
                        self._retire(i, r, done)
                if pending and any(s is None for s in self.active):
                    if self._fill_slots(pending):
                        continue  # retire prefill-finished, refill more
                # interleave: one chunk of prompt feeding, then one decode
                # step — a long prompt never stalls ongoing decodes
                fed = self._prefill_wave()
                stepped = self.step()
                if stepped:
                    steps += 1
                if fed or stepped:
                    continue
                if any(r is not None and r.done for r in self.active):
                    continue  # retire at loop top
                if any(r is not None for r in self.active):
                    raise RuntimeError("scheduler stalled with live slots")
                if pending:
                    continue  # slots all free: next _fill_slots admits
                break
        finally:
            self._on_token = None
        dt = time.time() - t0
        toks = sum(len(r.out) for r in done)
        stats = {
            "requests": len(done), "tokens": toks, "seconds": dt,
            "tok_per_s": toks / max(dt, 1e-9), "decode_steps": steps,
            "prefill_waves": len(self.buckets_used),
            "prefill_buckets": sorted(set(self.buckets_used)),
            "prefill_compiles": self._prefill._cache_size(),
            "decode_compiles": self._decode._cache_size(),
        }
        if done:
            reserved = [r.kv_reserved_bytes for r in done]
            stats["kv_bytes_reserved_per_request"] = {
                "mean": int(np.mean(reserved)), "max": int(max(reserved)),
            }
        if self.paged:
            stats["pages"] = {
                **self.alloc.stats(),
                "page_size": self.page_size,
                "leaked": self.alloc.in_use,
            }
        return stats


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the reduced config (--no-reduced for full)")
    ap.add_argument("--bits", type=int, default=0,
                    help="0 = fp; 2/4/8 = SplitQuantV2 linear quant")
    ap.add_argument("--split", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="outlier-splitting quantization (--no-split "
                         "for the plain linear baseline)")
    ap.add_argument("--engine", default="packed",
                    choices=("fake", "packed", "planes"),
                    help="quantized execution path (see module docstring)")
    ap.add_argument("--no-group", action="store_true",
                    help="disable fused QKV / gate+up kernel launches")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt-lens", default=None,
                    help="comma-separated heterogeneous prompt lengths "
                         "cycled over requests (overrides --prompt-len), "
                         "e.g. 4,16,23")
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="paged KV cache: per-request page reservations "
                         "from a shared pool instead of batch x max_len "
                         "contiguous strips")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page pool size (0 = batch * pages-per-row, "
                         "i.e. dense-equivalent capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prompts into N-token prefill waves "
                         "interleaved with decode steps (0 = whole prompt)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax (default); > 0 samples")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k most likely tokens (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.engine import decode_weight_bytes, weight_bytes
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    w_bytes = decode_weight_bytes(params, tie_embeddings=cfg.tie_embeddings)
    if args.bits:
        t0 = time.time()
        qm = restructure(params, QuantPolicy(
            bits=args.bits, split=args.split,
            packed=args.engine == "packed",
        ))
        if args.engine == "fake":
            params = qm.materialize()
        else:
            params = qm.as_executable(group=not args.no_group)
        w_bytes = decode_weight_bytes(params,
                                      tie_embeddings=cfg.tie_embeddings)
        print(f"[serve] SplitQuantV2 INT{args.bits} preprocessing "
              f"({args.engine} engine): {time.time()-t0:.1f}s, "
              f"{weight_bytes(params)/1e6:.2f} MB weights, "
              f"{w_bytes/1e6:.2f} MB read per decoded token")

    if args.prompt_lens:
        plens = [int(x) for x in args.prompt_lens.split(",")]
    else:
        plens = [args.prompt_len]
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, plens[i % len(plens)],
                                dtype=np.int32), args.gen)
        for i in range(args.requests)
    ]
    server = BatchedServer(
        model, params, args.batch, max(plens) + args.gen + 8,
        paged=args.paged, page_size=args.page_size,
        num_pages=args.num_pages or None,
        prefill_chunk=args.prefill_chunk,
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=args.seed,
    )
    stats = server.run(reqs)
    # decode reads every weight once per step: bytes/token on one chip
    stats["weight_bytes_per_token"] = w_bytes
    stats["engine"] = args.engine if args.bits else "fp"
    print(f"[serve] {stats}")
    if stats["requests"] != len(reqs):
        print(f"[serve] FAIL: served {stats['requests']}/{len(reqs)}")
        return 1
    if stats["decode_compiles"] > 1:
        print(f"[serve] FAIL: decode compiled "
              f"{stats['decode_compiles']}x (must be at most once)")
        return 1
    if args.paged and stats["pages"]["leaked"]:
        print(f"[serve] FAIL: {stats['pages']['leaked']} KV pages leaked")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
