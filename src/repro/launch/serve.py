"""Batched serving driver with SplitQuantV2 quantized weights.

    PYTHONPATH=src python -m repro.launch.serve --arch llama32-1b --reduced \
        --bits 4 --engine packed --batch 4 --prompt-len 16 --gen 8

Continuous batching: a request queue is packed into fixed batch slots. The
KV cache keeps a PER-SLOT fill length (``cache["len"]: (B,)``), so every
slot decodes at its own position against its own keys; finished sequences
are replaced between decode steps by a **batched in-place prefill** that
writes the new prompts straight into the live cache (rows of ongoing
requests are frozen via per-row ``seq_lens``). Prompts are right-padded to
power-of-two buckets, so slot swaps compile once per bucket instead of once
per distinct prompt length, and the decode step never recompiles at all.

``--engine`` selects how quantized weights execute:
  fake    dequantized dense weights (the paper's fake-quant evaluation)
  packed  6-bit packed storage streamed through the fused Pallas kernels
          with grouped QKV / gate+up launches (4 quantized matmul launches
          per block instead of 7) — the real deployment path
  planes  paper-faithful 3-plane storage through the fused k-plane kernel
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import reset_slots


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,)
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, minimum: int) -> int:
    """Next power of two >= max(n, minimum)."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


class BatchedServer:
    """Fixed-slot continuous batching over a decode_step function.

    Slot-swap contract: every wave of newly admitted requests is prefilled
    in ONE batched call into the live cache — recycled slots are reset
    (``reset_slots``), ongoing slots are frozen (``lengths == 0``), and the
    per-slot cache length makes the subsequent decode steps position each
    request correctly regardless of its neighbours."""

    def __init__(self, model, params, batch_slots: int, max_len: int,
                 bucket_min: int = 8):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.bucket_min = bucket_min
        self.cache = model.init_cache(batch_slots, max_len)
        self.active: list[Request | None] = [None] * batch_slots
        self.buckets_used: list[int] = []
        self._decode = jax.jit(model.decode_step)

        def _prefill_fn(params, tokens, lengths, cache):
            cache = reset_slots(cache, lengths > 0)
            return model.prefill(
                params, {"tokens": tokens, "lengths": lengths}, cache
            )

        self._prefill = jax.jit(_prefill_fn)

    # -- slot management ----------------------------------------------------

    def _fill_slots(self, pending: list[Request]):
        """Admit waiting requests into free slots; one batched prefill."""
        free = [i for i in range(self.slots) if self.active[i] is None]
        n = min(len(free), len(pending))
        if not n:
            return
        # validate BEFORE mutating active/pending: a rejected request must
        # not strand its wave-mates admitted-but-never-prefilled
        for r in pending[:n]:
            if len(r.prompt) == 0:
                # lengths==0 means "frozen slot": an empty prompt would
                # skip the slot reset and decode the previous occupant
                raise ValueError(f"request {r.rid}: empty prompt")
            # prefill writes len(prompt) KV rows, decode max_new-1 more;
            # dynamic_update_slice CLAMPS out-of-range writes, which would
            # silently overwrite live entries instead of failing
            need = len(r.prompt) + r.max_new - 1
            if need > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + gen "
                    f"{r.max_new} needs {need} cache rows > "
                    f"max_len={self.max_len}"
                )
        newly = [(i, pending.pop(0)) for i in free[:n]]
        for i, req in newly:
            self.active[i] = req
        lmax = max(len(r.prompt) for _, r in newly)
        lb = min(_bucket(lmax, self.bucket_min), self.max_len)
        self.buckets_used.append(lb)
        tokens = np.zeros((self.slots, lb), np.int32)
        lengths = np.zeros((self.slots,), np.int32)
        for i, req in newly:
            tokens[i, : len(req.prompt)] = req.prompt
            lengths[i] = len(req.prompt)
        logits, self.cache = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths), self.cache
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for i, req in newly:
            req.out.append(int(nxt[i]))
            req.done = len(req.out) >= req.max_new

    def step(self):
        """One decode step for all active slots; finished/empty slots are
        masked out (no cache write, no length advance)."""
        tokens = np.zeros((self.slots, 1), np.int32)
        active = np.zeros((self.slots,), bool)
        for i, r in enumerate(self.active):
            if r is not None and not r.done and r.out:
                tokens[i, 0] = r.out[-1]
                active[i] = True
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            active=jnp.asarray(active),
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            r.out.append(int(nxt[i]))
            if len(r.out) >= r.max_new:
                r.done = True

    def run(self, requests: list[Request]) -> dict:
        pending = list(requests)
        done: list[Request] = []
        steps = 0
        t0 = time.time()
        while True:
            # retire finished slots — including requests whose single
            # token came straight from the previous prefill wave
            for i, r in enumerate(self.active):
                if r is not None and r.done:
                    done.append(r)
                    self.active[i] = None
            if pending and any(s is None for s in self.active):
                self._fill_slots(pending)
                continue  # retire prefill-finished requests, refill more
            if not any(r is not None for r in self.active):
                break
            self.step()
            steps += 1
        dt = time.time() - t0
        toks = sum(len(r.out) for r in done)
        return {
            "requests": len(done), "tokens": toks, "seconds": dt,
            "tok_per_s": toks / max(dt, 1e-9), "decode_steps": steps,
            "prefill_waves": len(self.buckets_used),
            "prefill_buckets": sorted(set(self.buckets_used)),
            "prefill_compiles": self._prefill._cache_size(),
            "decode_compiles": self._decode._cache_size(),
        }


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the reduced config (--no-reduced for full)")
    ap.add_argument("--bits", type=int, default=0,
                    help="0 = fp; 2/4/8 = SplitQuantV2 linear quant")
    ap.add_argument("--split", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="outlier-splitting quantization (--no-split "
                         "for the plain linear baseline)")
    ap.add_argument("--engine", default="packed",
                    choices=("fake", "packed", "planes"),
                    help="quantized execution path (see module docstring)")
    ap.add_argument("--no-group", action="store_true",
                    help="disable fused QKV / gate+up kernel launches")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt-lens", default=None,
                    help="comma-separated heterogeneous prompt lengths "
                         "cycled over requests (overrides --prompt-len), "
                         "e.g. 4,16,23")
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.engine import decode_weight_bytes, weight_bytes
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    w_bytes = decode_weight_bytes(params, tie_embeddings=cfg.tie_embeddings)
    if args.bits:
        t0 = time.time()
        qm = restructure(params, QuantPolicy(
            bits=args.bits, split=args.split,
            packed=args.engine == "packed",
        ))
        if args.engine == "fake":
            params = qm.materialize()
        else:
            params = qm.as_executable(group=not args.no_group)
        w_bytes = decode_weight_bytes(params,
                                      tie_embeddings=cfg.tie_embeddings)
        print(f"[serve] SplitQuantV2 INT{args.bits} preprocessing "
              f"({args.engine} engine): {time.time()-t0:.1f}s, "
              f"{weight_bytes(params)/1e6:.2f} MB weights, "
              f"{w_bytes/1e6:.2f} MB read per decoded token")

    if args.prompt_lens:
        plens = [int(x) for x in args.prompt_lens.split(",")]
    else:
        plens = [args.prompt_len]
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, plens[i % len(plens)],
                                dtype=np.int32), args.gen)
        for i in range(args.requests)
    ]
    server = BatchedServer(model, params, args.batch,
                           max(plens) + args.gen + 8)
    stats = server.run(reqs)
    # decode reads every weight once per step: bytes/token on one chip
    stats["weight_bytes_per_token"] = w_bytes
    stats["engine"] = args.engine if args.bits else "fp"
    print(f"[serve] {stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
