"""Batched serving driver with SplitQuantV2 quantized weights.

    PYTHONPATH=src python -m repro.launch.serve --arch llama32-1b --reduced \
        --bits 4 --engine packed --batch 4 --prompt-len 16 --gen 8

Continuous-batching-lite: a request queue is packed into fixed batch slots;
finished sequences are replaced by waiting requests between decode steps
(slot swap = cache row reset — functional, jit-compatible).

``--engine`` selects how quantized weights execute:
  fake    dequantized dense weights (the paper's fake-quant evaluation)
  packed  6-bit packed storage streamed through the fused Pallas kernels
          with grouped QKV / gate+up launches (4 quantized matmul launches
          per block instead of 7) — the real deployment path
  planes  paper-faithful 3-plane storage through the fused k-plane kernel
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,)
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-slot continuous batching over a decode_step function."""

    def __init__(self, model, params, batch_slots: int, max_len: int):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.active: list[Request | None] = [None] * batch_slots
        self._decode = jax.jit(model.decode_step)

    def _prefill_slot(self, slot: int, req: Request):
        # single-slot prefill, then merge the slot's cache rows in
        cache1 = self.model.init_cache(1, self.max_len)
        logits, cache1 = self.model.prefill(
            self.params, {"tokens": jnp.asarray(req.prompt[None])}, cache1
        )
        def merge(full, one):
            if one.ndim == 0 or full.shape == one.shape:
                return full
            # batch dim differs; find it (first dim where sizes differ)
            for ax in range(one.ndim):
                if one.shape[ax] == 1 and full.shape[ax] == self.slots:
                    return jax.lax.dynamic_update_slice_in_dim(
                        full, one.astype(full.dtype), slot, axis=ax
                    )
            return full
        self.cache = jax.tree.map(merge, self.cache, cache1)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        self.active[slot] = req

    def step(self):
        """One decode step for all active slots."""
        tokens = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.out:
                tokens[i, 0] = r.out[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            r.out.append(int(nxt[i]))
            if len(r.out) >= r.max_new:
                r.done = True

    def run(self, requests: list[Request]) -> dict:
        pending = list(requests)
        done: list[Request] = []
        steps = 0
        t0 = time.time()
        while pending or any(r is not None and not r.done for r in self.active):
            # fill free slots
            for i in range(self.slots):
                r = self.active[i]
                if (r is None or r.done) and pending:
                    if r is not None and r.done:
                        done.append(r)
                    self._prefill_slot(i, pending.pop(0))
            self.step()
            steps += 1
            for i, r in enumerate(self.active):
                if r is not None and r.done and not pending:
                    done.append(r)
                    self.active[i] = None
        dt = time.time() - t0
        toks = sum(len(r.out) for r in done)
        return {"requests": len(done), "tokens": toks, "seconds": dt,
                "tok_per_s": toks / max(dt, 1e-9), "decode_steps": steps}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--bits", type=int, default=0,
                    help="0 = fp; 2/4/8 = SplitQuantV2 linear quant")
    ap.add_argument("--split", action="store_true", default=True)
    ap.add_argument("--engine", default="packed",
                    choices=("fake", "packed", "planes"),
                    help="quantized execution path (see module docstring)")
    ap.add_argument("--no-group", action="store_true",
                    help="disable fused QKV / gate+up kernel launches")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.engine import decode_weight_bytes, weight_bytes
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    w_bytes = decode_weight_bytes(params, tie_embeddings=cfg.tie_embeddings)
    if args.bits:
        t0 = time.time()
        qm = restructure(params, QuantPolicy(
            bits=args.bits, split=args.split,
            packed=args.engine == "packed",
        ))
        if args.engine == "fake":
            params = qm.materialize()
        else:
            params = qm.as_executable(group=not args.no_group)
        w_bytes = decode_weight_bytes(params,
                                      tie_embeddings=cfg.tie_embeddings)
        print(f"[serve] SplitQuantV2 INT{args.bits} preprocessing "
              f"({args.engine} engine): {time.time()-t0:.1f}s, "
              f"{weight_bytes(params)/1e6:.2f} MB weights, "
              f"{w_bytes/1e6:.2f} MB read per decoded token")

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len,
                                dtype=np.int32), args.gen)
        for i in range(args.requests)
    ]
    server = BatchedServer(model, params, args.batch,
                           args.prompt_len + args.gen + 8)
    stats = server.run(reqs)
    # decode reads every weight once per step: bytes/token on one chip
    stats["weight_bytes_per_token"] = w_bytes
    stats["engine"] = args.engine if args.bits else "fp"
    print(f"[serve] {stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
