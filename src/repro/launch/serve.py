"""Batched serving driver with SplitQuantV2 quantized weights.

    PYTHONPATH=src python -m repro.launch.serve --arch llama32-1b --reduced \
        --bits 4 --engine packed --batch 4 --prompt-len 16 --gen 8 \
        --paged --page-size 16 --prefill-chunk 32

Continuous batching: a request queue is packed into fixed batch slots. The
KV cache keeps a PER-SLOT fill length (``cache["len"]: (B,)``), so every
slot decodes at its own position against its own keys; finished sequences
are replaced between decode steps by **batched in-place prefill** waves
that write new prompts straight into the live cache (rows of ongoing
requests are frozen via per-row ``seq_lens``). Prompts are right-padded to
power-of-two buckets, so slot swaps compile once per bucket instead of once
per distinct prompt length, and the decode step never recompiles at all.

``--paged`` swaps the per-slot contiguous KV strips for the PAGED cache
(``repro.kvcache``): attention KV lives in a shared pool of fixed-size
pages, each request owns exactly the pages its prompt+generation needs, and
the scheduler admits by FREE-PAGE BUDGET instead of reserving
``batch × max_len`` up front — one long request no longer dictates the
memory bill for the whole batch. ``--prefill-chunk N`` additionally splits
long prompts into N-token waves interleaved with decode steps, so a giant
prompt doesn't stall ongoing decodes (works for dense caches too).

Sampling: greedy argmax by default; ``--temperature/--top-k/--top-p`` turn
on seeded stochastic sampling (host-side, reproducible via ``--seed``).
``BatchedServer.run(requests, on_token=...)`` streams tokens to the caller
as they decode.

``--prefix-cache`` (paged mode) turns on PREFIX SHARING: after a prompt is
fully prefilled, its full KV pages are indexed by a chain hash of their
token ids (``repro.kvcache.prefix``); a later request whose prompt starts
with the same tokens retains the matched pages read-only into its own page
table and prefills only the unmatched tail — fleets sharing a system
prompt stop paying for the same prefix pages and prefill compute N times.
Shared pages are copy-on-written before any write lands in one, and
reservation accounting is net of shared pages. ``--shared-prefix N``
prepends a common N-token prefix to every generated prompt (workload
shaping for smokes/benches).

``--engine`` selects how quantized weights execute:
  fake    dequantized dense weights (the paper's fake-quant evaluation)
  packed  6-bit packed storage streamed through the fused Pallas kernels
          with grouped QKV / gate+up launches (4 quantized matmul launches
          per block instead of 7) — the real deployment path
  planes  paper-faithful 3-plane storage through the fused k-plane kernel

``--speculate k`` (paged mode) turns on SPECULATIVE DECODING: a draft
model — by default the packed INT4 executable of the SAME weights
(``--draft-engine``/``--draft-bits``), the paper's accuracy result turned
into a latency win — proposes k tokens per request over its own paged KV
cache (``repro.spec``), and the target model scores all k+1 positions in
ONE batched forward (drafted tokens are just a prefill chunk whose logits
we keep). Accepted drafts are emitted in bulk; rejected ones rewind each
slot's ``cache["len"]`` (and, for recurrent families, restore + recompute
the boundary state) with no page leaked or double-written. Greedy decoding
is BIT-IDENTICAL to non-speculative serving; with sampling, standard
rejection sampling against the per-request seeded streams keeps each
emitted token an exact draw from the target distribution.

SERVING UNDER PRESSURE (``--page-growth`` / ``--preemption`` /
``--spec-floor`` / ``--inject`` / ``--max-wall-s``): on-demand page
growth admits requests with a prompt-only (+ ``--growth-headroom``)
reservation and grows their page lists per decode tick, so the same pool
admits MORE concurrent requests than full reservation — at the price of
possible mid-decode exhaustion. When the pool runs dry the scheduler
first evicts cached prefixes, then PREEMPTS a victim (lowest priority,
then youngest-by-emitted-tokens; the oldest live request is always
exempt, which makes forward progress provable — see
``runtime.resilience``): the victim's non-shared pages are released and
it is re-admitted later by replaying prompt + emitted tokens through the
ordinary prefill path, bit-identically for greedy streams. Speculative
requests degrade gracefully: under pool pressure, or when the trailing
acceptance rate sits below ``--spec-floor`` over ``--spec-window``
drafted tokens, a request decodes plainly for the round instead of
failing. SIGTERM (via ``PreemptionGuard``) and the ``--max-wall-s`` soft
deadline drain in-flight requests — finish the current wave, mark live
requests ``preempted`` with their partial streams, free every page. A
seeded fault injector (``--inject oop@tick7,fail@tick3``, see
``runtime.faultinject``) forces pool exhaustion / transient step
failures / latency at chosen decode ticks so chaos tests can assert the
recovery paths are exact.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import (PagePoolGroup, PrefixIndex, copy_page, pages_for,
                           read_pages, write_pages)
from repro.models.model import _RECURRENT_KEYS, reset_slots
from repro.obs import DEFAULT_CAP, JaxProfile, Observability, compile_counts
from repro.obs.trace import now as _now
from repro.runtime import sharding as shd
from repro.runtime.fault import PreemptionGuard, run_with_retries
from repro.runtime.faultinject import FaultInjector
from repro.runtime.resilience import (AcceptanceWindow, SchedulerStall,
                                      SlotDiag, pick_victim, replay_sequence)
from repro.spec import Drafter, SpecStats, Verifier
from repro.spec.policy import accept_greedy, accept_speculative, shaped_probs


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,)
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    fed: int = 0                # prompt tokens already prefilled (chunked)
    pages: list = dataclasses.field(default_factory=list)  # owned page ids
    kv_reserved_bytes: int = 0  # KV bytes reserved for this request
    start_len: int = 0          # prefix-cache hit: first position to prefill
    preloaded: bool = False     # recurrent state installed at admission
    indexed: bool = False       # prompt pages registered in the prefix index
    snaps: dict = dataclasses.field(default_factory=dict)  # boundary -> state
    rng: np.random.Generator | None = None  # per-request sampling stream
    dfed: int = 0               # prompt tokens prefilled into the DRAFT cache
    priority: int = 0           # victim policy: lower preempts first
    status: str = "ok"          # "ok" | "preempted" (drained with a partial
    #                             stream; mid-run preemptions restore to "ok")
    seq_no: int = -1            # admission order; the oldest live request is
    #                             growth-exempt (assigned once, survives replay)
    replay: np.ndarray | None = None  # preempted: tokens to re-prefill
    preemptions: int = 0        # times this request was preempted
    draft_on: bool = False      # drafting decision, frozen at (re)admission
    acc: "AcceptanceWindow | None" = None  # trailing draft acceptance
    spilled: bool = False       # pages live in the host spill store; restore
    #                             reloads them instead of replay recompute
    queued_t: float | None = None  # service submit time (tenant-queue entry
    #                                starts the TTFT clock, not admission)
    force: np.ndarray | None = None  # teacher forcing (eval): emit
    #                             force[len(out)] instead of sampling, while
    #                             the model still scores every position —
    #                             perplexity through the real serving path
    logits: list | None = None  # capture_logits=True: host (V,) logits row
    #                             behind each emitted token, append order ==
    #                             out order (the eval scorers read these)


def sample_token(
    logits: np.ndarray,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
    rng: np.random.Generator | None = None,
) -> int:
    """One token from a (V,) logits row. ``temperature <= 0`` is greedy
    argmax (the deterministic default the serving tests pin); otherwise
    temperature -> top-k filter -> top-p nucleus -> seeded draw. The
    shaping lives in ``spec.policy.shaped_probs`` — the SAME distribution
    the speculative rejection sampler verifies against."""
    if temperature <= 0.0:
        return int(np.argmax(np.asarray(logits)))
    if rng is None:
        rng = np.random.default_rng()
    probs = shaped_probs(np.asarray(logits), temperature=temperature,
                         top_k=top_k, top_p=top_p)
    return int(rng.choice(probs.size, p=probs))


def _bucket(n: int, minimum: int) -> int:
    """Next power of two >= max(n, minimum)."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


# divergence magnitudes span many decades (INT8 KL ~1e-5, INT2 KL ~10), so
# the probe histograms bucket by powers of ten, not the latency buckets
PROBE_BUCKETS = tuple(10.0 ** e for e in range(-8, 3))


class BatchedServer:
    """Fixed-slot continuous batching over a decode_step function.

    Slot-swap contract: every prefill wave is ONE batched call into the
    live cache — rows starting a fresh request are reset (``reset_slots``),
    rows mid-prompt continue at their own ``len``, ongoing/finished rows
    are frozen (``lengths == 0``) — and the per-slot cache length makes
    every subsequent step position each request correctly regardless of
    its neighbours.

    Paged mode: attention KV pages are reserved per request at admission
    (``ceil((prompt + gen - 1) / page_size)`` pages — deadlock-free: a
    request that is admitted can always finish) and freed at retirement;
    the scheduler admits while the free-page budget lasts. ``max_len``
    bounds one REQUEST (the page-table width), not the pool — the pool is
    ``num_pages`` and can be far below ``slots × max_len``.

    Prefix sharing (``prefix_cache=True``, paged only): admission matches
    the new prompt against the prefix index, retains the matched pages
    read-only, and reserves only the tail — ``start_len`` makes prefill
    begin past the shared prefix (positions, write offsets and masks all
    ride the per-row ``len`` contract). A request never scatters into a
    page with refcount > 1: the scheduler copy-on-writes first (fresh
    page, device copy, page-table swap — only a full-prompt page-boundary
    hit triggers it, to re-run the last token for logits). Recurrent
    families (zamba2) additionally need the ssm/conv state at the
    boundary: prefill waves are capped to end on page boundaries so every
    boundary's state is snapshotted into the index, and a hit installs the
    snapshot instead of resetting the slot. Requests admitted in the SAME
    wave cannot share with each other (the index only learns a prompt once
    it is fully prefilled).

    Chunked prefill: ``prefill_chunk > 0`` feeds prompts in chunk-sized
    waves; ``run`` alternates one prefill wave with one decode step so
    ongoing requests keep emitting tokens while a long prompt loads.
    """

    def __init__(self, model, params, batch_slots: int, max_len: int,
                 bucket_min: int = 8, *, paged: bool = False,
                 page_size: int = 16, num_pages: int | None = None,
                 prefix_cache: bool = False, prefix_state_budget: int = 0,
                 prefill_chunk: int = 0, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0, seed: int = 0,
                 speculate: int = 0, draft_params=None,
                 draft_num_pages: int | None = None,
                 page_growth: bool = False, growth_headroom: int = 0,
                 preemption: bool = True, spec_floor: float = 0.0,
                 spec_window: int = 16,
                 inject: "FaultInjector | str | None" = None,
                 guard: PreemptionGuard | None = None,
                 max_wall_s: float = 0.0,
                 spill_store=None, spill_threshold: int = 0,
                 slo=None, mesh=None,
                 obs: Observability | None = None,
                 trace_cap: int = DEFAULT_CAP,
                 profile: JaxProfile | None = None,
                 quality_probe: int = 0, probe_params=None,
                 capture_logits: bool = False):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.bucket_min = bucket_min
        self.paged = paged
        self.prefill_chunk = prefill_chunk
        self.sampling = {"temperature": temperature, "top_k": top_k,
                         "top_p": top_p}
        self._seed = seed
        self._on_token: Callable | None = None
        self.active: list[Request | None] = [None] * batch_slots
        self.buckets_used: list[int] = []
        # -- observability (repro.obs): default ON — registry + tracer +
        # timeline; Observability.disabled() keeps a REAL timeline so the
        # ``events`` compat property behaves identically either way
        if obs is None:
            obs = Observability(
                trace_cap=trace_cap,
                const_labels={"family": model.cfg.family},
            )
        self.obs = obs
        self.registry = obs.registry
        self.tracer = obs.tracer
        self.timeline = obs.timeline
        self.profile = profile
        self.prefill_tokens = 0     # tokens actually fed through prefill
        self.pages_allocated = 0    # fresh pages allocated (incl. COW copies)
        self.prefix_deferrals = 0   # admissions held back for cross-wave dedup
        # -- resilience (see module docstring + runtime.resilience) ---------
        self.page_growth = page_growth
        self.growth_headroom = growth_headroom
        self.preemption = preemption
        self.spec_floor = spec_floor
        self.spec_window = spec_window
        self.inject = (FaultInjector(inject, seed=seed,
                                     registry=self.registry)
                       if isinstance(inject, str) else inject)
        if self.inject is not None and self.inject.registry is None:
            self.inject.registry = self.registry
        self.guard = guard
        self.max_wall_s = max_wall_s
        self.preemptions = 0        # victim preemptions (pool pressure)
        self.replays = 0            # preempted requests re-admitted
        self.replay_tokens = 0      # tokens re-prefilled by those replays
        # -- spill tier (preempt-to-disk, see repro.serve.spill) ------------
        self.spill = spill_store
        self.spill_threshold = spill_threshold
        self.spills = 0             # preempted contexts spilled to the store
        self.spill_restores = 0     # re-admissions restored by page reload
        self.recompute_forwards = 0  # prefill waves that carried replay rows
        if spill_store is not None and not paged:
            raise ValueError("spill_store requires paged=True")
        # -- SLO loop (repro.serve.slo): the controller owns the chunk ------
        self.slo = slo
        self.slo_adjustments = 0
        if slo is not None:
            self.prefill_chunk = slo.chunk
            slo.spec_floor = slo.base_floor = spec_floor or slo.base_floor
            self.spec_floor = slo.spec_floor
        self.peak_concurrency = 0   # most slots simultaneously live
        self.drained = False        # run ended via SIGTERM / wall-clock drain
        self._seq_counter = 0       # admission order for the growth exemption
        self._pending: list[Request] = []
        if page_growth and not paged:
            raise ValueError("page_growth requires paged=True")
        if prefix_cache and not paged:
            raise ValueError("prefix_cache requires paged=True")
        if speculate and not paged:
            raise ValueError("speculate requires paged=True (draft KV and "
                             "verify rollback ride the paged cache)")
        if speculate and draft_params is None:
            raise ValueError("speculate requires draft_params (the draft "
                             "model's executable tree)")
        if speculate and speculate + 1 > max_len:
            raise ValueError(f"speculate={speculate} verify chunk exceeds "
                             f"max_len={max_len}")
        if speculate and (model.cfg.encdec or model.cfg.family == "vlm"):
            raise ValueError(
                f"{model.cfg.name}: speculative decoding covers token-only "
                "LM families (enc-dec / VLM verify_step is a follow-on)"
            )

        # -- mesh plan (GSPMD serving) ---------------------------------------
        # One MeshPlan binds this server run to one (data, model) mesh: DP
        # replica groups split the batch slots (and, in paged mode, the page
        # pool) while TP shards every matmul's output dim under the exact-TP
        # contract (bit-identical greedy streams — see runtime.sharding).
        self._plan = shd.MeshPlan(mesh) if mesh is not None else None
        if self._plan is not None:
            n_rep = self._plan.n_data
            if batch_slots % n_rep:
                raise ValueError(
                    f"batch_slots ({batch_slots}) must divide over the "
                    f"mesh's {n_rep} data replicas")
            self.params, self._param_shd = self._plan.put_params(params)
            params = self.params
        else:
            n_rep = 1
            self._param_shd = None
        self.n_replicas = n_rep
        self._slots_per_rep = batch_slots // n_rep

        if paged:
            self.page_size = page_size
            pages_per_row = pages_for(max_len, page_size)
            self.num_pages = num_pages or batch_slots * pages_per_row
            if self.num_pages % n_rep:
                raise ValueError(
                    f"num_pages ({self.num_pages}) must divide over the "
                    f"mesh's {n_rep} data replicas")
            self.cache = model.init_paged_cache(
                batch_slots, max_len, page_size=page_size,
                num_pages=self.num_pages,
            )
            # replica r owns global page ids [r*n, (r+1)*n): with the pool's
            # PAGE dim batch-sharded over `data`, a replica's pages — and all
            # its COW / copy_page / rewind traffic — stay on its own devices
            self.alloc = PagePoolGroup(self.num_pages, n_rep)
            self._table = np.zeros((batch_slots, pages_per_row), np.int32)
            self._table_dirty = False  # host table diverged from device copy
            pool_bytes = sum(
                v.nbytes for k, v in self.cache.items()
                if k in ("pages", "shared_pages")
            )
            self._page_bytes = pool_bytes // self.num_pages
            # one prefix index per DP replica, each bound to its own pool —
            # a replica's prefix hits retain pages its own devices hold
            self.prefixes = (
                [PrefixIndex(page_size, self.alloc.pools[r],
                             state_budget=prefix_state_budget)
                 for r in range(n_rep)]
                if prefix_cache else None
            )
            self.prefix = self.prefixes[0] if prefix_cache else None
            # recurrent leaves are part of a prefix (KV pages alone are
            # not): their boundary states ride the index as snapshots
            self._recurrent = [k for k in _RECURRENT_KEYS if k in self.cache]
            self._snap_boundaries = bool(self.prefix and self._recurrent)
        else:
            self.alloc = None
            self.prefix = None
            self.prefixes = None
            self._recurrent = []
            self._snap_boundaries = False
            self.cache = model.init_cache(batch_slots, max_len)
            kv_bytes = sum(
                v.nbytes for k, v in self.cache.items()
                if k in ("kv", "shared_kv")
            )
            # contiguous strips reserve max_len rows per slot up front
            self._kv_row_bytes = kv_bytes // batch_slots

        # canonical cache shardings: committed at init, pinned as every
        # jit's cache OUT sharding, and re-committed by _sync_table after
        # host-side cache edits — jitted-call input shardings stay
        # byte-stable so decode compiles exactly once
        if self._plan is not None:
            self._cache_shd = self._plan.cache_shardings(self.cache)
            self.cache = self._plan.put_cache(self.cache, self._cache_shd)
        else:
            self._cache_shd = None

        self.speculate = speculate
        if speculate:
            self.drafter = Drafter(
                model, draft_params, batch_slots, max_len,
                page_size=page_size, width=speculate + 1,
                num_pages=draft_num_pages, plan=self._plan,
                registry=self.registry,
            )
            self.verifier = Verifier(model, params, self._recurrent,
                                     plan=self._plan,
                                     cache_shd=self._cache_shd,
                                     registry=self.registry)
            self.spec = SpecStats(k=speculate)
        else:
            self.drafter = None
            self.verifier = None
            self.spec = None

        plan = self._plan
        if plan is None:
            self._decode = jax.jit(model.decode_step)
        else:
            # the hints context is entered INSIDE the traced body: the
            # exact-TP act_constraints (and the per-shard autotune keys via
            # tp_shards) are captured at trace time, like steps.py
            def _decode_fn(params, tokens, cache, active):
                with plan.hints():
                    return model.decode_step(params, tokens, cache,
                                             active=active)

            self._decode = jax.jit(_decode_fn,
                                   out_shardings=(None, self._cache_shd))

        def _prefill_fn(params, tokens, lengths, fresh, starts, cache):
            # fresh rows restart at ``starts`` (0, or past a shared prefix)
            cache = reset_slots(cache, fresh, starts)
            if plan is not None:
                with plan.hints():
                    return model.prefill(
                        params, {"tokens": tokens, "lengths": lengths}, cache
                    )
            return model.prefill(
                params, {"tokens": tokens, "lengths": lengths}, cache
            )

        if plan is None:
            self._prefill = jax.jit(_prefill_fn)
        else:
            self._prefill = jax.jit(_prefill_fn,
                                    out_shardings=(None, self._cache_shd))

        # -- quality observability (see module docstring) --------------------
        # capture_logits: the eval path asks for the host logits row behind
        # every emitted token; force (per request) teacher-forces the
        # emission. Neither touches the jitted functions.
        self.capture_logits = capture_logits
        # quality_probe=N: every N decode/verify ticks, replay each live
        # row's context through an fp-reference forward and record the
        # logit divergence (KL, top-1 agreement, max-abs-diff) between the
        # reference and the quantized logits THE SERVER JUST COMPUTED. The
        # probe owns a dedicated 1-slot dense cache and its own jit: it
        # never reads or writes the serving cache, never touches
        # self._prefill / self._decode (whose compile counts the stats
        # report), and only consumes host copies of serving logits — the
        # enabled-vs-disabled streams are bit-identical by construction.
        self.quality_probe = quality_probe
        self.probe_samples = 0
        self.probe_agreements = 0
        self._probe_tick = 0
        if quality_probe:
            if probe_params is None:
                raise ValueError("quality_probe requires probe_params "
                                 "(the fp reference weight tree)")
            self._probe_params = probe_params
            self._probe_cache = model.init_cache(1, max_len)

            def _probe_fn(params, tokens, lengths, cache):
                fresh = jnp.ones((tokens.shape[0],), bool)
                starts = jnp.zeros((tokens.shape[0],), jnp.int32)
                cache = reset_slots(cache, fresh, starts)
                return model.prefill(
                    params, {"tokens": tokens, "lengths": lengths}, cache
                )

            self._probe_prefill = jax.jit(_probe_fn)

    # -- sampling / streaming -----------------------------------------------

    def _pick_tokens(self, logits) -> Callable[[int], int]:
        """Per-slot token chooser from device logits (B, 1, V). Greedy mode
        argmaxes ON DEVICE and transfers B ints; stochastic sampling needs
        the full logits rows on the host (B x V, off the hot path).

        Each request draws from its OWN stream seeded by (server seed,
        rid): the sampled tokens depend only on the request and the model,
        not on which slot it landed in, what its neighbours were, or the
        order the scheduler admitted it.

        Eval hooks: ``capture_logits`` appends the host row behind each
        pick to the request's ``logits`` list, and a request's ``force``
        array teacher-forces the emitted token — both need the full rows
        on the host, so the device-argmax fast path only runs when
        neither is in play (serving streams stay untouched)."""
        eval_hooks = self.capture_logits or any(
            r is not None and r.force is not None for r in self.active)
        if self.sampling["temperature"] <= 0.0 and not eval_hooks:
            toks = np.asarray(jnp.argmax(logits[:, 0], -1))
            return lambda i: int(toks[i])
        rows = np.asarray(logits[:, 0])

        def pick(i: int) -> int:
            r = self.active[i]
            if self.capture_logits:
                if r.logits is None:
                    r.logits = []
                r.logits.append(rows[i].copy())
            if r.force is not None and len(r.out) < len(r.force):
                return int(r.force[len(r.out)])
            if self.sampling["temperature"] <= 0.0:
                return int(np.argmax(rows[i]))
            return sample_token(rows[i], **self.sampling, rng=r.rng)

        return pick

    def _emit(self, req: Request, tok: int):
        req.out.append(tok)
        req.done = len(req.out) >= req.max_new
        self.tracer.emit(req.rid)
        if self._on_token is not None:
            self._on_token(req, tok)

    # -- observability --------------------------------------------------------

    @property
    def events(self) -> list[str]:
        """Legacy event strings ("prefill" / "decode" / "verify" /
        "preempt:<rid>" ...), rendered from the structured timeline —
        the compat view over the new source of truth."""
        return self.timeline.legacy_events()

    def _tl(self, kind: str, **fields) -> None:
        """Emit one scheduler-timeline record, stamped with the live
        scheduler state every record shares (active slots; pool free /
        fragmentation in paged mode)."""
        fields["active"] = sum(1 for r in self.active if r is not None)
        if self.paged:
            fields["free_pages"] = self.alloc.free_pages
            fields["frag"] = round(self.alloc.fragmentation(), 4)
        self.timeline.emit(kind, **fields)

    def _span(self, i: int, r: Request, kind: str, t0: float, t1: float,
              out_before: int, **kw) -> None:
        """Attribute one wave's work to request ``r``: a tracer span whose
        ``emitted`` is exactly the tokens this wave appended to ``r.out``
        (so per-request span sums always reconcile with the stream), plus
        the per-replica token counter."""
        emitted = len(r.out) - out_before
        self.tracer.span(r.rid, kind, t0, t1, emitted=emitted, **kw)
        if emitted and self.registry.enabled:
            self.registry.counter(
                "serve_tokens_total", "tokens emitted, by replica",
            ).inc(emitted, replica=self._rep(i))

    # -- online divergence probe (quality observability) ---------------------

    def _probe_due(self) -> bool:
        """Tick the probe clock (one tick per decode/verify round) and
        decide whether this round is a probed one."""
        if not self.quality_probe:
            return False
        self._probe_tick += 1
        return self._probe_tick % self.quality_probe == 0

    def _probe_forward(self, seq: np.ndarray) -> np.ndarray:
        """fp-reference logits after ``seq``: one B=1 prefill over the
        probe's private dense cache, bucketed so the shadow jit compiles
        once per power-of-two length like the serving prefill."""
        lb = min(_bucket(len(seq), self.bucket_min), self.max_len)
        tokens = np.zeros((1, lb), np.int32)
        tokens[0, : len(seq)] = seq
        lengths = np.array([len(seq)], np.int32)
        logits, self._probe_cache = self._probe_prefill(
            self._probe_params, jnp.asarray(tokens), jnp.asarray(lengths),
            self._probe_cache,
        )
        return np.asarray(logits[0, 0])

    def _probe_row(self, r: Request, q_row: np.ndarray) -> None:
        """Compare the quantized serving distribution for request ``r``'s
        next token (``q_row``, already on the host) against the fp
        reference over the same context, and file the divergence into the
        registry/timeline. Host-side only — nothing here can perturb the
        serving streams."""
        seq = np.concatenate([r.prompt, np.asarray(r.out, np.int32)])
        if len(seq) > self.max_len:
            return
        fp = self._probe_forward(seq).astype(np.float64)
        q = np.asarray(q_row, np.float64)
        m = fp.max()
        logp_fp = fp - (m + np.log(np.sum(np.exp(fp - m))))
        mq = q.max()
        logp_q = q - (mq + np.log(np.sum(np.exp(q - mq))))
        kl = float(np.sum(np.exp(logp_fp) * (logp_fp - logp_q)))
        agree = int(np.argmax(fp)) == int(np.argmax(q))
        mad = float(np.max(np.abs(fp - q)))
        self.probe_samples += 1
        self.probe_agreements += int(agree)
        if self.registry.enabled:
            reg = self.registry
            reg.histogram(
                "quality_probe_kl",
                "KL(fp || quantized) of next-token logits at probed "
                "decode positions", buckets=PROBE_BUCKETS,
            ).observe(max(kl, 0.0))
            reg.histogram(
                "quality_probe_max_abs_diff",
                "max |logit_fp - logit_quantized| at probed positions",
                buckets=PROBE_BUCKETS,
            ).observe(mad)
            reg.counter(
                "quality_probe_samples_total", "decode positions probed "
                "against the fp reference").inc()
            if agree:
                reg.counter(
                    "quality_probe_top1_agree_total",
                    "probed positions where fp and quantized argmax "
                    "agree").inc()
        self._tl("probe", rid=r.rid, kl=round(kl, 6), agree=agree,
                 max_abs_diff=round(mad, 6))

    # -- slot management ----------------------------------------------------

    def _rep(self, i: int) -> int:
        """DP replica owning batch slot ``i`` (0 on a single-replica run):
        the cache's slot dim is batch-sharded over ``data``, so contiguous
        slot blocks live on contiguous replica device groups."""
        return i // self._slots_per_rep

    def _prefix_of(self, i: int) -> PrefixIndex | None:
        """Slot ``i``'s replica-local prefix index (None when disabled)."""
        return self.prefixes[self._rep(i)] if self.prefixes else None

    def _put(self, arr):
        """Host batch array -> device; slot-leading arrays shard over the
        data axes under a mesh plan so jitted input shardings never vary."""
        if self._plan is None:
            return jnp.asarray(arr)
        return self._plan.put_batch(arr)

    def _sync_table(self):
        """Re-upload the page table only when admission/retirement changed
        it — steady-state decode keeps the device copy (it rides through
        every jitted call unchanged in the cache pytree). Under a mesh plan
        the whole cache is re-committed to its canonical shardings: host
        edits (COW page copies, snapshot installs, rewinds) leave eager
        result shardings behind, and device_put on an already-canonical
        leaf is a no-op."""
        if self.paged and self._table_dirty:
            self.cache["page_table"] = jnp.asarray(self._table)
            self._table_dirty = False
        if self._plan is not None:
            self.cache = self._plan.put_cache(self.cache, self._cache_shd)

    def _seq(self, r: Request) -> np.ndarray:
        """The token sequence the prefill path feeds for ``r``: its prompt,
        or — after a preemption — the replay sequence (prompt + emitted
        tokens except the last, see ``resilience.replay_sequence``)."""
        return r.replay if r.replay is not None else r.prompt

    def _need_rows(self, r: Request) -> int:
        """KV rows ``r`` still needs END-TO-END from its current sequence:
        prefill writes ``len(seq)`` rows, decode one more per remaining
        token except the last. For a fresh request this is the classic
        ``prompt + max_new - 1``; for a replay it already nets out the
        rows the emitted tokens no longer need."""
        return len(self._seq(r)) + (r.max_new - len(r.out)) - 1

    def _call(self, seam: str, fn: Callable):
        """Run one device step through the fault boundary. With no
        injector installed this is a direct call (the hot path pays
        nothing). Under injection, the seam's slow/fail hooks fire first
        and transient failures retry via ``run_with_retries`` — safe
        because every step is a pure jitted function over an immutable
        cache pytree (re-running cannot double-apply a write), with
        ``OutOfPages`` excluded (deterministic resource condition: the
        scheduler's relief path owns it, not the retry loop).

        With a live registry the step additionally runs under the
        per-seam ``StepTimer`` (``block_until_ready`` + wall clock into
        ``serve_step_seconds{seam=...}``) — pure observation: blocking
        changes when the host sees values, never what they are. A
        ``NullRegistry`` run skips the wrapper entirely."""
        if self.obs.step_timer.enabled:
            inner = fn
            fn = lambda: self.obs.step_timer.run(seam, inner)
        if self.inject is None:
            return fn()

        def step():
            self.inject.on_step(seam)
            return fn()

        return run_with_retries(step, max_retries=3, base_delay_s=0.0,
                                retriable=(RuntimeError,))

    def _draftable(self, r: Request) -> bool:
        """Drafting decision, frozen into ``r.draft_on`` at (re)admission:
        speculation needs at least one draftable step — ``kk = min(k,
        max_new - emitted - 1)`` positive for some future round. Fresh
        requests need ``max_new >= 3``; a replayed request re-decides from
        its emitted count (a nearly-finished victim re-admits as a plain
        verify-wave rider and never re-touches the draft cache)."""
        if self.drafter is None:
            return False
        return r.max_new - (len(r.out) or 1) >= 2

    def _common_prefix_pages(self, a: np.ndarray, b: np.ndarray) -> int:
        """Leading FULL pages on which two prompts are token-identical."""
        ps = self.page_size
        n = 0
        for j in range(min(len(a), len(b)) // ps):
            if not np.array_equal(a[j * ps:(j + 1) * ps],
                                  b[j * ps:(j + 1) * ps]):
                break
            n += 1
        return n

    def _select_for_slots(self, pending: list[Request],
                          free: list[int]) -> list[tuple[int, Request]]:
        """Pair free slots (in index order) with pending requests (in queue
        order), DEFERRING any request whose prompt shares more full pages
        with a not-yet-indexed request on its TARGET REPLICA (already
        active there, or chosen earlier this wave for it) than that
        replica's prefix index can currently serve: admitting it now would
        prefill the common prefix twice, because an index only learns a
        prompt once it is fully prefilled. Serializing just those requests
        turns same-wave duplicates into ordinary cache hits one wave later
        — the deferral resolves as soon as the overlapping request
        finishes prefilling (it is driven by the same run loop), so no
        deadlock. Prefix indexes are replica-local, so only same-replica
        duplicates defer; on a single replica this reduces exactly to the
        old single-index selection."""
        if self.prefixes is None:
            return list(zip(free, pending))
        by_rep: dict[int, list[int]] = {}
        for i in free:
            by_rep.setdefault(self._rep(i), []).append(i)
        # nothing mid-prefill to duplicate against: admit without probing —
        # the steady blocked-on-pool retry path (every active already
        # indexed) never re-hashes prompts
        unindexed: dict[int, list[Request]] = {r: [] for r in by_rep}
        for i, r in enumerate(self.active):
            if r is not None and not r.indexed and self._rep(i) in by_rep:
                unindexed[self._rep(i)].append(r)
        out: list[tuple[int, Request]] = []
        for req in pending:
            if not by_rep:
                break
            rep = min(by_rep, key=lambda r: by_rep[r][0])
            slot = by_rep[rep][0]
            others = unindexed[rep]
            if others:
                overlap = max(
                    self._common_prefix_pages(self._seq(req), self._seq(o))
                    for o in others
                )
                if overlap:
                    matched, _, _ = self.prefixes[rep].match(
                        self._seq(req), need_state=bool(self._recurrent),
                        record=False
                    )
                    if overlap * self.page_size > matched:
                        self.prefix_deferrals += 1
                        continue
            out.append((slot, req))
            unindexed[rep].append(req)
            by_rep[rep].pop(0)
            if not by_rep[rep]:
                del by_rep[rep]
        return out

    def _fill_slots(self, pending: list[Request]) -> int:
        """Admit waiting requests into free slots, then run one prefill
        wave. Returns the number of requests admitted (0 when the free-page
        budget is exhausted — callers wait for retirements — or when every
        pending candidate is deferred for cross-wave prefix dedup)."""
        free = [i for i in range(self.slots) if self.active[i] is None]
        if not free or not pending:
            return 0
        picked = self._select_for_slots(pending, free)
        if not picked:
            return 0
        # validate BEFORE mutating active/pending: a rejected request must
        # not strand its wave-mates admitted-but-never-prefilled
        for _, r in picked:
            if r.rid < 0:
                # the per-request sampling stream seeds from (seed, rid):
                # SeedSequence rejects negatives, and failing AFTER pages
                # are reserved would strand them assigned-but-unadmitted
                raise ValueError(f"request rid must be >= 0, got {r.rid}")
            if len(r.prompt) == 0:
                # lengths==0 means "frozen slot": an empty prompt would
                # skip the slot reset and decode the previous occupant
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.max_new < 1:
                # max_new == 0 would under-reserve (prompt - 1 rows) while
                # prefill still writes the full prompt — in paged mode the
                # tail would scatter into a page owned by a live neighbour
                raise ValueError(f"request {r.rid}: max_new must be >= 1")
            # prefill writes len(seq) KV rows, decode the rest; this bound
            # is ALSO the deadlock-freedom anchor of on-demand growth: a
            # lone request's end-to-end need always fits the pool
            need = self._need_rows(r)
            if need > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(self._seq(r))} + gen "
                    f"{r.max_new} needs {need} cache rows > "
                    f"max_len={self.max_len}"
                )
            if (self.paged
                    and pages_for(need, self.page_size)
                    > self.alloc.per_replica):
                raise ValueError(
                    f"request {r.rid}: needs "
                    f"{pages_for(need, self.page_size)} pages > per-replica "
                    f"pool size {self.alloc.per_replica}"
                )
        admitted = 0
        for i, req in picked:
            if self.paged:
                if not self._admit_paged(i, req):
                    break  # budget exhausted: the rest wait for retirements
            else:
                req.kv_reserved_bytes = self._kv_row_bytes
            if req.rng is None:
                # NOT reset on re-admission: a preempted request's sampling
                # stream continues where it stopped, so sampled streams
                # survive preemption exactly like greedy ones
                req.rng = np.random.default_rng([self._seed, req.rid])
            if req.seq_no < 0:
                # admission order, assigned ONCE: a replayed request keeps
                # its original seq_no, so re-admission restores (not
                # resets) its growth-exemption seniority
                req.seq_no = self._seq_counter
                self._seq_counter += 1
            if req.spilled:
                # preempt-to-disk re-admission: reload page contents from
                # the host store — no replay prefill recompute happens
                self._restore_spill(i, req)
            elif req.replay is not None:
                self.replays += 1
                self.replay_tokens += len(req.replay) - req.start_len
                self.tracer.replay(req.rid,
                                   len(req.replay) - req.start_len)
                self._tl("replay", rid=req.rid,
                         tokens=len(req.replay) - req.start_len)
                self.registry.counter(
                    "resilience_replays_total",
                    "preempted requests re-admitted via replay",
                ).inc(replica=self._rep(i))
            for qi, p in enumerate(pending):  # identity removal: Request
                if p is req:                  # __eq__ compares ndarrays
                    del pending[qi]
                    break
            self.active[i] = req
            req.status = "ok"
            self.tracer.admitted(req.rid, replica=self._rep(i),
                                 prefix_hit_tokens=req.start_len,
                                 pages=len(req.pages))
            req.draft_on = self._draftable(req)
            if req.draft_on:
                # draft high-water: one row less than the target's — the
                # drafter never ingests the final emitted token (absolute
                # positions, so replay does not change it)
                self.drafter.admit(i, len(req.prompt) + req.max_new - 2)
                if self.spec_floor > 0.0 and req.acc is None:
                    req.acc = AcceptanceWindow(self.spec_floor,
                                               self.spec_window)
            admitted += 1
        if admitted:
            self._prefill_wave()
        return admitted

    def _admit_paged(self, i: int, req: Request) -> bool:
        """Reserve pages for ``req`` in slot ``i``; False when the pool
        cannot host it yet (even after evicting cached prefixes).

        With the prefix cache on, the prompt is matched against the index
        first: matched pages are RETAINED (read-only, refcount + 1) into
        the slot's page table, only the unmatched tail is allocated fresh,
        and ``start_len``/``fed`` begin past the shared prefix. A
        full-prompt match on a page boundary rolls back one token (its
        logits must be recomputed to sample the first output) and
        copy-on-writes the boundary page, so the shared copy is never
        scattered into. Recurrent families additionally install the
        boundary's state snapshot in place of the slot reset.

        On-demand growth (``page_growth=True``) reserves only the pages
        the SEQUENCE (+ ``growth_headroom`` tokens) needs — the rest grow
        per decode tick via :meth:`_ensure_rows` — so the same pool
        admits more concurrent requests than full reservation."""
        seq = self._seq(req)
        rep = self._rep(i)
        # a spilled request restores by OVERWRITING its pages with store
        # contents, so it must own every page exclusively: never retain
        # shared prefix pages for it
        prefix = None if req.spilled else self._prefix_of(i)
        np_need = pages_for(self._need_rows(req), self.page_size)
        if self.page_growth:
            goal = max(
                pages_for(min(self._need_rows(req),
                              len(seq) + self.growth_headroom),
                          self.page_size),
                pages_for(len(seq), self.page_size),  # always hold the seq
            )
        else:
            goal = np_need
        shared_tok, shared_pages, state = 0, [], None
        if prefix is not None:
            # dry-run probe: stats count and LRU move only when admission
            # actually commits (this path retries every scheduler step
            # while blocked on the pool)
            shared_tok, shared_pages, state = prefix.match(
                seq, need_state=bool(self._recurrent), record=False
            )
        m = len(shared_pages)
        rollback = m > 0 and shared_tok == len(seq)
        # fresh pages = unmatched tail (+1 when the boundary page is COWed)
        fresh_needed = goal - m + (1 if rollback else 0)
        if m:
            # retain BEFORE any eviction: matched pages must stay live even
            # if eviction drops their index entries
            self.alloc.retain(shared_pages)
        if not self.alloc.can_alloc(fresh_needed, rep):
            if prefix is None or not prefix.evict_for(fresh_needed):
                if m:
                    self.alloc.free(shared_pages)  # undo; retry after retire
                return False
        tail = self.alloc.alloc(goal - m, rep)
        if prefix is not None:
            prefix.record(seq, shared_tok)  # admission commits
        req.pages = shared_pages + tail
        req.start_len = shared_tok - (1 if rollback else 0)
        req.fed = req.start_len
        self._table[i, : len(req.pages)] = req.pages
        self._table_dirty = True
        self.pages_allocated += goal - m
        req.kv_reserved_bytes = (goal - m) * self._page_bytes
        if rollback:
            # the re-run token writes into the last SHARED page: make this
            # slot its exclusive writer first
            self._cow(i, req, req.start_len // self.page_size)
        if state is not None:
            # recurrent prefix: install the boundary snapshot instead of
            # resetting the slot (the wave treats the row as mid-prompt)
            self._install_state(i, state, req.start_len)
            req.preloaded = True
        return True

    def _cow(self, i: int, req: Request, logical_page: int) -> None:
        """Copy-on-write slot ``i``'s ``logical_page`` if it is shared:
        fresh page, device copy of the contents, page-table swap. No-op for
        pages this request already exclusively owns."""
        old = int(self._table[i, logical_page])
        new, copied = self.alloc.cow(old)
        if not copied:
            return
        for key in ("pages", "shared_pages"):
            if key in self.cache:
                self.cache[key] = copy_page(self.cache[key], old, new)
        req.pages[req.pages.index(old)] = new
        self._table[i, logical_page] = new
        self._table_dirty = True
        self.pages_allocated += 1
        req.kv_reserved_bytes += self._page_bytes

    def _cow_guard(self, i: int, req: Request, start: int, n: int) -> None:
        """Enforce the no-shared-writer invariant for a write of ``n``
        tokens at logical positions ``[start, start + n)``: any touched
        page still shared gets copy-on-written before the wave runs. After
        admission this never fires (the boundary COW already ran) — it is
        the structural guarantee, not a hot path."""
        if self.prefixes is None or n <= 0:
            return
        for lp in range(start // self.page_size,
                        (start + n - 1) // self.page_size + 1):
            self._cow(i, req, lp)

    def _install_state(self, i: int, state: dict, start_len: int) -> None:
        """Write a cached recurrent-state snapshot (and the matching fill
        length) into slot ``i``'s cache rows. Admission-path host update —
        off the jitted hot loop."""
        for k, v in state.items():
            self.cache[k] = self.cache[k].at[:, i].set(jnp.asarray(v))
        self.cache["len"] = self.cache["len"].at[i].set(
            jnp.int32(start_len)
        )

    def _index_prompt(self, i: int, req: Request) -> None:
        """Register a fully prefilled prompt's full pages in slot ``i``'s
        replica-local prefix index (with any recurrent boundary snapshots
        captured en route). A replayed sequence indexes like a prompt —
        its full pages are as reusable (and a future replay of the same
        request hits them)."""
        prefix = self._prefix_of(i)
        if prefix is None or req.indexed:
            return
        req.indexed = True
        prefix.insert(self._seq(req), req.pages, states=req.snaps or None)
        req.snaps = {}

    def _retire(self, i: int, req: Request, done: list[Request]):
        done.append(req)
        self.active[i] = None
        if self.paged:
            self.alloc.free(req.pages)
            self._table[i] = 0  # cosmetic: stale ids are unreachable anyway
            self._table_dirty = True
        if self.drafter is not None:
            self.drafter.release(i)  # idempotent; usually already released
        if self.spill is not None and req.spilled:
            # defensive: an active request was restored (spilled cleared),
            # but never leave a retired rid's file behind
            self.spill.drop(req.rid)
            req.spilled = False
        self.tracer.retire(req.rid, req.status, registry=self.registry)
        self.registry.counter(
            "serve_requests_total", "requests retired, by final status",
        ).inc(status=req.status, replica=self._rep(i))

    # -- preemption / on-demand growth (see runtime.resilience) -------------

    def _preempt(self, i: int, req: Request) -> None:
        """Evict ``req`` from slot ``i`` mid-flight: release its pages
        (shared prefix pages are never victim-released — they only lose
        this owner's reference, see ``PageAllocator.free``), invalidate
        its draft state, and requeue it at the FRONT of the pending queue
        with a replay sequence that restores it exactly.

        With a spill store attached, an eligible victim's page contents
        are snapshotted to the host FIRST (before the pages are freed):
        re-admission then restores by page reload instead of replaying
        the sequence through prefill. The replay sequence is still built
        either way — it is the length/readiness contract the scheduler
        reasons with, and the recompute fallback if the store is gone."""
        req.spilled = self._maybe_spill(i, req)
        req.replay = replay_sequence(req.prompt, req.out)
        req.fed = 0
        req.dfed = 0
        req.start_len = 0
        req.preloaded = False
        req.indexed = False
        req.snaps = {}
        req.preemptions += 1
        self.preemptions += 1
        self.alloc.free(req.pages)
        req.pages = []
        self._table[i] = 0
        self._table_dirty = True
        if self.drafter is not None:
            self.drafter.release(i)
        self.active[i] = None
        self._pending.insert(0, req)
        self.tracer.preempted(req.rid)
        self._tl("preempt", rid=req.rid, emitted=len(req.out))
        self.registry.counter(
            "resilience_preemptions_total",
            "victim preemptions on pool pressure",
        ).inc(replica=self._rep(i))
        # structural guarantee, not a hot path: preemption is the one op
        # that frees pages other parties may still reference
        self.alloc.audit()
        if self.prefixes is not None:
            for p in self.prefixes:
                p.audit()

    def _maybe_spill(self, i: int, req: Request) -> bool:
        """Snapshot slot ``i``'s KV page contents (and recurrent state
        rows) into the host spill store, if ``req`` is eligible: a spill
        store is attached, the request is fully prefilled and decoding
        (mid-prefill victims have nothing worth saving — their replay IS
        the remaining prefill), and the context has at least
        ``spill_threshold`` rows (short contexts replay cheaply). Must run
        BEFORE the allocator frees the pages."""
        if self.spill is None or not req.out:
            return False
        if req.fed < len(self._seq(req)):
            return False
        # rows the cache holds for a caught-up decoder == len(replay):
        # prompt + emitted[:-1] (the final token is re-fed, not stored)
        rows = len(req.prompt) + len(req.out) - 1
        if rows < self.spill_threshold:
            return False
        ids = req.pages[: pages_for(rows, self.page_size)]
        payload = {"rows": np.int32(rows)}
        for key in ("pages", "shared_pages"):
            if key in self.cache:
                payload[f"pool.{key}"] = np.asarray(
                    read_pages(self.cache[key], ids))
        for key in self._recurrent:
            payload[f"state.{key}"] = np.asarray(self.cache[key][:, i])
        self.spill.spill(req.rid, payload)
        self.spills += 1
        self._tl("spill", rid=req.rid, rows=rows, pages=len(ids))
        self.registry.counter(
            "resilience_spills_total",
            "preempted contexts spilled to the host store",
        ).inc(replica=self._rep(i))
        return True

    def _restore_spill(self, i: int, req: Request) -> None:
        """Reload a spilled context into slot ``i``'s freshly allocated
        pages: page contents scatter back by physical id, recurrent state
        rows reinstall, and the slot's fill length jumps straight to the
        stored row count — the request is decode-ready without a single
        replay prefill forward (the next decode step re-feeds ``out[-1]``
        exactly as it would after any other wave)."""
        payload = self.spill.restore(req.rid)
        rows = int(payload["rows"])
        ids = req.pages[: pages_for(rows, self.page_size)]
        for key in ("pages", "shared_pages"):
            if key in self.cache:
                self.cache[key] = write_pages(self.cache[key], ids,
                                              payload[f"pool.{key}"])
        for key in self._recurrent:
            self.cache[key] = self.cache[key].at[:, i].set(
                jnp.asarray(payload[f"state.{key}"]))
        self.cache["len"] = self.cache["len"].at[i].set(jnp.int32(rows))
        req.fed = rows  # fully "prefilled": no wave will pick this row up
        # the restored sequence is never re-walked by a prefill wave, so
        # it can never be indexed — mark it so dedup does not wait on it
        req.indexed = True
        req.spilled = False
        self.spill.drop(req.rid)
        self.spill_restores += 1
        self._tl("restore", rid=req.rid, rows=rows, pages=len(ids))
        self.registry.counter(
            "resilience_spill_restores_total",
            "spilled contexts restored by page reload",
        ).inc(replica=self._rep(i))

    def _preempt_one(self, rep: int = 0) -> Request | None:
        """Preempt the policy victim WITHIN replica ``rep`` (lowest
        priority, then youngest, then latest-admitted; the replica's
        oldest live request is always exempt — the deadlock-freedom
        anchor holds per page pool, since preempting a neighbour in
        another replica would relieve nothing). Returns the victim, or
        None when only the exempt request remains."""
        live = [(i, r) for i, r in enumerate(self.active)
                if r is not None and self._rep(i) == rep]
        if len(live) <= 1:
            return None
        exempt = min(r.seq_no for _, r in live)
        pick = pick_victim(live, exempt)
        if pick is None:
            return None
        vi, victim = pick
        self._preempt(vi, victim)
        return victim

    def _ensure_rows(self, i: int, req: Request, rows: int, *,
                     preempt: bool = True) -> bool:
        """Grow slot ``i``'s page list to cover ``rows`` KV rows.

        Relief order on exhaustion: prefix-cache eviction first (free
        capacity, no one loses work), then victim preemption. Returns
        False when the request cannot proceed THIS tick — it was itself
        chosen as the victim, or relief is exhausted/disabled
        (``preempt=False`` is the degradation probe: speculative headroom
        is not worth preempting a neighbour for). A False from a
        non-probe call just skips the row for one tick; retirement or
        relief unblocks it later, and greedy streams are invariant to the
        skipped tick.

        The injector's forced ``oop`` fires here (non-probe calls only)
        and preempts a victim even when the pool could serve the need —
        that is what makes chaos-test preemptions land at exact ticks."""
        need = pages_for(rows, self.page_size) - len(req.pages)
        rep = self._rep(i)
        prefix = self._prefix_of(i)
        if (preempt and self.inject is not None
                and self.inject.take("oop")):
            if not self.preemption:
                return False  # behave like unrelieved exhaustion: skip
            self._preempt_one(rep)
            if self.active[i] is not req:
                return False  # the requester itself was the chosen victim
        if need <= 0:
            return True
        while not self.alloc.can_alloc(need, rep):
            if prefix is not None and prefix.evict_for(need):
                break
            if not (preempt and self.preemption):
                return False
            if self._preempt_one(rep) is None:
                return False  # only the exempt oldest remains
            if self.active[i] is not req:
                return False
        grown = self.alloc.alloc(need, rep)
        self._table[i, len(req.pages): len(req.pages) + need] = grown
        req.pages.extend(grown)
        self._table_dirty = True
        self.pages_allocated += need
        req.kv_reserved_bytes += need * self._page_bytes
        return True

    def _draft_prefill_wave(self) -> bool:
        """Mirror prefill into the DRAFT cache: the drafter scores
        continuations of the same prompt, so it must ingest the prompt too
        (always from position 0 — a target-side prefix hit shares no pages
        with the draft pool). Runs on the same wave cadence as the target
        prefill; logits are discarded."""
        if self.drafter is None:
            return False
        rows = [(i, r) for i, r in enumerate(self.active)
                if r is not None and r.draft_on
                and r.dfed < len(self._seq(r))]
        if not rows:
            return False
        chunk = self.prefill_chunk or self.max_len
        sizes = {i: min(chunk, len(self._seq(r)) - r.dfed) for i, r in rows}
        lb = min(_bucket(max(sizes.values()), self.bucket_min), self.max_len)
        tokens = np.zeros((self.slots, lb), np.int32)
        lengths = np.zeros((self.slots,), np.int32)
        fresh = np.zeros((self.slots,), bool)
        fed_after: dict[int, int] = {}
        for i, r in rows:
            c = sizes[i]
            tokens[i, :c] = self._seq(r)[r.dfed : r.dfed + c]
            lengths[i] = c
            fresh[i] = r.dfed == 0
            r.dfed += c
            fed_after[i] = r.dfed
        self.drafter.prefill_wave(tokens, lengths, fresh, fed_after)
        self._tl("draft_prefill", rows=len(rows))
        return True

    def _prefill_wave(self) -> bool:
        """ONE batched prefill advancing every mid-prompt row by one chunk
        (the whole remaining prompt when ``prefill_chunk == 0``). Rows whose
        prompt completes get their first token sampled from this wave's
        logits at their own last real position."""
        drafted = self._draft_prefill_wave()
        rows = [(i, r) for i, r in enumerate(self.active)
                if r is not None and r.fed < len(self._seq(r))]
        if not rows:
            return drafted
        if any(r.replay is not None for _, r in rows):
            # replay-restore recompute (spill-restored requests never
            # enter a wave — the spill tier's whole point)
            self.recompute_forwards += 1
        chunk = self.prefill_chunk or self.max_len
        sizes = {}
        for i, r in rows:
            c = min(chunk, len(self._seq(r)) - r.fed)
            if self._snap_boundaries:
                # recurrent prefix caching: cap the wave at the next page
                # boundary so its state can be snapshotted for the index
                c = min(c, (r.fed // self.page_size + 1) * self.page_size
                        - r.fed)
            sizes[i] = c
        lb = min(_bucket(max(sizes.values()), self.bucket_min), self.max_len)
        self.buckets_used.append(lb)
        tokens = np.zeros((self.slots, lb), np.int32)
        lengths = np.zeros((self.slots,), np.int32)
        fresh = np.zeros((self.slots,), bool)
        starts = np.zeros((self.slots,), np.int32)
        for i, r in rows:
            c = sizes[i]
            tokens[i, :c] = self._seq(r)[r.fed : r.fed + c]
            lengths[i] = c
            # first wave of a request resets the slot — unless its state
            # was preloaded from the prefix index at admission
            fresh[i] = r.fed == r.start_len and not r.preloaded
            starts[i] = r.start_len
            if self.paged:
                self._cow_guard(i, r, r.fed, c)
            r.fed += c
            self.prefill_tokens += c
        self._sync_table()

        def _wave():
            return self._prefill(
                self.params, self._put(tokens), self._put(lengths),
                self._put(fresh), self._put(starts), self.cache,
            )

        t0 = _now()
        logits, self.cache = self._call("prefill", _wave)
        t1 = _now()
        self._tl("prefill", bucket=lb, rows=len(rows),
                 tokens=int(sum(sizes.values())))
        if self._snap_boundaries:
            for i, r in rows:
                if (not r.indexed and r.fed > 0
                        and r.fed % self.page_size == 0
                        and r.fed not in r.snaps):
                    r.snaps[r.fed] = {
                        k: np.asarray(self.cache[k][:, i])
                        for k in self._recurrent
                    }
        pick = self._pick_tokens(logits)
        for i, r in rows:
            before = len(r.out)
            if r.fed == len(self._seq(r)):
                self._index_prompt(i, r)
                if not r.out:
                    # replayed requests skip this: their first token(s)
                    # were emitted before preemption — the replay tail's
                    # logits would re-derive out[-1], which the next
                    # decode step re-feeds instead
                    self._emit(r, pick(i))
            self._span(i, r, "prefill", t0, t1, before, fed=sizes[i])
        return True

    def step(self) -> bool:
        """One decode step for all decode-ready slots; finished, empty and
        mid-prefill slots are masked out (no cache write, no length
        advance). In growth mode each ready row first secures the page its
        write lands in (:meth:`_ensure_rows`) — a row whose growth fails,
        or that gets preempted by a NEIGHBOUR'S growth, sits the tick out
        (greedy streams are invariant to the skipped tick)."""
        ready = [(i, r) for i, r in enumerate(self.active)
                 if (r is not None and not r.done and r.out
                     and r.fed == len(self._seq(r)))]
        grown: dict[int, bool] = {}
        if self.paged:
            for i, r in ready:
                if self.active[i] is not r:
                    continue  # preempted by an earlier row's growth
                # decode writes ONE row at len(prompt) + len(out) - 1
                grown[i] = self._ensure_rows(i, r,
                                             len(r.prompt) + len(r.out))
        tokens = np.zeros((self.slots, 1), np.int32)
        active = np.zeros((self.slots,), bool)
        for i, r in ready:
            if self.active[i] is not r:
                continue  # a LATER row's growth preempted this one
            if self.paged and not grown.get(i, False):
                continue
            tokens[i, 0] = r.out[-1]
            active[i] = True
            if self.paged:
                # COW if the write page is somehow still shared
                # (post-admission invariant: it never is)
                self._cow_guard(i, r, len(r.prompt) + len(r.out) - 1, 1)
        if not active.any():
            return False
        self._sync_table()

        def _step():
            return self._decode(
                self.params, self._put(tokens), self.cache,
                active=self._put(active),
            )

        t0 = _now()
        logits, self.cache = self._call("decode", _step)
        t1 = _now()
        self._tl("decode", rows=int(active.sum()))
        if self._probe_due():
            rows_host = np.asarray(logits[:, 0])
            for i, r in enumerate(self.active):
                if active[i]:
                    self._probe_row(r, rows_host[i])
        pick = self._pick_tokens(logits)
        for i, r in enumerate(self.active):
            if active[i]:
                before = len(r.out)
                self._emit(r, pick(i))
                self._span(i, r, "decode", t0, t1, before)
        return True

    def _spec_ready(self, i: int, r: Request | None) -> bool:
        """Decode-ready for a speculative round: target prompt fully
        prefilled AND (for drafting requests) the draft cache too — a
        prefix-cache hit can finish the target's prefill first, in which
        case the request waits a wave for its drafter rather than decode
        un-drafted."""
        if r is None or r.done or not r.out or r.fed < len(self._seq(r)):
            return False
        if r.draft_on and r.dfed < len(self._seq(r)):
            return False
        return True

    def _spec_round(self) -> bool:
        """One draft -> verify -> accept/rollback round for every
        decode-ready slot (spec mode's replacement for :meth:`step`).

        Each drafting slot proposes ``kk = min(k, remaining - 1)`` tokens
        (clamped so the verify chunk NEVER writes past the request's
        standard page reservation — speculation needs no extra pages);
        slots out of draft budget ride the same verify wave as plain
        single-token rows, so the target model runs exactly ONE forward
        per round regardless of the mix, and ``decode_step`` is never
        traced in spec mode."""
        rows = [(i, r) for i, r in enumerate(self.active)
                if self._spec_ready(i, r)]
        if not rows:
            return False
        greedy = self.sampling["temperature"] <= 0.0
        deg0 = self.spec.degraded_rounds
        # capacity + degradation phase BEFORE any drafting: decide each
        # row's draft budget under pool pressure / acceptance history
        kks: dict[int, int] = {}
        for i, r in rows:
            if self.active[i] is not r:
                continue  # preempted by an earlier row's growth
            kk = (min(self.speculate, r.max_new - len(r.out) - 1)
                  if r.draft_on else 0)
            if kk > 0 and r.acc is not None and r.acc.degraded():
                # persistent drafter divergence: decode plainly this round;
                # aging the window lets drafting re-probe later
                r.acc.age()
                kk = 0
                self.spec.degraded_rounds += 1
            base_rows = len(r.prompt) + len(r.out)  # plain width-1 write
            if self.paged and kk > 0:
                if not self._ensure_rows(i, r, base_rows + kk,
                                         preempt=False):
                    # pool pressure: speculative HEADROOM is not worth
                    # preempting a neighbour — fall back to plain decode
                    # for this round
                    kk = 0
                    self.spec.degraded_rounds += 1
            if self.paged and not self._ensure_rows(i, r, base_rows + kk):
                continue  # preempted or blocked: sits this round out
            kks[i] = kk
        rows = [(i, r) for i, r in rows
                if self.active[i] is r and i in kks]
        if not rows:
            return False
        jobs = []
        for i, r in rows:
            if kks[i] > 0:
                jobs.append((
                    i,
                    np.concatenate([r.prompt,
                                    np.asarray(r.out, np.int32)]),
                    kks[i],
                ))
        drafts: dict[int, list[int]] = {i: [] for i, _ in rows}
        qdists: dict[int, np.ndarray] = {}
        if jobs:
            d, q = self.drafter.draft_round(
                jobs, sampling=self.sampling,
                rngs={i: self.active[i].rng for i, _, _ in jobs},
            )
            drafts.update(d)
            qdists.update(q)
        # one verify forward scores every row's chunk at once
        width = self.speculate + 1
        tokens = np.zeros((self.slots, width), np.int32)
        lengths = np.zeros((self.slots,), np.int32)
        base = np.zeros((self.slots,), np.int32)
        for i, r in rows:
            di = drafts[i]
            base[i] = len(r.prompt) + len(r.out) - 1
            tokens[i, 0] = r.out[-1]
            tokens[i, 1 : 1 + len(di)] = di
            lengths[i] = 1 + len(di)
            if self.paged:
                self._cow_guard(i, r, int(base[i]), 1 + len(di))
        self._sync_table()

        probe_now = self._probe_due()

        def _score():
            return self.verifier.score(self.cache, tokens, lengths,
                                       greedy=greedy,
                                       keep_logits0=probe_now)

        t0 = _now()
        scores, self.cache, snap = self._call("verify", _score)
        t1 = _now()
        self._tl("verify", rows=len(rows), drafting=len(jobs),
                 k=self.speculate,
                 degraded=self.spec.degraded_rounds - deg0)
        if probe_now:
            # position 0 of the verify chunk is the target distribution
            # after the last emitted token — the same quantity step()
            # probes in plain mode
            logits0 = self.verifier.last_logits0
            for i, r in rows:
                self._probe_row(r, logits0[i])
        self.spec.rounds += 1
        self.spec.target_forwards += 1
        # host-side acceptance per request, then one batched rollback
        new_lens = base + lengths  # post-verify lens
        rejected = np.zeros((self.slots,), bool)
        verdicts: dict[int, int] = {}
        emits: dict[int, int] = {}
        for i, r in rows:
            di = drafts[i]
            if greedy:
                m, tok = accept_greedy(di, scores[i])
            else:
                p = np.stack([
                    shaped_probs(scores[i, j], **self.sampling)
                    for j in range(len(di) + 1)
                ])
                m, tok = accept_speculative(di, qdists.get(i), p, r.rng)
            self.spec.drafted += len(di)
            self.spec.accepted += m
            if len(di) and self.registry.enabled:
                self.registry.counter(
                    "spec_drafted_total", "draft tokens proposed",
                ).inc(len(di), replica=self._rep(i))
                if m:
                    self.registry.counter(
                        "spec_accepted_total",
                        "draft tokens that survived verification",
                    ).inc(m, replica=self._rep(i))
            if r.acc is not None and len(di):
                r.acc.record(len(di), m)
            if kks[i] > 0:
                verdicts[i] = m
            if m < len(di):  # rejected suffix: un-write it
                rejected[i] = True
                new_lens[i] = base[i] + m + 1
            emits[i] = tok
        if rejected.any():
            self.cache = self.verifier.rollback(
                self.cache, snap, base, new_lens, rejected, tokens
            )
            if self._recurrent:
                self.spec.recompute_forwards += 1
                self.spec.target_forwards += 1
        if verdicts:
            self.drafter.finish_round(verdicts)
        for i, r in rows:
            before = len(r.out)
            for t in drafts[i][: verdicts.get(i, 0)]:
                self._emit(r, t)
                self.spec.emitted += 1
            self._emit(r, emits[i])
            self.spec.emitted += 1
            self._span(i, r, "verify", t0, t1, before,
                       drafted=len(drafts[i]),
                       accepted=verdicts.get(i, 0))
            if r.draft_on and r.max_new - len(r.out) - 1 <= 0:
                # out of draft budget: the drafter is done with this slot
                # one round before the target retires — release its pages
                self.drafter.release(i)
        return True

    def _stall(self) -> SchedulerStall:
        """Build the diagnostic stall exception from live-slot state."""
        diags = []
        for i, r in enumerate(self.active):
            if r is None:
                continue
            pend = 0
            if self.paged:
                # end-of-decode row need, valid mid-flight (``_need_rows``
                # is admission-time: it folds emitted tokens into the
                # replay sequence, which a live slot hasn't built)
                end_rows = len(r.prompt) + r.max_new - 1
                pend = max(pages_for(end_rows, self.page_size)
                           - len(r.pages), 0)
            diags.append(SlotDiag(
                slot=i, rid=r.rid, seq_len=len(self._seq(r)), fed=r.fed,
                emitted=len(r.out), max_new=r.max_new,
                pages_held=len(r.pages), pages_pending=pend,
            ))
        return SchedulerStall(
            diags, self.alloc.free_pages if self.paged else None,
            recent=self.timeline.tail(8))

    def _slo_tick(self) -> None:
        """Close the SLO loop once per decode tick: hand the tracer's
        token-granular TTFT/TPOT observations to the controller and apply
        whatever it decides — a new chunked-prefill budget (greedy streams
        are chunk-invariant, so retuning live never changes tokens) and/or
        a raised speculative acceptance floor (live requests' trailing
        windows pick it up immediately)."""
        if self.slo is None:
            return
        for kind, seconds in self.tracer.drain_observations():
            self.slo.observe(kind, seconds)
        chunk, floor = self.slo.tick()
        if chunk != self.prefill_chunk:
            self.prefill_chunk = chunk
            self.slo_adjustments += 1
            self._tl("slo", chunk=chunk, floor=round(floor, 4))
            if self.registry.enabled:
                self.registry.gauge(
                    "slo_prefill_chunk",
                    "SLO-tuned chunked-prefill budget",
                ).set(chunk)
        if floor != self.spec_floor:
            self.spec_floor = floor
            self.slo_adjustments += 1
            for r in self.active:
                if r is not None and r.acc is not None:
                    r.acc.floor = floor

    def _drain_due(self, t0: float) -> bool:
        if self.guard is not None and self.guard.requested:
            return True
        return bool(self.max_wall_s) and time.time() - t0 > self.max_wall_s

    def _drain(self, done: list[Request]) -> None:
        """Graceful shutdown: the current wave already finished (checked
        at the loop top), so live requests retire with their partial
        streams (tokens were streamed via ``on_token`` as they decoded)
        under ``status='preempted'``; nothing new is admitted; every page
        is freed — a drained server must leak nothing."""
        self.drained = True
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if not r.done:  # a finished row retires normally, status "ok"
                r.status = "preempted"
                r.done = True
            self._retire(i, r, done)
        for r in self._pending:
            r.status = "preempted"
            if r.spilled and self.spill is not None:
                # a spilled-but-never-restored context: its file would
                # orphan (zero spill files after a drain is an invariant)
                self.spill.drop(r.rid)
                r.spilled = False
        self._tl("drain", unserved=len(self._pending))

    def run(self, requests: list[Request],
            on_token: Callable[[Request, int], None] | None = None, *,
            feed: Callable[[], list[Request]] | None = None,
            idle_wait_s: float = 0.002) -> dict:
        """Serve ``requests`` to completion. ``on_token(request, token)``
        streams each decoded token to the caller as it is sampled.

        ``feed`` turns the batch loop into a SERVICE loop: it is polled
        every scheduler iteration for newly admitted requests (the asyncio
        front-end hands it ``FairScheduler.drain``), and an idle server
        waits ``idle_wait_s`` instead of exiting — the run then ends only
        through the drain path (SIGTERM guard or ``max_wall_s``)."""
        if self.speculate and any(r.force is not None for r in requests):
            raise ValueError(
                "teacher forcing (Request.force) is incompatible with "
                "speculative decoding: drafts would verify against the "
                "model's own continuation, not the forced one")
        self._on_token = on_token
        self._pending = list(requests)
        for r in self._pending:
            self.tracer.queued(r.rid, r.queued_t)
        done: list[Request] = []
        steps = 0
        t0 = time.time()
        try:
            while True:
                # decode-step counter = the chaos tick clock AND the
                # timeline/profiler tick clock
                self.timeline.set_tick(steps)
                if self.inject is not None:
                    self.inject.set_tick(steps)
                if self.profile is not None:
                    self.profile.on_tick(steps)
                if feed is not None:
                    for r in feed():
                        self._pending.append(r)
                        self.tracer.queued(r.rid, r.queued_t)
                if self._drain_due(t0):
                    self._drain(done)
                    break
                # retire finished slots — including requests whose single
                # token came straight from the previous prefill wave
                for i, r in enumerate(self.active):
                    if r is not None and r.done:
                        self._retire(i, r, done)
                if self._pending and any(s is None for s in self.active):
                    if self._fill_slots(self._pending):
                        self.peak_concurrency = max(
                            self.peak_concurrency,
                            sum(1 for r in self.active if r is not None),
                        )
                        continue  # retire prefill-finished, refill more
                self.peak_concurrency = max(
                    self.peak_concurrency,
                    sum(1 for r in self.active if r is not None),
                )
                # interleave: one chunk of prompt feeding, then one decode
                # step — a long prompt never stalls ongoing decodes
                fed = self._prefill_wave()
                stepped = (self._spec_round() if self.speculate
                           else self.step())
                if stepped:
                    steps += 1
                    self._slo_tick()
                if fed or stepped:
                    continue
                if any(r is not None and r.done for r in self.active):
                    continue  # retire at loop top
                if any(r is not None for r in self.active):
                    raise self._stall()
                if self._pending:
                    continue  # slots all free: next _fill_slots admits
                if feed is not None:
                    # service mode: idle is not done — wait for traffic
                    # until the guard/wall-clock drain says stop
                    time.sleep(idle_wait_s)
                    continue
                break
        finally:
            self._on_token = None
            if self.profile is not None:
                self.profile.stop()
        dt = time.time() - t0
        return self._build_stats(done, steps, dt)

    def _build_stats(self, done: list[Request], steps: int,
                     dt: float) -> dict:
        """THE stats builder: one registry-backed assembly of the
        end-of-run stats dict (CLI, bench and tests all read this shape)
        that simultaneously files the same numbers into the metrics
        registry — the dict and ``Registry.snapshot()`` can never
        disagree because they are built from one pass."""
        toks = sum(len(r.out) for r in done)
        cc = compile_counts(prefill=self._prefill, decode=self._decode)
        stats = {
            "requests": len(done), "tokens": toks, "seconds": dt,
            "tok_per_s": toks / max(dt, 1e-9), "decode_steps": steps,
            "prefill_waves": len(self.buckets_used),
            "prefill_buckets": sorted(set(self.buckets_used)),
            "prefill_compiles": cc["prefill"],
            "decode_compiles": cc["decode"],
            "prefill_tokens": self.prefill_tokens,
        }
        stats["resilience"] = {
            "page_growth": self.page_growth,
            "preemptions": self.preemptions,
            "replays": self.replays,
            "replay_tokens": self.replay_tokens,
            "degraded_rounds": (self.spec.degraded_rounds
                                if self.spec else 0),
            "peak_concurrency": self.peak_concurrency,
            "drained": self.drained,
            "preempted_requests": sum(1 for r in done
                                      if r.status == "preempted"),
            "unserved": len(self._pending),
            "spills": self.spills,
            "spill_restores": self.spill_restores,
            "recompute_forwards": self.recompute_forwards,
        }
        if self.spill is not None:
            stats["resilience"]["spill_store"] = self.spill.stats()
        if self.slo is not None:
            stats["slo"] = {
                "adjustments": self.slo_adjustments,
                "chunk": self.prefill_chunk,
                "spec_floor": self.spec_floor,
                "ticks": self.slo.ticks,
                "history": list(self.slo.history)[-32:],
            }
        if self.inject is not None:
            stats["resilience"]["injected"] = self.inject.summary()
        if self.paged:
            self.alloc.audit()  # end-of-run structural check
        if done:
            reserved = [r.kv_reserved_bytes for r in done]
            stats["kv_bytes_reserved_per_request"] = {
                "mean": int(np.mean(reserved)), "max": int(max(reserved)),
            }
        if self.paged:
            cached = (sum(p.pages_held for p in self.prefixes)
                      if self.prefixes else 0)
            stats["pages"] = {
                **self.alloc.stats(),
                "page_size": self.page_size,
                "pages_allocated": self.pages_allocated,
                "prefix_cached": cached,
                # pages held past retirement are LEAKED unless the prefix
                # cache deliberately holds them (drop_prefix_cache releases
                # those and must return the pool to zero in use)
                "leaked": self.alloc.in_use - cached,
            }
            if self.prefixes is not None:
                stats["prefix"] = self._prefix_stats()
                stats["prefix"]["admission_deferrals"] = self.prefix_deferrals
        if self._plan is not None:
            stats["mesh"] = {
                "data": self._plan.n_data,
                "model": self._plan.n_model,
                "devices": self._plan.n_data * self._plan.n_model,
            }
            if self.paged:
                # peak KV bytes each DP replica's page pool committed —
                # the per-device memory bill the mesh run actually pays
                stats["mesh"]["kv_reserved_bytes_per_replica"] = [
                    a.peak_in_use * self._page_bytes
                    for a in self.alloc.pools
                ]
        if self.speculate:
            self.spec.draft_forwards = self.drafter.forwards
            stats["spec"] = {
                **self.spec.summary(),
                "verify_compiles": self.verifier.compiles,
                "draft_compiles": self.drafter.compiles(),
                # the draft pool must drain like the target pool: a draft
                # page alive after every request retired is a real leak
                "draft_pages_leaked": self.drafter.alloc.in_use,
            }
        if self.quality_probe:
            stats["probe"] = {
                "every": self.quality_probe,
                "samples": self.probe_samples,
                "top1_agreements": self.probe_agreements,
                "top1_agreement_rate": (
                    self.probe_agreements / max(self.probe_samples, 1)
                ),
            }
        self._export_metrics(stats, cc)
        stats["obs"] = {
            "trace_events": self.timeline.seq,
            "trace_dropped": self.timeline.dropped,
            "requests": self.tracer.summary(),
            "step_time": self.obs.step_timer.summary(),
        }
        return stats

    def _export_metrics(self, stats: dict, cc: dict) -> None:
        """File the end-of-run scheduler/pool/prefix/spec state into the
        registry as gauges (event-shaped metrics — tokens, requests,
        preemptions, faults — were already counted live where they
        happened). No-ops wholesale under a ``NullRegistry``."""
        reg = self.registry
        if not reg.enabled:
            return
        g = reg.gauge
        g("serve_decode_ticks", "decode/verify rounds run").set(
            stats["decode_steps"])
        g("serve_prefill_waves", "batched prefill waves run").set(
            stats["prefill_waves"])
        g("serve_prefill_tokens", "tokens fed through prefill").set(
            stats["prefill_tokens"])
        g("serve_tok_per_s", "end-of-run decode throughput").set(
            stats["tok_per_s"])
        compiles = dict(cc)
        if self.speculate:
            compiles["verify"] = self.verifier.compiles
            for k, v in self.drafter.compiles().items():
                compiles[f"draft_{k}"] = v
        for step, n in compiles.items():
            g("serve_jit_compiles",
              "compilation-cache size per jitted step").set(n, step=step)
        res = stats["resilience"]
        g("resilience_peak_concurrency",
          "most slots simultaneously live").set(res["peak_concurrency"])
        g("resilience_degraded_rounds",
          "spec rounds decoded plainly under pressure").set(
            res["degraded_rounds"])
        g("resilience_drained", "1 when the run ended by drain").set(
            int(res["drained"]))
        if self.paged:
            for r, a in enumerate(self.alloc.pools):
                ps = a.stats()
                g("kv_pages_in_use", "pool pages held, per replica").set(
                    ps["in_use"], replica=r)
                g("kv_pages_free", "pool pages free, per replica").set(
                    ps["free"], replica=r)
                g("kv_pages_peak", "peak pool pages held").set(
                    ps["peak_in_use"], replica=r)
                g("kv_pool_fragmentation",
                  "free-list discontiguity, 0..1").set(
                    ps["fragmentation"], replica=r)
                g("kv_cow_copies", "copy-on-write page copies").set(
                    ps["cow_copies"], replica=r)
            g("kv_pages_allocated",
              "fresh pages allocated (incl. COW copies)").set(
                self.pages_allocated)
            g("kv_pages_leaked",
              "pages held past retirement, net of prefix cache").set(
                stats["pages"]["leaked"])
        if self.prefixes is not None:
            for r, p in enumerate(self.prefixes):
                ps = p.stats()
                g("prefix_hits", "prefix-cache hits, per replica").set(
                    ps["hits"], replica=r)
                g("prefix_misses", "prefix-cache misses").set(
                    ps["misses"], replica=r)
                g("prefix_hit_tokens",
                  "prompt tokens served from cached prefixes").set(
                    ps["hit_tokens"], replica=r)
                g("prefix_entries", "live prefix-index entries").set(
                    ps["entries"], replica=r)
                g("prefix_pages_held",
                  "pool pages the index keeps alive").set(
                    ps["pages_held"], replica=r)
        if self._plan is not None:
            g("mesh_data_replicas", "DP replica groups").set(
                self._plan.n_data)
            g("mesh_model_shards", "TP shards per replica").set(
                self._plan.n_model)
        if self.speculate:
            sp = stats["spec"]
            g("spec_acceptance_rate",
              "accepted / drafted over the run").set(sp["acceptance_rate"])
            g("spec_emitted_per_target_forward",
              "speculative figure of merit").set(
                sp["emitted_per_target_forward"])
        g("obs_trace_events", "timeline records ever emitted").set(
            self.timeline.seq)
        g("obs_trace_dropped",
          "timeline records dropped by the ring buffer").set(
            self.timeline.dropped)

    def _prefix_stats(self) -> dict:
        """Aggregate prefix-index stats: the single index's dict on one
        replica (unchanged keys for existing callers), summed counters plus
        a per-replica breakdown under DP."""
        if len(self.prefixes) == 1:
            return self.prefixes[0].stats()
        per = [p.stats() for p in self.prefixes]
        out = {k: sum(s[k] for s in per) for k in per[0]}
        out["per_replica"] = per
        return out

    def drop_prefix_cache(self) -> None:
        """Release every page the prefix indexes hold (cache teardown).
        With no live requests, every replica's pool must return to zero
        pages in use — anything left is a real leak."""
        if self.prefixes is not None:
            for p in self.prefixes:
                p.release_all()


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the reduced config (--no-reduced for full)")
    ap.add_argument("--bits", type=int, default=0,
                    help="0 = fp; 2/4/8 = SplitQuantV2 linear quant")
    ap.add_argument("--split", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="outlier-splitting quantization (--no-split "
                         "for the plain linear baseline)")
    ap.add_argument("--engine", default="packed",
                    choices=("fake", "packed", "planes"),
                    help="quantized execution path (see module docstring)")
    ap.add_argument("--no-group", action="store_true",
                    help="disable fused QKV / gate+up kernel launches")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt-lens", default=None,
                    help="comma-separated heterogeneous prompt lengths "
                         "cycled over requests (overrides --prompt-len), "
                         "e.g. 4,16,23")
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="paged KV cache: per-request page reservations "
                         "from a shared pool instead of batch x max_len "
                         "contiguous strips")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page pool size (0 = batch * pages-per-row, "
                         "i.e. dense-equivalent capacity)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="share common prompt prefixes via page refcounts "
                         "(paged mode): matched full pages are retained "
                         "read-only, only the tail is prefilled")
    ap.add_argument("--prefix-state-budget", type=int, default=0,
                    help="byte cap on recurrent boundary-state snapshots "
                         "held by the prefix index (0 = unbounded); over "
                         "budget, LRU entries lose their snapshot but keep "
                         "their KV pages")
    ap.add_argument("--speculate", type=int, default=0,
                    help="speculative decoding: draft k tokens per request "
                         "with the quantized draft model and verify them "
                         "in one target forward (paged mode; 0 = off)")
    ap.add_argument("--draft-engine", default="packed",
                    choices=("fake", "packed", "planes"),
                    help="execution path for the draft model (built from "
                         "the same weights; packed INT4 is the point)")
    ap.add_argument("--draft-bits", type=int, default=4,
                    help="draft-model quantization bits (SplitQuantV2)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common N-token prefix to every "
                         "generated prompt (shared-prompt workload "
                         "shaping for smokes/benches)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prompts into N-token prefill waves "
                         "interleaved with decode steps (0 = whole prompt)")
    ap.add_argument("--page-growth", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="admit with a prompt-only (+headroom) page "
                         "reservation and grow per decode tick (paged "
                         "mode): more admitted concurrency, preemption "
                         "handles mid-decode exhaustion")
    ap.add_argument("--growth-headroom", type=int, default=0,
                    help="extra tokens reserved beyond the prompt at "
                         "admission in growth mode")
    ap.add_argument("--preemption", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="preempt+replay victims on pool exhaustion; "
                         "--no-preemption skips ticks instead and may "
                         "stall (SchedulerStall)")
    ap.add_argument("--spec-floor", type=float, default=0.0,
                    help="trailing draft acceptance-rate floor below "
                         "which a request decodes plainly for the round "
                         "(0 = never degrade)")
    ap.add_argument("--spec-window", type=int, default=16,
                    help="drafted tokens in the acceptance window")
    ap.add_argument("--inject", default="",
                    help="fault plan, e.g. oop@tick7,fail@tick3,slow@tick5 "
                         "(see repro.runtime.faultinject); with greedy "
                         "sampling the CLI re-runs the workload cleanly "
                         "and FAILS unless streams match bit-exactly")
    ap.add_argument("--spill-dir", default="",
                    help="preempt-to-disk tier: spill eligible preempted "
                         "contexts' KV pages to .npz files under this "
                         "directory and restore by page reload instead of "
                         "replay recompute (paged mode; empty = off)")
    ap.add_argument("--spill-threshold", type=int, default=0,
                    help="minimum cache rows (prompt + emitted - 1) a "
                         "preempted context must hold to spill; shorter "
                         "contexts replay through prefill instead")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="time-to-first-token target: when the trailing "
                         "median exceeds it (and TPOT is healthy) the SLO "
                         "controller GROWS the chunked-prefill budget "
                         "(0 = no target)")
    ap.add_argument("--slo-tpot-ms", type=float, default=0.0,
                    help="inter-token latency target: violations SHRINK "
                         "the chunked-prefill budget (decode interleaves "
                         "more) and raise the spec degradation floor "
                         "(0 = no target)")
    ap.add_argument("--slo-chunk-min", type=int, default=8,
                    help="smallest chunked-prefill budget the SLO "
                         "controller may tune down to")
    ap.add_argument("--max-wall-s", type=float, default=0.0,
                    help="soft deadline: drain in-flight requests (partial "
                         "streams, status=preempted, zero leaks) and exit "
                         "cleanly after S seconds (0 = off)")
    ap.add_argument("--mesh", default="",
                    help="serve on a DxM (data x model) device mesh, e.g. "
                         "2x2: D data-parallel replica groups split the "
                         "batch slots and page pool, M-way tensor "
                         "parallelism shards every matmul's output dim "
                         "(exact-TP: greedy streams stay bit-identical to "
                         "the single-device path). Empty = no mesh.")
    ap.add_argument("--obs", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="telemetry registry + per-request tracing "
                         "(--no-obs swaps in the no-op registry; the "
                         "scheduler timeline stays on either way)")
    ap.add_argument("--metrics-out", default="",
                    help="write the end-of-run metrics snapshot to this "
                         "path in Prometheus text format")
    ap.add_argument("--trace-out", default="",
                    help="write the scheduler timeline to this path as "
                         "JSONL (meta head line + one record per event)")
    ap.add_argument("--trace-cap", type=int, default=DEFAULT_CAP,
                    help="ring-buffer cap on timeline records (0 = "
                         "unbounded); the run FAILS if records are "
                         "dropped, so raise this rather than letting a "
                         "long smoke wrap")
    ap.add_argument("--jax-profile", default="",
                    help="capture a jax.profiler trace into this "
                         "directory, gated around --profile-ticks decode "
                         "ticks")
    ap.add_argument("--profile-ticks", type=int, default=8,
                    help="decode ticks the --jax-profile trace spans")
    ap.add_argument("--quality-probe", type=int, default=0,
                    help="every N decode ticks, replay each live row's "
                         "context through an fp-reference forward and "
                         "record quantized-vs-fp logit divergence (KL, "
                         "top-1 agreement, max-abs-diff) into the "
                         "registry; greedy streams are bit-identical "
                         "probe-on vs probe-off (0 = off)")
    ap.add_argument("--quant-report", default="",
                    help="write the ranked per-layer quantization-quality "
                         "report (SQNR base vs split, clipping, outlier "
                         "mass — worst layer first) to this JSON path and "
                         "file its gauges into the metrics registry")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax (default); > 0 samples")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k most likely tokens (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def build_engine(args):
    """Build the serving engine a parsed CLI namespace describes:
    ``(cfg, model, params, draft_params, w_bytes, mesh)``. Shared by this
    CLI and the service front-end (``repro.serve.app``), so both launch
    the exact same quantized execution path."""
    from repro.configs import get_config
    from repro.core import QuantPolicy, restructure
    from repro.engine import decode_weight_bytes, weight_bytes
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    w_bytes = decode_weight_bytes(params, tie_embeddings=cfg.tie_embeddings)
    # fp reference hooks are captured BEFORE quantization rebinds params:
    # the probe needs the unquantized tree, and the quant report measures
    # the fp weights the quantizer is about to compress
    probe_params = params if getattr(args, "quality_probe", 0) else None
    if getattr(args, "quant_report", ""):
        from repro.core import build_quant_report
        from repro.obs.metrics import global_registry
        t0 = time.time()
        rep = build_quant_report(params, QuantPolicy(
            bits=args.bits or 4, split=args.split,
            packed=args.engine == "packed",
        ))
        rep.record(global_registry())
        rep.save(args.quant_report)
        s = rep.summary()
        print(f"[serve] quant report -> {args.quant_report} "
              f"({s['layers']} layers, mean SQNR gain "
              f"{s['mean_sqnr_gain_db']:+.2f} dB, worst layer "
              f"{s['worst_layer']} at {s['worst_layer_sqnr_split_db']:.2f} "
              f"dB, {time.time() - t0:.1f}s)")
    draft_params = None
    if args.speculate:
        # the drafter quantizes the SAME weights the target serves —
        # self-speculation is the paper's accuracy claim cashed in as
        # serving latency (built before the target tree replaces params)
        t0 = time.time()
        qd = restructure(params, QuantPolicy(
            bits=args.draft_bits, split=args.split,
            packed=args.draft_engine == "packed",
        ))
        if args.draft_engine == "fake":
            draft_params = qd.materialize()
        else:
            draft_params = qd.as_executable(group=not args.no_group)
        print(f"[serve] draft model: SplitQuantV2 INT{args.draft_bits} "
              f"({args.draft_engine} engine), {time.time()-t0:.1f}s, "
              f"{weight_bytes(draft_params)/1e6:.2f} MB weights")
    if args.bits:
        t0 = time.time()
        qm = restructure(params, QuantPolicy(
            bits=args.bits, split=args.split,
            packed=args.engine == "packed",
        ))
        if args.engine == "fake":
            params = qm.materialize()
        else:
            params = qm.as_executable(group=not args.no_group)
        w_bytes = decode_weight_bytes(params,
                                      tie_embeddings=cfg.tie_embeddings)
        print(f"[serve] SplitQuantV2 INT{args.bits} preprocessing "
              f"({args.engine} engine): {time.time()-t0:.1f}s, "
              f"{weight_bytes(params)/1e6:.2f} MB weights, "
              f"{w_bytes/1e6:.2f} MB read per decoded token")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh
        try:
            d, m = (int(x) for x in args.mesh.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--mesh must be DxM (e.g. 2x2), got "
                             f"{args.mesh!r}")
        if d * m > jax.device_count():
            raise SystemExit(
                f"--mesh {d}x{m} needs {d * m} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N for CPU runs)")
        mesh = make_mesh((d, m), ("data", "model"))
        print(f"[serve] mesh: {d} data replica(s) x {m} model shard(s) "
              f"over {d * m} {jax.devices()[0].platform} device(s)")
    return cfg, model, params, draft_params, w_bytes, mesh, probe_params


def main(argv=None):
    args = build_parser().parse_args(argv)
    (cfg, model, params, draft_params, w_bytes, mesh,
     probe_params) = build_engine(args)

    if args.prompt_lens:
        plens = [int(x) for x in args.prompt_lens.split(",")]
    else:
        plens = [args.prompt_len]

    def make_reqs():
        # deterministic workload: the --inject self-check rebuilds the
        # identical request list for its clean reference run
        rng = np.random.default_rng(args.seed)
        common = rng.integers(0, cfg.vocab_size, args.shared_prefix,
                              dtype=np.int32)
        return [
            Request(i, np.concatenate([
                common,
                rng.integers(0, cfg.vocab_size, plens[i % len(plens)],
                             dtype=np.int32),
            ]), args.gen)
            for i in range(args.requests)
        ]

    max_len = args.shared_prefix + max(plens) + args.gen + 8
    slo_on = args.slo_ttft_ms > 0 or args.slo_tpot_ms > 0

    def make_slo():
        if not slo_on:
            return None
        from repro.serve import SLOController
        return SLOController(
            ttft_ms=args.slo_ttft_ms, tpot_ms=args.slo_tpot_ms,
            chunk=args.prefill_chunk or max_len,
            chunk_min=args.slo_chunk_min, chunk_max=max_len,
            spec_floor=args.spec_floor,
        )

    def make_spill():
        if not args.spill_dir:
            return None
        from repro.serve import SpillStore
        return SpillStore(args.spill_dir)

    def make_server(*, inject=None, guard=None, max_wall_s=0.0, obs=None,
                    profile=None):
        return BatchedServer(
            model, params, args.batch, max_len,
            paged=args.paged, page_size=args.page_size,
            num_pages=args.num_pages or None,
            prefix_cache=args.prefix_cache,
            prefix_state_budget=args.prefix_state_budget,
            prefill_chunk=args.prefill_chunk,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, seed=args.seed,
            speculate=args.speculate, draft_params=draft_params,
            page_growth=args.page_growth,
            growth_headroom=args.growth_headroom,
            preemption=args.preemption, spec_floor=args.spec_floor,
            spec_window=args.spec_window, inject=inject, guard=guard,
            max_wall_s=max_wall_s,
            spill_store=make_spill(), spill_threshold=args.spill_threshold,
            slo=make_slo(), mesh=mesh, obs=obs,
            trace_cap=args.trace_cap, profile=profile,
            quality_probe=args.quality_probe, probe_params=probe_params,
        )

    greedy = args.temperature <= 0.0
    ref_out = None
    if args.inject and greedy:
        # clean reference first: the injected run must reproduce these
        # streams bit-exactly despite forced preemptions/faults. It runs
        # with telemetry DISABLED, so the stream comparison below also
        # certifies that the enabled registry never perturbs serving.
        ref_reqs = make_reqs()
        make_server(obs=Observability.disabled()).run(ref_reqs)
        ref_out = {r.rid: list(r.out) for r in ref_reqs}

    if args.obs:
        obs = Observability(
            trace_cap=args.trace_cap,
            const_labels={"family": cfg.family,
                          "engine": args.engine if args.bits else "fp"},
        )
    else:
        obs = Observability.disabled(trace_cap=args.trace_cap)
    profile = (JaxProfile(args.jax_profile, ticks=args.profile_ticks)
               if args.jax_profile else None)

    guard = PreemptionGuard().install()
    try:
        reqs = make_reqs()
        server = make_server(inject=args.inject or None, guard=guard,
                             max_wall_s=args.max_wall_s, obs=obs,
                             profile=profile)
        stats = server.run(reqs)
    finally:
        guard.uninstall()
    # decode reads every weight once per step: bytes/token on one chip
    stats["weight_bytes_per_token"] = w_bytes
    stats["engine"] = args.engine if args.bits else "fp"
    print(f"[serve] {stats}")
    # telemetry artifacts are written BEFORE the FAIL gates so a failing
    # smoke still leaves its metrics/trace behind for diagnosis
    if args.metrics_out:
        obs.dump_metrics(args.metrics_out)
        print(f"[serve] metrics -> {args.metrics_out}")
    if args.trace_out:
        n = obs.dump_trace(args.trace_out)
        print(f"[serve] timeline -> {args.trace_out} ({n} records)")
    req_sum = server.tracer.summary()
    if req_sum.get("ttft_s"):
        print(f"[serve] ttft p50={req_sum['ttft_s']['p50'] * 1e3:.1f}ms "
              f"p95={req_sum['ttft_s']['p95'] * 1e3:.1f}ms | "
              f"tpot p50={req_sum.get('tpot_s', {}).get('p50', 0) * 1e3:.1f}"
              f"ms | queue p50="
              f"{req_sum.get('queue_wait_s', {}).get('p50', 0) * 1e3:.1f}ms")
    if args.quality_probe:
        pr = stats["probe"]
        print(f"[serve] quality probe: {pr['samples']} positions probed "
              f"(every {pr['every']} ticks), top-1 agreement "
              f"{pr['top1_agreement_rate']:.3f}")
        if stats["decode_steps"] >= args.quality_probe and not pr["samples"]:
            print("[serve] FAIL: probe enabled but zero positions probed")
            return 1
    if server.timeline.dropped:
        print(f"[serve] FAIL: {server.timeline.dropped} timeline records "
              f"dropped (ring cap {server.timeline.cap}; raise "
              f"--trace-cap)")
        return 1
    if mesh is not None and args.paged:
        per = stats["pages"].get("per_replica", [stats["pages"]])
        for r, ps in enumerate(per):
            kv = stats["mesh"]["kv_reserved_bytes_per_replica"][r]
            print(f"[serve] replica {r}: pages in_use={ps['in_use']} "
                  f"peak={ps['peak_in_use']} cow_copies={ps['cow_copies']} "
                  f"peak_kv_reserved={kv / 1e6:.3f} MB")
    drained = stats["resilience"]["drained"]
    if drained:
        res = stats["resilience"]
        print(f"[serve] drained cleanly: {stats['requests']} retired "
              f"({res['preempted_requests']} partial), "
              f"{res['unserved']} unserved")
    if not drained and stats["requests"] != len(reqs):
        print(f"[serve] FAIL: served {stats['requests']}/{len(reqs)}")
        return 1
    if stats["decode_compiles"] > 1:
        print(f"[serve] FAIL: decode compiled "
              f"{stats['decode_compiles']}x (must be at most once)")
        return 1
    if args.paged and stats["pages"]["leaked"]:
        print(f"[serve] FAIL: {stats['pages']['leaked']} KV pages leaked")
        return 1
    if args.spill_dir:
        store = stats["resilience"]["spill_store"]
        print(f"[serve] spill tier: {store['spills']} spills, "
              f"{store['restores']} restores, "
              f"{stats['resilience']['recompute_forwards']} recompute "
              f"forwards, {store['bytes_written'] / 1e6:.2f} MB written")
        if store["orphans"]:
            print(f"[serve] FAIL: {store['orphans']} orphaned spill "
                  f"file(s) left in {args.spill_dir}")
            return 1
    if slo_on:
        slo = stats["slo"]
        print(f"[serve] slo: {slo['adjustments']} adjustment(s), final "
              f"chunk={slo['chunk']} floor={slo['spec_floor']:.2f} over "
              f"{slo['ticks']} tick(s)")
    if ref_out is not None and not drained:
        got = {r.rid: list(r.out) for r in reqs}
        if got != ref_out:
            bad = sorted(rid for rid in ref_out
                         if got.get(rid) != ref_out[rid])
            print(f"[serve] FAIL: injected-run streams diverge from the "
                  f"clean run for rids {bad}")
            return 1
        if "oop" in args.inject and not stats["resilience"]["preemptions"]:
            print("[serve] FAIL: oop injection fired no preemption "
                  "(tick beyond the run, or nothing preemptible)")
            return 1
        print(f"[serve] chaos OK: streams bit-identical across "
              f"{stats['resilience']['preemptions']} preemption(s) / "
              f"{stats['resilience']['replays']} replay(s)")
    if args.prefix_cache:
        if (args.shared_prefix and args.requests > 1
                and stats["prefix"]["hits"] == 0):
            print("[serve] FAIL: no prefix-cache hits on a shared-prefix "
                  "workload")
            return 1
        server.drop_prefix_cache()
        if server.alloc.in_use:
            print(f"[serve] FAIL: {server.alloc.in_use} pages still in use "
                  "after prefix-cache drop")
            return 1
    if args.speculate:
        sp = stats["spec"]
        if sp["drafted"] and sp["acceptance_rate"] <= 0:
            print("[serve] FAIL: speculation accepted zero draft tokens")
            return 1
        if sp["draft_pages_leaked"]:
            print(f"[serve] FAIL: {sp['draft_pages_leaked']} DRAFT KV "
                  "pages leaked")
            return 1
        if sp["verify_compiles"] > 1:
            print(f"[serve] FAIL: verify compiled {sp['verify_compiles']}x "
                  "(fixed k+1 chunk must compile at most once)")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
