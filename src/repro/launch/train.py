"""Production trainer CLI.

    PYTHONPATH=src python -m repro.launch.train --arch llama32-1b \
        --steps 200 --batch 8 --seq 256 --mesh 1x1 --ckpt-dir /tmp/ckpt

Wires together: config registry, data pipeline (deterministic resume),
sharded train step (DP/TP/SP/ZeRO-1), async checkpointing, preemption
handling, straggler heartbeats, and retry-on-transient-failure. The same
loop drives the CPU examples and a real multi-host launch (host topology
from env: REPRO_HOST_ID / REPRO_N_HOSTS).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    if len(dims) == 2:
        return dims, ("data", "model")
    if len(dims) == 3:
        return dims, ("pod", "data", "model")
    raise ValueError(f"mesh must be DxM or PxDxM, got {s}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--heartbeat-dir", default=None)
    args = ap.parse_args(argv)

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import DataLoader, Prefetcher, SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.optim import adamw
    from repro.runtime import steps as steps_mod
    from repro.runtime.fault import Heartbeat, PreemptionGuard, run_with_retries

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    dims, axes = parse_mesh(args.mesh)
    mesh = make_mesh(dims, axes)

    opt_cfg = adamw.AdamWConfig(
        peak_lr=args.lr, warmup=args.warmup, total_steps=args.steps
    )
    step_fn, (p_shd, o_shd, b_shd), _ = steps_mod.build_train_step(
        model, mesh, opt_cfg, shape
    )

    host_id = int(os.environ.get("REPRO_HOST_ID", "0"))
    n_hosts = int(os.environ.get("REPRO_N_HOSTS", "1"))
    loader = DataLoader(
        SyntheticLM(cfg.vocab_size, seed=args.seed), args.batch, args.seq,
        seed=args.seed, host_id=host_id, n_hosts=n_hosts,
    )

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    with mesh:
        params = jax.jit(model.init, out_shardings=p_shd)(
            jax.random.PRNGKey(args.seed)
        )
        opt = jax.jit(adamw.init_opt_state, out_shardings=o_shd)(params)
        if ckpt and args.resume and ckpt.latest_step() is not None:
            start_step, state = ckpt.restore(
                None, {"params": params, "opt": opt},
                {"params": p_shd, "opt": o_shd},
            )
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

        guard = PreemptionGuard().install()
        hb = Heartbeat(args.heartbeat_dir, host_id) if args.heartbeat_dir else None
        it = iter(Prefetcher(iter(
            loader.batch_at(s) for s in range(start_step, args.steps)
        )))
        t_last = time.time()
        for step in range(start_step, args.steps):
            batch = next(it)
            batch = {k: jax.device_put(v, b_shd[k]) for k, v in batch.items()}

            def do_step():
                nonlocal params, opt
                params, opt, metrics = step_fn(params, opt, batch)
                return metrics

            metrics = run_with_retries(
                do_step,
                on_failure=lambda a, e: print(f"[train] retry {a}: {e!r}"),
            )
            dt = time.time() - t_last
            t_last = time.time()
            if hb:
                hb.beat(step, dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"[train] step {step} loss={float(metrics['loss']):.4f} "
                    f"lr={float(metrics['lr']):.2e} "
                    f"gnorm={float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms",
                    flush=True,
                )
            if ckpt and ((step + 1) % args.ckpt_every == 0 or guard.requested):
                ckpt.save(step + 1, {"params": params, "opt": opt})
                if guard.requested:
                    print("[train] preemption requested: checkpointed, exiting")
                    ckpt.wait()
                    return 0
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt},
                      blocking=True)
    print("[train] done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
