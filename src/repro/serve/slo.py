"""SLO feedback loop: tune the chunked-prefill budget (and the spec
degradation floor) against TTFT/TPOT targets.

The scheduler calls :meth:`SLOController.observe` with the tracer's
per-token latency observations (``ttft`` = queue entry -> first emitted
token, ``tpot`` = gap between consecutive emitted tokens) and
:meth:`SLOController.tick` once per decode tick. The tick compares the
trailing medians against the ``--slo-ttft-ms/--slo-tpot-ms`` targets and
adjusts two knobs the engine already honors live:

* ``prefill_chunk`` — a TPOT violation means decoding slots are starved
  behind long prefill waves, so the chunk SHRINKS (more decode ticks
  interleave between prompt chunks); a TTFT violation with healthy TPOT
  means prompts sit in prefill too long, so the chunk GROWS. Greedy
  streams are invariant to the chunk size (pinned by the chunked-prefill
  tests), so retuning mid-run never changes tokens — only their timing.
* ``spec_floor`` — under a TPOT violation the speculative acceptance
  floor RISES, so low-acceptance drafting (whose misses inflate
  inter-token gaps with wasted verify work) degrades to plain decode
  sooner.

All decisions are pure functions of (knob, observed/target ratios):
:func:`tune_chunk` and :func:`tune_spec_floor` never read a clock and the
controller never timestamps anything itself — observations arrive as
(kind, seconds) pairs from the caller — so every decision is
unit-testable without wall time.
"""
from __future__ import annotations

from collections import deque

# one tick's multiplicative step is clamped so a burst of outliers cannot
# swing the budget more than 4x in either direction
_MAX_STEP = 4.0


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    if not n:
        return 0.0
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def tune_chunk(chunk: int, ttft_ratio: float, tpot_ratio: float,
               lo: int, hi: int) -> int:
    """One pure control step for the chunked-prefill budget.

    ``*_ratio`` is observed/target (> 1 means the SLO is violated; pass
    0 for "no target" or "no data"). TPOT dominates: shrinking to protect
    inter-token gaps wins over growing to protect TTFT, because a starved
    decoder hurts every active stream while a slow first token hurts one.
    The result is clamped to ``[lo, hi]`` and, at a fixed TPOT ratio, is
    weakly monotone non-decreasing in ``ttft_ratio``.
    """
    if hi < lo:
        raise ValueError(f"invalid chunk range [{lo}, {hi}]")
    chunk = min(max(chunk, lo), hi)
    if tpot_ratio > 1.0:
        chunk = int(chunk / min(tpot_ratio, _MAX_STEP))
    elif ttft_ratio > 1.0:
        chunk = int(round(chunk * min(ttft_ratio, _MAX_STEP)))
    return min(max(chunk, lo), hi)


def tune_spec_floor(floor: float, tpot_ratio: float,
                    cap: float = 0.95) -> float:
    """One pure control step for the speculative acceptance floor.

    A TPOT violation raises the floor (capped) so marginal drafting
    degrades to plain decode; once TPOT recovers the floor decays back
    toward its configured base in the controller. ``floor <= 0`` (spec
    degradation disabled) is left untouched.
    """
    if floor <= 0.0:
        return floor
    if tpot_ratio > 1.0:
        return min(floor * min(tpot_ratio, _MAX_STEP), cap)
    return floor


class SLOController:
    """Trailing-window feedback controller for one ``BatchedServer``.

    Pure in the injectable-clock sense: it owns no clock, only a bounded
    window of caller-supplied observations. ``tick()`` returns the
    (chunk, spec_floor) pair the engine should run with next tick and
    records a history entry whenever either knob moved.
    """

    def __init__(self, *, ttft_ms: float = 0.0, tpot_ms: float = 0.0,
                 chunk: int, chunk_min: int = 8, chunk_max: int | None = None,
                 spec_floor: float = 0.0, window: int = 64):
        if chunk <= 0:
            raise ValueError("SLO control needs a finite initial chunk")
        self.ttft_ms = ttft_ms
        self.tpot_ms = tpot_ms
        self.chunk_min = min(chunk_min, chunk)
        self.chunk_max = max(chunk_max if chunk_max is not None else chunk,
                             chunk)
        self.chunk = chunk
        self.base_floor = spec_floor
        self.spec_floor = spec_floor
        self._obs = {"ttft": deque(maxlen=window),
                     "tpot": deque(maxlen=window)}
        self.ticks = 0
        self.history: list[dict] = []

    def observe(self, kind: str, seconds: float) -> None:
        q = self._obs.get(kind)
        if q is not None:
            q.append(seconds)

    def _ratio(self, kind: str, target_ms: float) -> float:
        if target_ms <= 0 or not self._obs[kind]:
            return 0.0
        return _median(self._obs[kind]) * 1e3 / target_ms

    def tick(self) -> tuple[int, float]:
        self.ticks += 1
        ttft_r = self._ratio("ttft", self.ttft_ms)
        tpot_r = self._ratio("tpot", self.tpot_ms)
        chunk = tune_chunk(self.chunk, ttft_r, tpot_r,
                           self.chunk_min, self.chunk_max)
        floor = tune_spec_floor(self.spec_floor, tpot_r)
        if tpot_r and tpot_r <= 1.0 and floor > self.base_floor:
            # TPOT healthy again: relax the degradation floor halfway
            # back toward its configured base each tick
            floor = max(self.base_floor, 0.5 * (floor + self.base_floor))
        if chunk != self.chunk or floor != self.spec_floor:
            self.history.append({"tick": self.ticks,
                                 "ttft_ratio": round(ttft_r, 3),
                                 "tpot_ratio": round(tpot_r, 3),
                                 "chunk": chunk,
                                 "spec_floor": round(floor, 4)})
        self.chunk = chunk
        self.spec_floor = floor
        return chunk, floor
