"""Per-tenant weighted-fair admission: deficit round-robin (DRR).

The service front-end (``repro.serve.app``) accepts requests from many
tenants but the scheduler (``launch/serve.py``) consumes ONE admission
queue. :class:`FairScheduler` sits between them: each tenant gets a FIFO
queue and a *deficit counter*; every drain round credits each backlogged
tenant ``quantum * weight`` tokens of deficit and releases requests from
the head of its queue while their cost (prompt + generation tokens) fits
the accumulated deficit. Classic DRR properties carry over:

* NO STARVATION — a backlogged tenant's deficit grows every round, so its
  head-of-line request is released within ``ceil(cost / (quantum *
  weight))`` rounds no matter what the other tenants submit;
* WEIGHTED SHARES — over a persistent backlog, the work released for a
  tenant after ``R`` rounds is ``R * quantum * weight`` minus its final
  deficit, which is bounded by its largest request cost: shares track
  weights to within one request;
* DETERMINISM — rounds visit tenants in first-submission order and queues
  are FIFO, so the release order is a pure function of the submission
  sequence (no clock, no randomness).

Decisions never read a clock. The injectable ``clock`` exists only for
*stamping* (``queued_t`` on submitted requests, per-tenant wait stats),
so unit tests drive it with a fake counter and the service uses the same
monotonic clock the tracer timestamps with.

The scheduler is thread-safe: the asyncio front-end submits from the
event-loop thread while the scheduler thread drains.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable

from repro.obs.trace import now as _monotonic


def default_cost(req) -> float:
    """Work a request asks of the engine: prompt tokens to prefill plus
    tokens to generate. Anything with ``prompt``/``max_new`` works."""
    return float(len(req.prompt) + req.max_new)


class _Tenant:
    __slots__ = ("name", "weight", "deficit", "queue", "submitted",
                 "released", "released_cost", "wait_s")

    def __init__(self, name: str, weight: float):
        self.name = name
        self.weight = weight
        self.deficit = 0.0
        self.queue: deque = deque()  # (item, submit_t)
        self.submitted = 0
        self.released = 0
        self.released_cost = 0.0
        self.wait_s: list[float] = []


class FairScheduler:
    """Deficit round-robin over per-tenant queues -> one admission queue."""

    def __init__(self, quantum: float = 64.0,
                 cost: Callable | None = None,
                 clock: Callable[[], float] | None = None):
        if quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self.quantum = quantum
        self._cost = cost or default_cost
        self._clock = clock or _monotonic
        self._tenants: dict[str, _Tenant] = {}
        self._ring: list[str] = []  # first-submission order: determinism
        self._lock = threading.Lock()

    def submit(self, tenant: str, item, weight: float = 1.0) -> None:
        """Queue ``item`` under ``tenant``. ``weight`` (re)binds the
        tenant's share; the submit time is stamped onto ``item.queued_t``
        (when the attribute exists) so downstream TTFT measurements start
        at submission, not at admission-queue entry."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        t = self._clock()
        with self._lock:
            q = self._tenants.get(tenant)
            if q is None:
                q = self._tenants[tenant] = _Tenant(tenant, weight)
                self._ring.append(tenant)
            q.weight = weight
            q.submitted += 1
            if hasattr(item, "queued_t") and getattr(item, "queued_t") is None:
                item.queued_t = t
            q.queue.append((item, t))

    def drain(self, rounds: int = 1) -> list:
        """Run up to ``rounds`` DRR rounds and return the released items
        in admission order. Stops early once every queue is empty."""
        out: list = []
        t = self._clock()
        with self._lock:
            for _ in range(max(rounds, 1)):
                if not any(q.queue for q in self._tenants.values()):
                    break
                for name in self._ring:
                    q = self._tenants[name]
                    if not q.queue:
                        continue
                    q.deficit += self.quantum * q.weight
                    while q.queue:
                        item, t_sub = q.queue[0]
                        c = self._cost(item)
                        if c > q.deficit:
                            break
                        q.queue.popleft()
                        q.deficit -= c
                        q.released += 1
                        q.released_cost += c
                        q.wait_s.append(t - t_sub)
                        out.append(item)
                    if not q.queue:
                        # idle tenants do not hoard deficit (standard DRR):
                        # credit only accrues against a live backlog
                        q.deficit = 0.0
        return out

    @property
    def backlog(self) -> int:
        with self._lock:
            return sum(len(q.queue) for q in self._tenants.values())

    def stats(self) -> dict:
        with self._lock:
            return {
                "tenants": {
                    name: {
                        "weight": q.weight,
                        "submitted": q.submitted,
                        "released": q.released,
                        "released_cost": q.released_cost,
                        "backlog": len(q.queue),
                        "mean_wait_s": (sum(q.wait_s) / len(q.wait_s)
                                        if q.wait_s else 0.0),
                    }
                    for name, q in self._tenants.items()
                },
                "backlog": sum(len(q.queue) for q in self._tenants.values()),
            }
