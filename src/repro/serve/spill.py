"""Preempt-to-disk tier: spill a preempted context's KV pages to a
host-side store and restore them by page reload instead of replaying the
whole sequence through prefill.

The engine-side policy lives in ``launch/serve.py`` (only fully-prefilled
decoding victims at or above ``--spill-threshold`` rows spill; everything
else takes the PR 6 recompute-replay path). This module is just the
store: one ``.npz`` file per request id holding the request's page
contents for every paged pool leaf plus the per-slot recurrent state
rows, written with numpy on the host — device arrays never touch disk
directly.

Lifecycle: ``spill(rid, payload)`` at preemption, ``restore(rid)`` at
re-admission (the engine then drops the file), ``drop(rid)`` on
retirement/drain for anything still spilled. ``files()`` lists what is
left on disk, so "zero orphaned spill files" is a checkable invariant at
the end of every run.
"""
from __future__ import annotations

import os
import pathlib

import numpy as np


class SpillStore:
    """Host-side page store, one compressed-free ``.npz`` per request."""

    def __init__(self, root: str | os.PathLike):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.spills = 0
        self.restores = 0
        self.drops = 0
        self.bytes_written = 0

    def path(self, rid: int) -> pathlib.Path:
        return self.root / f"req_{int(rid)}.npz"

    def spill(self, rid: int, payload: dict) -> None:
        """Persist ``payload`` (str -> ndarray) for ``rid``. Overwrites a
        stale entry for the same rid (a re-preempted restore)."""
        p = self.path(rid)
        np.savez(p, **{k: np.asarray(v) for k, v in payload.items()})
        self.spills += 1
        self.bytes_written += p.stat().st_size

    def restore(self, rid: int) -> dict:
        with np.load(self.path(rid)) as z:
            out = {k: z[k] for k in z.files}
        self.restores += 1
        return out

    def drop(self, rid: int) -> bool:
        p = self.path(rid)
        if p.exists():
            p.unlink()
            self.drops += 1
            return True
        return False

    def has(self, rid: int) -> bool:
        return self.path(rid).exists()

    def files(self) -> list[str]:
        return sorted(str(p) for p in self.root.glob("req_*.npz"))

    def stats(self) -> dict:
        return {
            "spills": self.spills,
            "restores": self.restores,
            "drops": self.drops,
            "bytes_written": self.bytes_written,
            "orphans": len(self.files()),
        }
