"""Service layer over the batched engine: asyncio HTTP/SSE front-end,
per-tenant weighted-fair admission, the SLO feedback controller, and the
preempt-to-disk spill store.

``FairScheduler``/``SLOController``/``SpillStore`` are pure host-side
modules importable without jax; ``ServeApp`` (the asyncio front-end)
pulls in the engine and is exported lazily.
"""
from repro.serve.slo import SLOController, tune_chunk, tune_spec_floor
from repro.serve.spill import SpillStore
from repro.serve.tenants import FairScheduler, default_cost

__all__ = [
    "FairScheduler",
    "SLOController",
    "ServeApp",
    "SpillStore",
    "default_cost",
    "tune_chunk",
    "tune_spec_floor",
]


def __getattr__(name):
    if name == "ServeApp":  # lazy: importing the app pulls in jax
        from repro.serve.app import ServeApp
        return ServeApp
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
