"""Asyncio HTTP/SSE service front-end over ``BatchedServer``.

The engine keeps its synchronous scheduler loop — proven bit-exact under
chaos/mesh/spec — and runs it unchanged in a worker thread via the
``run(feed=...)`` service hook. This module is the thin asynchronous
shell around it:

* ``POST /v1/generate`` — JSON body ``{"prompt": [token ids],
  "max_new": N, "tenant": "...", "weight": W, "priority": P}``; the
  response streams Server-Sent Events, one ``data: {"rid", "index",
  "token"}`` frame per decoded token (fired from the engine's existing
  ``on_token`` callback) and a final ``data: {"done": true, "status",
  "tokens"}`` frame. Greedy streams are BIT-IDENTICAL to a library
  ``BatchedServer.run`` on the same workload: the service changes how
  tokens travel, never which tokens exist.
* ``GET /metrics`` — the live ``Registry.to_prometheus()`` snapshot.
* ``GET /healthz`` — liveness + drain state.
* ``POST /drain`` — trips the PR 6 ``PreemptionGuard`` flag, the same
  path SIGTERM takes: in-flight requests retire with partial streams and
  zero leaks, queued requests return unserved, open SSE streams get a
  terminal ``status: "preempted"`` frame.

Admission is per-tenant weighted-fair: submissions land in
``FairScheduler`` queues and the scheduler thread drains one deficit
round-robin round per scheduler iteration. Tokens cross threads via
``loop.call_soon_threadsafe`` into per-request asyncio queues — the
engine never blocks on a slow client.

The HTTP layer is hand-rolled over ``asyncio.start_server`` (one request
per connection, ``Connection: close``) so the service carries zero
dependencies beyond the standard library.
"""
from __future__ import annotations

import argparse
import asyncio
import itertools
import json
import signal
import threading

import numpy as np

from repro.runtime.fault import PreemptionGuard
from repro.serve.tenants import FairScheduler

_JSON = {"Content-Type": "application/json"}
_SSE_HEAD = (b"HTTP/1.1 200 OK\r\n"
             b"Content-Type: text/event-stream\r\n"
             b"Cache-Control: no-cache\r\n"
             b"Connection: close\r\n\r\n")


def _response(code: int, body: bytes, headers: dict | None = None) -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              503: "Service Unavailable"}.get(code, "OK")
    head = [f"HTTP/1.1 {code} {reason}"]
    for k, v in {"Content-Length": len(body), "Connection": "close",
                 **(headers or {})}.items():
        head.append(f"{k}: {v}")
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


class ServeApp:
    """One engine, one listener: the serving *process*.

    ``start()`` binds the socket (``port=0`` -> ephemeral, read back from
    ``self.port``) and launches the engine's service loop in a worker
    thread; ``stop()`` drains it through the guard and joins. The engine's
    end-of-run stats dict lands in ``self.stats``.
    """

    def __init__(self, server, *, fair: FairScheduler | None = None,
                 host: str = "127.0.0.1", port: int = 0,
                 max_new_cap: int = 4096):
        self.server = server
        self.fair = fair if fair is not None else FairScheduler()
        if server.guard is None:
            # the guard doubles as the drain flag even when no signal
            # handler is installed (POST /drain just sets .requested)
            server.guard = PreemptionGuard()
        self.guard = server.guard
        self.host = host
        self.port = port
        self.max_new_cap = max_new_cap
        self.stats: dict | None = None
        self.error: BaseException | None = None
        self._streams: dict[int, asyncio.Queue] = {}
        self._ended: set[int] = set()
        self._auto_rid = itertools.count(1 << 20)  # clear of client rids
        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._srv: asyncio.base_events.Server | None = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "ServeApp":
        self._loop = asyncio.get_running_loop()
        self._srv = await asyncio.start_server(self._handle, self.host,
                                               self.port)
        self.port = self._srv.sockets[0].getsockname()[1]
        self._thread = threading.Thread(target=self._engine_loop,
                                        name="engine-loop", daemon=True)
        self._thread.start()
        return self

    async def stop(self) -> dict | None:
        """Drain the engine (same flag SIGTERM sets), join its thread,
        close the listener. Returns the engine's stats dict."""
        self.guard.requested = True
        if self._thread is not None:
            await asyncio.get_running_loop().run_in_executor(
                None, self._thread.join)
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()
        if self.error is not None:
            raise self.error
        return self.stats

    def _engine_loop(self) -> None:
        try:
            self.stats = self.server.run([], on_token=self._on_token,
                                         feed=self.fair.drain)
        except BaseException as e:  # surface engine crashes to stop()
            self.error = e
        finally:
            if self._loop is not None and not self._loop.is_closed():
                self._loop.call_soon_threadsafe(self._finish_all)

    # -- engine thread -> event loop -----------------------------------------

    def _on_token(self, req, tok: int) -> None:
        # engine thread: hop to the loop; req fields are read HERE so the
        # loop-side closure carries plain values
        self._loop.call_soon_threadsafe(self._push, req.rid, int(tok),
                                        req.done, req.status)

    def _push(self, rid: int, tok: int, done: bool, status: str) -> None:
        q = self._streams.get(rid)
        if q is None:
            return
        q.put_nowait(("tok", tok))
        if done:
            q.put_nowait(("end", status))
            self._ended.add(rid)

    def _finish_all(self) -> None:
        """Engine loop exited (drain or crash): close every stream that
        never saw a terminal frame — drained partials and unserved
        requests end with status 'preempted'."""
        for rid, q in list(self._streams.items()):
            if rid not in self._ended:
                q.put_nowait(("end", "preempted"))
                self._ended.add(rid)

    # -- HTTP ----------------------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            if not line:
                return
            try:
                method, path, _ = line.decode("latin1").split(None, 2)
            except ValueError:
                writer.write(_response(400, b"malformed request line\n"))
                return
            headers = {}
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", "0") or 0)
            if n:
                body = await reader.readexactly(n)
            route = (method.upper(), path.split("?", 1)[0])
            if route == ("POST", "/v1/generate"):
                await self._generate(writer, body)
            elif route == ("GET", "/metrics"):
                text = self.server.registry.to_prometheus()
                writer.write(_response(200, text.encode(), {
                    "Content-Type": "text/plain; version=0.0.4"}))
            elif route == ("GET", "/healthz"):
                payload = {
                    "status": "draining" if self.guard.requested else "ok",
                    "active": sum(1 for r in self.server.active
                                  if r is not None),
                    "backlog": self.fair.backlog,
                }
                writer.write(_response(200,
                                       json.dumps(payload).encode(), _JSON))
            elif route == ("POST", "/drain"):
                self.guard.requested = True
                writer.write(_response(200, b'{"draining": true}', _JSON))
            else:
                writer.write(_response(404, b"not found\n"))
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        from repro.launch.serve import Request  # deferred: heavy import
        try:
            spec = json.loads(body or b"{}")
            prompt = np.asarray(spec["prompt"], np.int32)
            max_new = int(spec.get("max_new", 16))
            if prompt.ndim != 1 or prompt.size == 0:
                raise ValueError("prompt must be a non-empty 1-D token list")
            if not 1 <= max_new <= self.max_new_cap:
                raise ValueError(f"max_new must be in [1, {self.max_new_cap}]")
        except (KeyError, ValueError, TypeError) as e:
            writer.write(_response(400, f"bad request: {e}\n".encode()))
            return
        if self.guard.requested:
            writer.write(_response(503, b"draining\n"))
            return
        rid = int(spec["rid"]) if "rid" in spec else next(self._auto_rid)
        req = Request(rid, prompt, max_new,
                      priority=int(spec.get("priority", 0)))
        q: asyncio.Queue = asyncio.Queue()
        self._streams[rid] = q
        # queue registered BEFORE submit: the engine thread may emit the
        # first token before this coroutine runs again
        self.fair.submit(str(spec.get("tenant", "default")), req,
                         weight=float(spec.get("weight", 1.0)))
        writer.write(_SSE_HEAD)
        await writer.drain()
        emitted = 0
        try:
            while True:
                kind, val = await q.get()
                if kind == "tok":
                    frame = {"rid": rid, "index": emitted, "token": val}
                    emitted += 1
                else:
                    frame = {"rid": rid, "done": True, "status": val,
                             "tokens": emitted}
                writer.write(b"data: " + json.dumps(frame).encode() + b"\n\n")
                await writer.drain()
                if kind == "end":
                    break
        finally:
            self._streams.pop(rid, None)
            self._ended.discard(rid)


# -- client helpers (tests + selfcheck share them) ---------------------------

async def http_request(host: str, port: int, method: str, path: str,
                       body: bytes = b"") -> tuple[int, bytes]:
    """Minimal one-shot HTTP client; returns (status, body)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        status = await reader.readline()
        code = int(status.split()[1])
        n = None
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin1").partition(":")
            if k.strip().lower() == "content-length":
                n = int(v)
        data = (await reader.readexactly(n) if n is not None
                else await reader.read())
        return code, data
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except Exception:
            pass


async def sse_generate(host: str, port: int, payload: dict,
                       on_token=None) -> dict:
    """Submit one generation and consume its SSE stream. Returns
    ``{"code", "tokens", "done"}`` (``done`` is the terminal frame,
    None if the stream was cut)."""
    body = json.dumps(payload).encode()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Type: application/json\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        status = await reader.readline()
        code = int(status.split()[1])
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
        tokens: list[int] = []
        done = None
        if code == 200:
            while True:
                line = await reader.readline()
                if not line:
                    break
                line = line.strip()
                if not line.startswith(b"data: "):
                    continue
                evt = json.loads(line[6:])
                if evt.get("done"):
                    done = evt
                    break
                tokens.append(evt["token"])
                if on_token is not None:
                    on_token(evt)
        return {"code": code, "tokens": tokens, "done": done}
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except Exception:
            pass


# -- CLI ---------------------------------------------------------------------

def _service_parser() -> argparse.ArgumentParser:
    from repro.launch.serve import build_parser
    ap = build_parser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral, printed at startup)")
    ap.add_argument("--quantum", type=float, default=64.0,
                    help="deficit round-robin quantum (cost units = "
                         "prompt + generation tokens per request)")
    ap.add_argument("--selfcheck", action="store_true",
                    help="CI smoke: start the service in-process, run a "
                         "mixed-tenant SSE workload against it, and exit "
                         "nonzero unless every greedy stream is "
                         "bit-identical to the library BatchedServer.run "
                         "reference with zero timeline drops, zero page "
                         "leaks and zero orphaned spill files")
    return ap


def _make_service(args, *, guard=None):
    """(engine, app) for a parsed service CLI namespace."""
    from repro.launch import serve as launch

    (cfg, model, params, draft_params, w_bytes, mesh,
     probe_params) = launch.build_engine(args)
    plens = ([int(x) for x in args.prompt_lens.split(",")]
             if args.prompt_lens else [args.prompt_len])
    max_len = args.shared_prefix + max(plens) + args.gen + 8
    slo = None
    if args.slo_ttft_ms > 0 or args.slo_tpot_ms > 0:
        from repro.serve import SLOController
        slo = SLOController(
            ttft_ms=args.slo_ttft_ms, tpot_ms=args.slo_tpot_ms,
            chunk=args.prefill_chunk or max_len,
            chunk_min=args.slo_chunk_min, chunk_max=max_len,
            spec_floor=args.spec_floor,
        )
    spill = None
    if args.spill_dir:
        from repro.serve import SpillStore
        spill = SpillStore(args.spill_dir)
    obs = (launch.Observability(
        trace_cap=args.trace_cap,
        const_labels={"family": cfg.family,
                      "engine": args.engine if args.bits else "fp"})
        if args.obs else launch.Observability.disabled(
            trace_cap=args.trace_cap))
    server = launch.BatchedServer(
        model, params, args.batch, max_len,
        paged=args.paged, page_size=args.page_size,
        num_pages=args.num_pages or None,
        prefix_cache=args.prefix_cache,
        prefix_state_budget=args.prefix_state_budget,
        prefill_chunk=args.prefill_chunk,
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=args.seed, speculate=args.speculate, draft_params=draft_params,
        page_growth=args.page_growth, growth_headroom=args.growth_headroom,
        preemption=args.preemption, spec_floor=args.spec_floor,
        spec_window=args.spec_window, inject=args.inject or None,
        guard=guard, max_wall_s=args.max_wall_s,
        spill_store=spill, spill_threshold=args.spill_threshold,
        slo=slo, mesh=mesh, obs=obs, trace_cap=args.trace_cap,
        quality_probe=args.quality_probe, probe_params=probe_params,
    )
    app = ServeApp(server, fair=FairScheduler(quantum=args.quantum),
                   host=args.host, port=args.port)
    return server, app


def _selfcheck_workload(args, cfg):
    """The deterministic mixed-tenant workload the selfcheck runs: the
    same request shapes the library CLI generates, spread over two
    tenants with unequal weights."""
    plens = ([int(x) for x in args.prompt_lens.split(",")]
             if args.prompt_lens else [args.prompt_len])
    rng = np.random.default_rng(args.seed)
    common = rng.integers(0, cfg.vocab_size, args.shared_prefix,
                          dtype=np.int32)
    reqs = []
    for i in range(args.requests):
        prompt = np.concatenate([
            common,
            rng.integers(0, cfg.vocab_size, plens[i % len(plens)],
                         dtype=np.int32),
        ])
        tenant, weight = (("heavy", 1.0) if i % 3 else ("light", 3.0))
        reqs.append({"rid": i, "prompt": prompt, "max_new": args.gen,
                     "tenant": tenant, "weight": weight})
    return reqs


async def _run_selfcheck(args) -> int:
    from repro.launch import serve as launch

    # 1) library reference: plain BatchedServer.run — telemetry off, no
    #    faults, no spill tier, no SLO retuning. The service below runs
    #    with every flagged hazard live and must reproduce these streams
    #    bit-exactly anyway.
    ref_args = argparse.Namespace(**vars(args))
    ref_args.inject = ""
    ref_args.spill_dir = ""
    ref_args.slo_ttft_ms = ref_args.slo_tpot_ms = 0.0
    ref_args.obs = False
    ref_server, _ = _make_service(ref_args)
    workload = _selfcheck_workload(args, ref_server.model.cfg)
    ref_reqs = [launch.Request(w["rid"], w["prompt"], w["max_new"])
                for w in workload]
    ref_stats = ref_server.run(ref_reqs)
    ref = {r.rid: list(r.out) for r in ref_reqs}
    print(f"[service] reference: {ref_stats['requests']} requests, "
          f"{ref_stats['tokens']} tokens")

    # 2) the service, with every flagged hazard live (faults, SLO
    #    retuning, spill tier), serving the same workload over HTTP/SSE
    server, app = _make_service(args)
    await app.start()
    print(f"[service] listening on {app.host}:{app.port}")
    results = await asyncio.gather(*[
        sse_generate(app.host, app.port, {
            "rid": w["rid"], "prompt": w["prompt"].tolist(),
            "max_new": w["max_new"], "tenant": w["tenant"],
            "weight": w["weight"],
        }) for w in workload
    ])
    code, health = await http_request(app.host, app.port, "GET", "/healthz")
    assert code == 200, health
    code, metrics = await http_request(app.host, app.port, "GET", "/metrics")
    code, _ = await http_request(app.host, app.port, "POST", "/drain")
    stats = await app.stop()

    failures = []
    got = {w["rid"]: r["tokens"] for w, r in zip(workload, results)}
    if got != ref:
        bad = sorted(rid for rid in ref if got.get(rid) != ref[rid])
        failures.append(f"SSE streams diverge from library run: rids {bad}")
    if any(r["done"] is None or r["done"]["status"] != "ok"
           for r in results):
        failures.append("a stream ended without a clean terminal frame")
    if server.timeline.dropped:
        failures.append(f"{server.timeline.dropped} timeline records "
                        f"dropped")
    if args.paged and stats["pages"]["leaked"]:
        failures.append(f"{stats['pages']['leaked']} KV pages leaked")
    if args.inject and "oop" in args.inject:
        if not stats["resilience"]["preemptions"]:
            failures.append("oop injection fired no preemption")
    if args.spill_dir:
        orphans = stats["resilience"]["spill_store"]["orphans"]
        if orphans:
            failures.append(f"{orphans} orphaned spill file(s)")
    if args.slo_ttft_ms or args.slo_tpot_ms:
        print(f"[service] slo: {stats['slo']['adjustments']} adjustment(s),"
              f" final chunk={stats['slo']['chunk']}")
    if args.obs:
        from repro.obs import parse_prometheus
        snap = parse_prometheus(metrics.decode())
        if "serve_tokens_total" not in snap:
            failures.append("/metrics snapshot missing serve_tokens_total")
    print(f"[service] fair shares: "
          f"{json.dumps(app.fair.stats()['tenants'], default=str)}")
    for f in failures:
        print(f"[service] FAIL: {f}")
    if not failures:
        print(f"[service] selfcheck OK: {len(workload)} streams "
              f"bit-identical through SSE, "
              f"{stats['resilience']['preemptions']} preemption(s), "
              f"{stats['resilience']['spills']} spill(s)")
    return 1 if failures else 0


async def _run_service(args) -> int:
    server, app = _make_service(args, guard=PreemptionGuard())
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGTERM, signal.SIGINT):
        loop.add_signal_handler(sig,
                                lambda: setattr(app.guard, "requested", True))
    await app.start()
    print(f"[service] listening on http://{app.host}:{app.port} "
          f"(POST /v1/generate, GET /metrics, GET /healthz, POST /drain)")
    while not app.guard.requested:
        await asyncio.sleep(0.05)
    stats = await app.stop()
    print(f"[service] drained: {stats['requests']} requests retired")
    return 0


def main(argv=None) -> int:
    args = _service_parser().parse_args(argv)
    if args.selfcheck:
        return asyncio.run(_run_selfcheck(args))
    return asyncio.run(_run_service(args))


if __name__ == "__main__":
    raise SystemExit(main())
