"""Which parameters get split/quantized (paper §3 exclusions + safety adds).

The paper excludes: embedding tables (lookup semantics, not matmul),
normalization parameters (gamma/beta are calibration-critical 1-D vectors),
activations (need calibration data — out of SplitQuantV2's scope). We add:
MoE router matrices (tiny but routing-decisive), biases, and any rank<2
parameter. Matching is by parameter *path* (all model-zoo params have
stable, descriptive paths) plus rank, so the policy transfers to any pytree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

EXCLUDE_SUBSTRINGS: tuple[str, ...] = (
    "embed",       # embedding tables (paper §3)
    "norm",        # all normalization params (paper §3)
    "scale",       # qk-norm / per-channel scales
    "bias",
    "router",      # MoE gate — tiny, accuracy-critical
    "conv",        # depthwise conv1d kernels (mamba2) / stub frontends
    "a_log",       # mamba2 state decay
    "dt_",         # mamba2 Δt projection params (1-D-ish, dynamics-critical)
    "time_",       # rwkv6 time-mix μ / decay vectors
    "pos",         # positional tables
)


@dataclass(frozen=True)
class QuantPolicy:
    """Configuration of the restructuring pass."""

    bits: int = 4
    k: int = 3                      # paper fixes k=3; 2 is the §5 trade-off
    split: bool = True              # False → plain linear-quant baseline
    packed: bool = False            # beyond-paper 6-bit layout
    min_size: int = 4096            # don't bother below this many elements
    exclude: Sequence[str] = field(default_factory=lambda: EXCLUDE_SUBSTRINGS)

    def wants(self, path: str, ndim: int, size: int) -> bool:
        if ndim < 2 or size < self.min_size:
            return False
        p = path.lower()
        return not any(s in p for s in self.exclude)
