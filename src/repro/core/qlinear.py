"""Quantized linear execution paths.

Three ways to run ``y = x @ Ŵ + b`` with SplitQuantV2 weights, all producing
identical values (tested):

* ``splitq_linear_3pass`` — the **paper's deployment**: three real layers,
  one matmul per plane, outputs summed. This is the paper-faithful baseline
  (and its §5 limitation: 3× matmul work).
* ``splitq_linear_fused`` — dequantize-and-add the planes, then a single
  matmul (what our Pallas kernel ``splitq_matmul`` does tile-wise in VMEM).
* ``splitq_linear_packed`` — single matmul from the 6-bit packed layout
  (Pallas kernel ``splitq_packed``), half the paper's weight bandwidth.

``qlinear`` is the non-split baseline (per-tensor quantized linear). The jnp
bodies here double as the oracles for the Pallas kernels in
``repro/kernels``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor, dequantize, unpack_codes
from repro.core.split import PackedSplitQTensor, SplitQTensor


def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


def qlinear(x: jax.Array, qt: QTensor, b: jax.Array | None = None) -> jax.Array:
    """Baseline: per-tensor linear-quantized weight."""
    return linear(x, qt.dequantize(), b)


def splitq_linear_3pass(
    x: jax.Array, sq: SplitQTensor, b: jax.Array | None = None
) -> jax.Array:
    """Paper-faithful: k separate (de)quantized layers, outputs summed."""
    y = jnp.zeros(x.shape[:-1] + (sq.shape[-1],), jnp.float32)
    for c in range(sq.k):
        q = unpack_codes(sq.planes[c], sq.bits, out_len=sq.shape[-1])
        wc = dequantize(q.reshape(sq.shape), sq.plane_qparams(c))
        y = y + jnp.dot(x.astype(jnp.float32), wc)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


def splitq_linear_fused(
    x: jax.Array, sq: SplitQTensor, b: jax.Array | None = None
) -> jax.Array:
    """Fused: sum planes first (one matmul). Value-identical to 3pass up to
    float summation order; bit-identical weight sum because plane supports
    are disjoint and off-support entries are exact zeros."""
    return linear(x, sq.dequantize(), b)


def splitq_linear_packed(
    x: jax.Array, psq: PackedSplitQTensor, b: jax.Array | None = None
) -> jax.Array:
    """Single matmul from the 6-bit packed layout."""
    return linear(x, psq.dequantize(), b)
