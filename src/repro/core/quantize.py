"""Basic linear (affine) quantization — the paper's eqs. (1)-(3).

    Q(x) = INT(S·x) + Z,   S = (2^b − 1)/(α − β),   Z = −2^{b−1} − INT(S·β)
    dequant(q) = (q − Z) / S

This is deliberately the *de-facto-standard* scheme the paper targets: the
whole point of SplitQuantV2 is that after its preprocessing, this basic
scheme matches advanced GPU-hungry algorithms. We implement:

* per-tensor / per-channel / per-group granularity (per-tensor is what edge
  frameworks give you and what the paper evaluates; the others exist for the
  ablation "is SplitQuantV2 ≈ group quant without framework support?"),
* symmetric ranges optionally (``symmetric=True``) for kernels that want
  zero-point-free matmuls,
* ``include_zero`` range extension — required by the split transform so that
  masked-out weights encode exactly to the zero-point (see core/split.py),
* int4/int2 bit-packing into int8 carriers for real deployment storage
  (kernels unpack in VMEM).

All ops are pure jnp and jit-safe; scalars stay in fp32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

INT_DTYPE = jnp.int8  # carrier for all b <= 8


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["scale", "zero"],
    meta_fields=["bits"],
)
@dataclasses.dataclass(frozen=True)
class QParams:
    """Affine quantization parameters. scale/zero broadcast against q.

    ``bits`` is static pytree metadata (it controls code paths, so it must
    never become a tracer)."""

    scale: jax.Array  # S, fp32
    zero: jax.Array  # Z, fp32 (integral values; kept float for arithmetic)
    bits: int

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def _minmax(x: jax.Array, axes, keepdims: bool) -> tuple[jax.Array, jax.Array]:
    return (
        jnp.min(x, axis=axes, keepdims=keepdims),
        jnp.max(x, axis=axes, keepdims=keepdims),
    )


def compute_qparams(
    x: jax.Array,
    bits: int,
    *,
    channel_axis: int | None = None,
    group_size: int | None = None,
    symmetric: bool = False,
    include_zero: bool = False,
    beta: jax.Array | None = None,
    alpha: jax.Array | None = None,
) -> QParams:
    """Derive (S, Z) from data range (or an explicit [beta, alpha] range).

    channel_axis: per-channel granularity — one (S, Z) per index of that axis.
    group_size:   per-group along the *last* axis (reshape-based).
    include_zero: extend the range hull to contain 0.0.
    """
    xf = x.astype(jnp.float32)
    if beta is None or alpha is None:
        if group_size is not None:
            assert channel_axis is None, "group and channel are exclusive"
            g = xf.reshape(xf.shape[:-1] + (xf.shape[-1] // group_size, group_size))
            beta, alpha = _minmax(g, -1, True)
            beta = jnp.repeat(beta, group_size, axis=-1).reshape(xf.shape)
            alpha = jnp.repeat(alpha, group_size, axis=-1).reshape(xf.shape)
        elif channel_axis is not None:
            axes = tuple(i for i in range(xf.ndim) if i != channel_axis % xf.ndim)
            beta, alpha = _minmax(xf, axes, True)
        else:
            beta, alpha = _minmax(xf, None, False)
    beta = jnp.asarray(beta, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)
    if symmetric:
        m = jnp.maximum(jnp.abs(beta), jnp.abs(alpha))
        beta, alpha = -m, m
    if include_zero:
        beta = jnp.minimum(beta, 0.0)
        alpha = jnp.maximum(alpha, 0.0)
    span = jnp.maximum(alpha - beta, 1e-12)
    scale = (2.0**bits - 1.0) / span  # eq. (2)
    zero = -(2.0 ** (bits - 1)) - jnp.round(scale * beta)  # eq. (3)
    return QParams(scale=scale, zero=zero, bits=bits)


def quantize(x: jax.Array, qp: QParams) -> jax.Array:
    """eq. (1) with saturation to the signed b-bit range. Returns int8 codes."""
    q = jnp.round(qp.scale * x.astype(jnp.float32)) + qp.zero
    return jnp.clip(q, qp.qmin, qp.qmax).astype(INT_DTYPE)


def dequantize(q: jax.Array, qp: QParams) -> jax.Array:
    return (q.astype(jnp.float32) - qp.zero) / qp.scale


def fake_quant(
    x: jax.Array,
    bits: int,
    **kw,
) -> tuple[jax.Array, QParams]:
    """quantize → dequantize round-trip (what accuracy eval measures)."""
    qp = compute_qparams(x, bits, **kw)
    return dequantize(quantize(x, qp), qp), qp


# ---------------------------------------------------------------------------
# Bit packing (int4 / int2 codes into int8 carriers, little-nibble-first).
# ---------------------------------------------------------------------------


def pack_codes(q: jax.Array, bits: int) -> jax.Array:
    """Pack signed b-bit codes (stored in int8) along the last axis.

    bits=8 is the identity. bits=4 packs 2/byte, bits=2 packs 4/byte.
    The last axis must be divisible by (8 // bits).
    """
    if bits == 8:
        return q
    per = 8 // bits
    assert q.shape[-1] % per == 0, (q.shape, bits)
    u = (q.astype(jnp.int32) & ((1 << bits) - 1)).astype(jnp.uint8)
    u = u.reshape(q.shape[:-1] + (q.shape[-1] // per, per))
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits).astype(jnp.uint8)
    packed = jnp.zeros(u.shape[:-1], jnp.uint8)
    for i in range(per):
        packed = packed | (u[..., i] << shifts[i])
    return packed.astype(jnp.int8)


def unpack_codes(p: jax.Array, bits: int, out_len: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_codes`; returns sign-extended int8 codes."""
    if bits == 8:
        return p
    per = 8 // bits
    u = p.astype(jnp.uint8)
    parts = []
    mask = (1 << bits) - 1
    for i in range(per):
        v = (u >> jnp.uint8(i * bits)) & jnp.uint8(mask)
        # sign extend from `bits`
        v = v.astype(jnp.int32)
        v = jnp.where(v >= (1 << (bits - 1)), v - (1 << bits), v)
        parts.append(v.astype(jnp.int8))
    out = jnp.stack(parts, axis=-1).reshape(p.shape[:-1] + (p.shape[-1] * per,))
    if out_len is not None:
        out = out[..., :out_len]
    return out


# ---------------------------------------------------------------------------
# Whole-tensor convenience (used by the baseline quantizer and benchmarks).
# ---------------------------------------------------------------------------


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["packed", "qp"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class QTensor:
    """A quantized tensor: packed codes + params + logical shape (static)."""

    packed: jax.Array
    qp: QParams
    shape: tuple[int, ...]

    def dequantize(self) -> jax.Array:
        q = unpack_codes(self.packed, self.qp.bits, out_len=self.shape[-1])
        return dequantize(q.reshape(self.shape), self.qp)


@functools.partial(jax.jit, static_argnames=("bits", "symmetric", "include_zero"))
def quantize_tensor(
    x: jax.Array, bits: int, symmetric: bool = False, include_zero: bool = False
) -> QTensor:
    """Per-tensor quantize + pack (the paper's deployment storage format)."""
    qp = compute_qparams(x, bits, symmetric=symmetric, include_zero=include_zero)
    q = quantize(x, qp)
    pad = (-x.shape[-1]) % (8 // bits)
    if pad:
        q = jnp.pad(q, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return QTensor(packed=pack_codes(q, bits), qp=qp, shape=x.shape)
