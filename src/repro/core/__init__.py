"""SplitQuantV2 core: k-means clustering, linear quantization, layer
splitting, and the whole-model restructuring pass."""
from repro.core.apply import QuantizedModel, quantize_model, restructure
from repro.core.kmeans import kmeans1d, cluster_masks
from repro.core.policy import QuantPolicy
from repro.core.qlinear import (
    linear,
    qlinear,
    splitq_linear_3pass,
    splitq_linear_fused,
    splitq_linear_packed,
)
# NOTE: the bare `quantize` function is intentionally NOT re-exported — it
# would shadow the `repro.core.quantize` submodule attribute on the package.
from repro.core.quantize import (
    QParams,
    QTensor,
    compute_qparams,
    dequantize,
    fake_quant,
    pack_codes,
    quantize_tensor,
    unpack_codes,
)
from repro.core.report import LayerQuantStats, QuantReport, build_quant_report
from repro.core.split import (
    PackedSplitQTensor,
    SplitQTensor,
    split_error_stats,
    split_fp,
    split_quantize,
    split_quantize_packed,
    sqnr_db,
    tensor_quant_stats,
)

__all__ = [
    "QuantizedModel", "quantize_model", "restructure", "kmeans1d",
    "cluster_masks", "QuantPolicy", "linear", "qlinear",
    "splitq_linear_3pass", "splitq_linear_fused", "splitq_linear_packed",
    "QParams", "QTensor", "compute_qparams", "dequantize", "fake_quant",
    "pack_codes", "quantize_tensor", "unpack_codes",
    "PackedSplitQTensor", "SplitQTensor", "split_error_stats", "split_fp",
    "split_quantize", "split_quantize_packed", "sqnr_db",
    "tensor_quant_stats", "LayerQuantStats", "QuantReport",
    "build_quant_report",
]
