"""1-D k-means for SplitQuantV2 weight clustering.

The paper clusters the scalar weight values of every linear/conv layer into
k=3 (lower / middle / upper) clusters. In 1-D, optimal k-means clusters are
contiguous value intervals, so the whole problem reduces to choosing k-1
thresholds. We exploit this twice:

* ``kmeans1d`` — histogram-accelerated Lloyd's algorithm: O(n) one-pass
  histogram, then Lloyd iterations over ``bins`` weighted points instead of
  ``n`` scalars. This is what makes "split a 1B model in ~2 CPU-minutes"
  (paper §4.3) possible, and it is jit-able / pjit-able so a *sharded* 20B
  model can be preprocessed in place on a TPU mesh (beyond-paper).
* deterministic quantile init — identical restructuring on every host of a
  multi-host job without any coordination.

All functions are pure JAX (fp32 internally) and run under jit; a Pallas
kernel for the assignment/update hot loop lives in ``repro.kernels.kmeans1d``
and is validated against :func:`lloyd_step` as its oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

DEFAULT_BINS = 4096
DEFAULT_ITERS = 32


class KMeansResult(NamedTuple):
    """Result of 1-D k-means.

    centroids:  (k,) cluster centers, sorted ascending.
    boundaries: (k-1,) decision thresholds between adjacent centroids.
    inertia:    () within-cluster sum of squared distances (over histogram).
    """

    centroids: jax.Array
    boundaries: jax.Array
    inertia: jax.Array


def quantile_init(x: jax.Array, k: int) -> jax.Array:
    """Deterministic centroid init at the (i+0.5)/k quantiles."""
    qs = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k
    return jnp.quantile(x.astype(jnp.float32), qs)


def assign(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest-centroid assignment. x: (...,), centroids: (k,) -> int32 ids."""
    d = jnp.abs(x[..., None].astype(jnp.float32) - centroids.astype(jnp.float32))
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def lloyd_step(
    values: jax.Array, weights: jax.Array, centroids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One Lloyd iteration over weighted 1-D points.

    values:  (m,) point coordinates (histogram bin centers or raw scalars)
    weights: (m,) point masses (bin counts; ones for raw scalars)
    Returns (new_centroids (k,), inertia ()). Empty clusters keep their
    previous centroid (standard Lloyd fix; deterministic).
    """
    ids = assign(values, centroids)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(ids, k, dtype=jnp.float32)  # (m, k)
    w = weights.astype(jnp.float32)
    mass = onehot.T @ w  # (k,)
    wsum = onehot.T @ (w * values.astype(jnp.float32))  # (k,)
    new = jnp.where(mass > 0, wsum / jnp.maximum(mass, 1.0), centroids)
    d2 = (values.astype(jnp.float32) - new[ids]) ** 2
    inertia = jnp.sum(w * d2)
    return jnp.sort(new), inertia


@functools.partial(jax.jit, static_argnames=("k", "bins", "iters"))
def kmeans1d(
    x: jax.Array,
    k: int = 3,
    bins: int = DEFAULT_BINS,
    iters: int = DEFAULT_ITERS,
) -> KMeansResult:
    """Histogram-accelerated 1-D k-means with deterministic quantile init.

    Works on any-shape ``x`` (flattened). Degenerate inputs (constant tensor)
    return k identical centroids — the split transform handles that case by
    putting everything in the middle cluster.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    lo = jnp.min(flat)
    hi = jnp.max(flat)
    span = jnp.maximum(hi - lo, 1e-12)
    # Histogram: O(n) once; Lloyd then runs on `bins` weighted points.
    idx = jnp.clip(((flat - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    counts = jnp.zeros((bins,), jnp.float32).at[idx].add(1.0)
    centers = lo + (jnp.arange(bins, dtype=jnp.float32) + 0.5) * (span / bins)

    init = quantile_init(flat, k)

    def body(carry, _):
        cents, _ = carry
        new, inertia = lloyd_step(centers, counts, cents)
        return (new, inertia), None

    (cents, inertia), _ = jax.lax.scan(
        body, (init, jnp.float32(0.0)), None, length=iters
    )
    boundaries = (cents[:-1] + cents[1:]) / 2.0
    return KMeansResult(cents, boundaries, inertia)


def cluster_masks(x: jax.Array, boundaries: jax.Array) -> jax.Array:
    """Membership ids from interval boundaries. Returns int32, same shape as x.

    1-D k-means clusters are the intervals (-inf, b0], (b0, b1], ..., so ids
    are computed by threshold comparison — O(n·(k-1)) with no argmin, and
    bit-stable across platforms.
    """
    xf = x.astype(jnp.float32)
    return jnp.sum(
        (xf[..., None] > boundaries.astype(jnp.float32)).astype(jnp.int32), axis=-1
    )


def kmeans1d_np(x, k: int = 3, bins: int = DEFAULT_BINS, iters: int = DEFAULT_ITERS):
    """NumPy twin of :func:`kmeans1d` for host-side preprocessing paths and
    as an independent oracle in tests."""
    import numpy as np

    flat = np.asarray(x, dtype=np.float32).reshape(-1)
    lo, hi = float(flat.min()), float(flat.max())
    span = max(hi - lo, 1e-12)
    idx = np.clip(((flat - lo) / span * bins).astype(np.int64), 0, bins - 1)
    counts = np.bincount(idx, minlength=bins).astype(np.float32)
    centers = lo + (np.arange(bins, dtype=np.float32) + 0.5) * (span / bins)
    qs = (np.arange(k, dtype=np.float32) + 0.5) / k
    cents = np.quantile(flat, qs)
    for _ in range(iters):
        ids = np.argmin(np.abs(centers[:, None] - cents[None, :]), axis=1)
        new = cents.copy()
        for c in range(k):
            m = counts[ids == c]
            if m.sum() > 0:
                new[c] = (m * centers[ids == c]).sum() / m.sum()
        cents = np.sort(new)
    bounds = (cents[:-1] + cents[1:]) / 2.0
    return cents, bounds
