"""The SplitQuantV2 transform (paper §3) — layer splitting by 1-D k-means.

Given a weight tensor ``W``, partition its scalar values into ``k=3``
contiguous clusters (lower / middle / upper) with 1-D k-means, and represent

    W = Σ_c  W ⊙ m_c            (m_c = membership mask of cluster c)

Each plane ``W ⊙ m_c`` is quantized *per-tensor* with its own (S, Z) over the
hull of the cluster's value range **extended to include 0**. The extension is
what makes the split exact under quantization: masked-out entries encode to
the zero-point ``Z_c`` (guaranteed in-range because 0 ∈ [β, α]) and therefore
dequantize to exactly 0.0 — planes never leak error into each other's
support. The dense middle cluster of a bell-shaped weight distribution gets a
range ~10–20× narrower than the full tensor, i.e. a ~10–20× larger scale
factor — the paper's resolution win.

Two storage formats:

* :class:`SplitQTensor` — the **paper-faithful** format: k full-shape packed
  int-b planes (model size k·b/32 of FP32 — the paper's "3/8 for INT4").
* :class:`PackedSplitQTensor` — **beyond-paper**: every element belongs to
  exactly one cluster, so store one b-bit code + a 2-bit cluster id
  (b+2 bits/weight, e.g. 6 bits for INT4 → 3/16 of FP32) plus a k-entry
  (S, Z) LUT. Bit-identical dequantized values, half the paper's footprint,
  directly addressing the paper's §5 limitation.

Everything is jit-safe; the transform runs under pjit on sharded weights.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kmeans
from repro.core.quantize import (
    QParams,
    QTensor,
    compute_qparams,
    dequantize,
    pack_codes,
    quantize,
    unpack_codes,
)


class SplitInfo(NamedTuple):
    """Clustering metadata for one tensor."""

    centroids: jax.Array  # (k,)
    boundaries: jax.Array  # (k-1,)
    counts: jax.Array  # (k,) cluster populations


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["planes", "scales", "zeros", "info"],
    meta_fields=["bits", "shape"],
)
@dataclasses.dataclass(frozen=True)
class SplitQTensor:
    """Paper-faithful storage: k packed planes, each full logical shape."""

    planes: jax.Array  # (k, ...packed shape) int8 carriers
    scales: jax.Array  # (k,) fp32
    zeros: jax.Array  # (k,) fp32
    info: SplitInfo
    bits: int
    shape: tuple[int, ...]

    @property
    def k(self) -> int:
        return self.planes.shape[0]

    def plane_qparams(self, c: int) -> QParams:
        return QParams(self.scales[c], self.zeros[c], self.bits)

    def dequantize(self) -> jax.Array:
        """Effective weight Ŵ = Σ_c dequant(plane_c)."""
        out = jnp.zeros(self.shape, jnp.float32)
        for c in range(self.k):
            q = unpack_codes(self.planes[c], self.bits, out_len=self.shape[-1])
            out = out + dequantize(q.reshape(self.shape), self.plane_qparams(c))
        return out


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "cids", "scales", "zeros"],
    meta_fields=["bits", "shape"],
)
@dataclasses.dataclass(frozen=True)
class PackedSplitQTensor:
    """Beyond-paper storage: one b-bit code + 2-bit cluster id per element."""

    codes: jax.Array  # packed int-b codes, int8 carriers
    cids: jax.Array  # packed 2-bit cluster ids, int8 carriers
    scales: jax.Array  # (k,) fp32
    zeros: jax.Array  # (k,) fp32
    bits: int
    shape: tuple[int, ...]

    def dequantize(self) -> jax.Array:
        q = unpack_codes(self.codes, self.bits, out_len=self.shape[-1])
        q = q.reshape(self.shape).astype(jnp.float32)
        cid = unpack_codes(self.cids, 2, out_len=self.shape[-1])
        cid = (cid.reshape(self.shape).astype(jnp.int32)) & 0x3
        s = self.scales[cid]
        z = self.zeros[cid]
        return (q - z) / s


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "cids", "scales", "zeros"],
    meta_fields=["bits", "kclusters", "widths", "align"],
)
@dataclasses.dataclass(frozen=True)
class PackedSplitQGroup:
    """Several packed tensors sharing one K dim, concatenated along N.

    The serving engine fuses sibling projections (QKV; gate+up) into ONE
    kernel launch: members are quantized *independently* (bit-identical to
    their standalone PackedSplitQTensor form) and their packed codes/cids are
    concatenated along N, each member padded to a multiple of ``align`` so
    every (bn ≤ align) output block maps to exactly one member. The kernel
    selects the member's k-entry (1/S, Z) LUT rows by block index, keeping
    cluster ids at 2 bits — the 6-bit/weight footprint survives grouping.
    """

    codes: jax.Array   # (..., K, Np_tot//per) int8 carriers
    cids: jax.Array    # (..., K, Np_tot//4) packed 2-bit ids
    scales: jax.Array  # (..., G*k) fp32, member-major
    zeros: jax.Array   # (..., G*k) fp32
    bits: int
    kclusters: int
    widths: tuple[int, ...]   # logical N of each member
    align: int                # member padding granularity along N

    @property
    def groups(self) -> int:
        return len(self.widths)

    def padded_widths(self) -> tuple[int, ...]:
        return tuple(-(-w // self.align) * self.align for w in self.widths)

    def dequantize(self) -> list[jax.Array]:
        """Per-member effective weights (the padding columns are dropped)."""
        n_tot = sum(self.padded_widths())
        q = unpack_codes(self.codes, self.bits, out_len=n_tot)
        q = q.reshape(self.codes.shape[:-1] + (n_tot,)).astype(jnp.float32)
        cid = unpack_codes(self.cids, 2, out_len=n_tot)
        cid = cid.reshape(q.shape).astype(jnp.int32) & 0x3
        out, off = [], 0
        for g, (w, pw) in enumerate(zip(self.widths, self.padded_widths())):
            qs = q[..., off:off + w]
            cs = cid[..., off:off + w]
            s = self.scales[..., g * self.kclusters:(g + 1) * self.kclusters]
            z = self.zeros[..., g * self.kclusters:(g + 1) * self.kclusters]
            sg = jnp.take_along_axis(
                jnp.broadcast_to(s[..., None, :], cs.shape[:-1] + s.shape[-1:]),
                cs, axis=-1,
            ) if s.ndim > 1 else s[cs]
            zg = jnp.take_along_axis(
                jnp.broadcast_to(z[..., None, :], cs.shape[:-1] + z.shape[-1:]),
                cs, axis=-1,
            ) if z.ndim > 1 else z[cs]
            out.append((qs - zg) / sg)
            off += pw
        return out


def group_packed(
    members: list[PackedSplitQTensor], align: int | None = None
) -> PackedSplitQGroup:
    """Concatenate independently-quantized packed tensors along N.

    Bit-exact: member codes/scales are reused untouched; only zero bytes are
    appended so each member's span is a multiple of ``align`` (the padded
    output columns are garbage and sliced off by the kernel wrapper).
    """
    bits = members[0].bits
    per = 8 // bits
    k = members[0].scales.shape[-1]
    assert all(m.bits == bits and m.scales.shape[-1] == k for m in members)
    widths = tuple(m.shape[-1] for m in members)
    if align is None:
        align = 512 if all(w % 512 == 0 for w in widths) else 128
    codes, cids = [], []
    for m, w in zip(members, widths):
        pw = -(-w // align) * align
        pad_codes = (pw - m.codes.shape[-1] * per) // per
        pad_cids = pw // 4 - m.cids.shape[-1]
        lead = [(0, 0)] * (m.codes.ndim - 1)
        codes.append(jnp.pad(m.codes, lead + [(0, pad_codes)]))
        cids.append(jnp.pad(m.cids, lead + [(0, pad_cids)]))
    return PackedSplitQGroup(
        codes=jnp.concatenate(codes, axis=-1),
        cids=jnp.concatenate(cids, axis=-1),
        scales=jnp.concatenate([m.scales for m in members], axis=-1),
        zeros=jnp.concatenate([m.zeros for m in members], axis=-1),
        bits=bits, kclusters=k, widths=widths, align=align,
    )


def split_masks(w: jax.Array, k: int = 3, bins: int = kmeans.DEFAULT_BINS,
                iters: int = kmeans.DEFAULT_ITERS) -> tuple[jax.Array, SplitInfo]:
    """Cluster ids (int32, shape of w) + clustering metadata."""
    res = kmeans.kmeans1d(w, k=k, bins=bins, iters=iters)
    ids = kmeans.cluster_masks(w, res.boundaries)
    counts = jnp.bincount(ids.reshape(-1), length=k).astype(jnp.int32)
    return ids, SplitInfo(res.centroids, res.boundaries, counts)


def plane_qparams_from_ids(
    w: jax.Array, ids: jax.Array, k: int, bits: int
) -> tuple[jax.Array, jax.Array]:
    """Per-cluster (S, Z) over hull(cluster range ∪ {0}). Returns ((k,),(k,))."""
    wf = w.astype(jnp.float32)
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    scales, zeros = [], []
    for c in range(k):
        sel = ids == c
        beta = jnp.min(jnp.where(sel, wf, big))
        alpha = jnp.max(jnp.where(sel, wf, -big))
        empty = ~jnp.any(sel)
        beta = jnp.where(empty, 0.0, beta)
        alpha = jnp.where(empty, 0.0, alpha)
        qp = compute_qparams(
            wf, bits, beta=jnp.minimum(beta, 0.0), alpha=jnp.maximum(alpha, 0.0)
        )
        scales.append(qp.scale)
        zeros.append(qp.zero)
    return jnp.stack(scales), jnp.stack(zeros)


def _pad_last(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[-1]) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


@functools.partial(jax.jit, static_argnames=("bits", "k", "bins", "iters"))
def split_quantize(
    w: jax.Array,
    bits: int,
    k: int = 3,
    bins: int = kmeans.DEFAULT_BINS,
    iters: int = kmeans.DEFAULT_ITERS,
) -> SplitQTensor:
    """SplitQuantV2 on one tensor → paper-faithful k-plane storage."""
    ids, info = split_masks(w, k=k, bins=bins, iters=iters)
    scales, zeros = plane_qparams_from_ids(w, ids, k, bits)
    planes = []
    for c in range(k):
        qp = QParams(scales[c], zeros[c], bits)
        wc = jnp.where(ids == c, w.astype(jnp.float32), 0.0)
        planes.append(pack_codes(_pad_last(quantize(wc, qp), 8 // bits), bits))
    return SplitQTensor(
        planes=jnp.stack(planes), scales=scales, zeros=zeros, bits=bits,
        shape=tuple(w.shape), info=info,
    )


@functools.partial(jax.jit, static_argnames=("bits", "k", "bins", "iters"))
def split_quantize_packed(
    w: jax.Array,
    bits: int,
    k: int = 3,
    bins: int = kmeans.DEFAULT_BINS,
    iters: int = kmeans.DEFAULT_ITERS,
) -> PackedSplitQTensor:
    """SplitQuantV2 → beyond-paper (b+2)-bit packed storage.

    Bit-identical dequantized values to :func:`split_quantize`: each element
    is encoded with its own cluster's (S, Z); the other planes' exact zeros
    are implicit rather than stored.
    """
    assert k <= 4, "cluster id is stored in 2 bits"
    ids, _ = split_masks(w, k=k, bins=bins, iters=iters)
    scales, zeros = plane_qparams_from_ids(w, ids, k, bits)
    s = scales[ids]
    z = zeros[ids]
    q = jnp.round(s * w.astype(jnp.float32)) + z
    q = jnp.clip(q, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1).astype(jnp.int8)
    codes = pack_codes(_pad_last(q, 8 // bits), bits)
    cids = pack_codes(_pad_last(ids.astype(jnp.int8), 4), 2)
    return PackedSplitQTensor(
        codes=codes, cids=cids, scales=scales, zeros=zeros, bits=bits,
        shape=tuple(w.shape),
    )


def split_fp(w: jax.Array, k: int = 3) -> tuple[jax.Array, SplitInfo]:
    """FP split only (no quantization): planes (k, *w.shape) with Σ = w exactly.

    This is the "preservation of functionality" object (paper §4.1)."""
    ids, info = split_masks(w, k=k)
    planes = jnp.stack(
        [jnp.where(ids == c, w, jnp.zeros_like(w)) for c in range(k)]
    )
    return planes, info


# ---------------------------------------------------------------------------
# Error metrics (benchmarks & tests)
# ---------------------------------------------------------------------------


def sqnr_db(w: jax.Array, w_hat: jax.Array) -> jax.Array:
    """Signal-to-quantization-noise ratio in dB."""
    sig = jnp.mean(jnp.square(w.astype(jnp.float32)))
    err = jnp.mean(jnp.square(w.astype(jnp.float32) - w_hat.astype(jnp.float32)))
    return 10.0 * jnp.log10(sig / jnp.maximum(err, 1e-30))


@functools.partial(jax.jit, static_argnames=("bits", "k"))
def split_error_stats(w: jax.Array, bits: int, k: int = 3) -> dict[str, jax.Array]:
    """Baseline per-tensor linear quant vs SplitQuantV2, on one tensor."""
    qp = compute_qparams(w, bits)
    base = dequantize(quantize(w, qp), qp)
    sq = split_quantize(w, bits, k=k)
    sp = sq.dequantize()
    return {
        "sqnr_base_db": sqnr_db(w, base),
        "sqnr_split_db": sqnr_db(w, sp),
        "mse_base": jnp.mean(jnp.square(w - base)),
        "mse_split": jnp.mean(jnp.square(w - sp)),
    }


@functools.partial(jax.jit, static_argnames=("bits", "k"))
def tensor_quant_stats(w: jax.Array, bits: int, k: int = 3) -> dict[str, jax.Array]:
    """Everything the per-layer quant report needs from ONE tensor.

    Extends :func:`split_error_stats` with the attribution signals that
    explain *why* a layer's SQNR looks the way it does: the fraction of
    values the baseline quantizer saturates (``clip_frac_base``), the
    population of the outer k-means clusters (``outlier_frac`` — the mass
    SplitQuantV2 peels off into their own planes), and the range-resolution
    win of the middle cluster vs the full tensor (``range_gain`` ≈ the
    paper's 10–20× scale-factor claim). Shares one clustering pass between
    the error metrics and the attribution stats."""
    wf = w.astype(jnp.float32)
    qp = compute_qparams(wf, bits)
    raw = jnp.round(qp.scale * wf) + qp.zero
    clip_frac = jnp.mean(((raw < qp.qmin) | (raw > qp.qmax)).astype(jnp.float32))
    base = dequantize(quantize(wf, qp), qp)

    ids, info = split_masks(wf, k=k)
    scales, zeros = plane_qparams_from_ids(wf, ids, k, bits)
    # packed-formula dequant (bit-identical to the k-plane sum)
    s = scales[ids]
    z = zeros[ids]
    q = jnp.clip(jnp.round(s * wf) + z, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1)
    sp = (q - z) / s

    total = jnp.float32(wf.size)
    counts = info.counts.astype(jnp.float32)
    # k-means boundaries are sorted, so clusters 0 and k-1 hold the tails
    outlier_frac = (counts[0] + counts[-1]) / total
    full_span = jnp.max(wf) - jnp.min(wf)
    # middle cluster = densest; its (S) vs the full-tensor scale is the
    # per-weight resolution multiplier the split buys
    mid = jnp.argmax(counts)
    range_gain = scales[mid] / qp.scale
    return {
        "sqnr_base_db": sqnr_db(wf, base),
        "sqnr_split_db": sqnr_db(wf, sp),
        "mse_base": jnp.mean(jnp.square(wf - base)),
        "mse_split": jnp.mean(jnp.square(wf - sp)),
        "clip_frac_base": clip_frac,
        "outlier_frac": outlier_frac,
        "range_gain": range_gain,
        "cluster_counts": info.counts,
    }


def choose_k(w: jax.Array, bits: int, max_k: int = 3, min_gain_db: float = 3.0) -> int:
    """Dynamic per-layer k (paper §5 future work): smallest k whose marginal
    SQNR gain over k-1 exceeds ``min_gain_db``. Host-side helper (concrete)."""
    import numpy as np

    prev = None
    best = 1
    for k in range(1, max_k + 1):
        if k == 1:
            qp = compute_qparams(w, bits)
            w_hat = dequantize(quantize(w, qp), qp)
        else:
            w_hat = split_quantize(w, bits, k=k).dequantize()
        s = float(sqnr_db(w, w_hat))
        if prev is None or s - prev >= min_gain_db:
            best = k
            prev = s
        else:
            break
    return best
