"""Whole-model restructuring pass — SplitQuantV2 over a parameter pytree.

``restructure(params, policy)`` walks any pytree of arrays, applies
SplitQuantV2 (or the plain linear-quant baseline) to every leaf the policy
selects, and returns a :class:`QuantizedModel` holding quantized leaves +
untouched leaves. ``materialize()`` rebuilds an ordinary param pytree with
*effective* (dequantized) weights so any model in the zoo runs unchanged —
this is exactly the fake-quant semantics the paper evaluates, while the
serving path can route selected matmuls through the packed Pallas kernels.

Stacked layers (leading scan axis of size L) are handled by vmapping the
per-tensor transform over the leading axis: each layer gets its *own*
clustering and scales, matching the paper's layer-by-layer processing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import split as split_mod
from repro.core.policy import QuantPolicy
from repro.core.quantize import QTensor, quantize_tensor


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclass
class QuantizedModel:
    """Result of the restructuring pass."""

    qleaves: dict[str, Any]          # path -> QTensor | SplitQTensor | Packed
    passthrough: dict[str, jax.Array]
    treedef: Any
    paths: list[str]                 # leaf order for reconstruction
    stacked: dict[str, bool]         # path -> had leading layer axis
    policy: QuantPolicy

    def materialize(self) -> Any:
        """Params pytree with effective (dequantized) weights."""
        leaves = []
        for p in self.paths:
            if p in self.qleaves:
                qt = self.qleaves[p]
                if self.stacked[p]:
                    w = jax.vmap(lambda t: t.dequantize())(qt)
                else:
                    w = qt.dequantize()
                leaves.append(w)
            else:
                leaves.append(self.passthrough[p])
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def as_executable(self, *, group: bool = True) -> Any:
        """Params-like pytree with hot-path leaves kept in packed storage.

        The model forward routes these through the packed Pallas kernels
        (see repro.engine) — real 6-bit weight streaming instead of the
        fake-quant dense weights ``materialize()`` rebuilds. With
        ``group=True``, sibling projections are fused (wq/wk/wv -> wqkv,
        w_gate/w_up -> w_gateup) so a decode block costs 4 quantized kernel
        launches instead of 7."""
        from repro.engine.executable import build_executable

        return build_executable(self, group=group)

    def size_bytes(self) -> dict[str, int]:
        """Storage accounting (reproduces the paper's 3/8-of-FP32 claim)."""
        def nbytes(t):
            return sum(
                np.prod(l.shape) * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(t)
            )
        q = int(sum(nbytes(v) for v in self.qleaves.values()))
        rest = int(sum(nbytes(v) for v in self.passthrough.values()))
        return {"quantized": q, "passthrough": rest, "total": q + rest}


def _transform_leaf(w: jax.Array, policy: QuantPolicy, stacked: bool):
    def one(t):
        if not policy.split:
            return quantize_tensor(t, policy.bits)
        if policy.packed:
            return split_mod.split_quantize_packed(t, policy.bits, k=policy.k)
        return split_mod.split_quantize(t, policy.bits, k=policy.k)

    if stacked:
        return jax.vmap(one)(w)
    return one(w)


def restructure(
    params: Any,
    policy: QuantPolicy | None = None,
    *,
    stacked_axis_paths: Callable[[str], bool] | None = None,
) -> QuantizedModel:
    """Apply SplitQuantV2 (per ``policy``) to every selected leaf.

    stacked_axis_paths: predicate marking leaves whose axis 0 is a scan/layer
      axis (each slice is an independent layer → independent clustering).
      Default: any selected leaf with ndim >= 3 whose path contains "layers".
    """
    policy = policy or QuantPolicy()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    qleaves: dict[str, Any] = {}
    passthrough: dict[str, jax.Array] = {}
    paths: list[str] = []
    stacked: dict[str, bool] = {}

    for path, leaf in flat:
        p = _path_str(path)
        paths.append(p)
        leaf = jnp.asarray(leaf)
        if policy.wants(p, leaf.ndim, leaf.size):
            if stacked_axis_paths is not None:
                is_stacked = stacked_axis_paths(p) and leaf.ndim >= 3
            else:
                is_stacked = leaf.ndim >= 3 and "layers" in p.lower()
            qleaves[p] = _transform_leaf(leaf, policy, is_stacked)
            stacked[p] = is_stacked
        else:
            passthrough[p] = leaf
            stacked[p] = False
    return QuantizedModel(
        qleaves=qleaves, passthrough=passthrough, treedef=treedef,
        paths=paths, stacked=stacked, policy=policy,
    )


def quantize_model(params: Any, bits: int, *, split: bool = True,
                   packed: bool = False, k: int = 3) -> Any:
    """One-call fake-quant: restructure + materialize effective weights."""
    qm = restructure(params, QuantPolicy(bits=bits, split=split, packed=packed, k=k))
    return qm.materialize()
