"""Per-layer quantization-quality report (the quant-time telemetry layer).

``build_quant_report(params, policy)`` walks the same leaf selection the
restructuring pass uses and computes, for every layer that would be
quantized, the SplitQuantV2 error/attribution stats from
:func:`repro.core.split.tensor_quant_stats` — baseline vs split SQNR,
baseline clip fraction, outlier-cluster mass, and the middle-cluster
resolution gain. Stacked scan leaves (leading L axis) expand to one row
per layer slice (``path/L3``), matching the paper's layer-by-layer
processing.

The report is three things at once:

* a ranked JSON artifact (``--quant-report out.json`` on ``serve.py`` and
  ``examples/quantize_llm.py``) with worst-layer-first attribution,
* a :class:`repro.obs.metrics.Registry` feed (``record()``: gauges
  labeled ``layer``/``bits``/``split`` so Prometheus exports carry
  per-layer quality next to the serving latency series), and
* the CI accuracy gate's per-layer assertion surface
  (``sqnr_split_db >= sqnr_base_db`` on every quantized layer).

Computing k-means + two quant round-trips per leaf is NOT free, so the
report is strictly opt-in — nothing on the serving hot path pays for it
unless ``--quant-report`` (or an explicit ``build_quant_report`` call)
asks.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable

import jax
import numpy as np

from repro.core.policy import QuantPolicy
from repro.core.split import tensor_quant_stats


@dataclasses.dataclass(frozen=True)
class LayerQuantStats:
    """One quantized layer's error + attribution numbers."""

    layer: str                  # leaf path; stacked leaves get "/L{i}"
    shape: tuple[int, ...]
    size: int
    bits: int
    split: bool
    k: int
    sqnr_base_db: float
    sqnr_split_db: float
    mse_base: float
    mse_split: float
    clip_frac_base: float
    outlier_frac: float
    range_gain: float
    cluster_counts: tuple[int, ...]

    @property
    def sqnr_gain_db(self) -> float:
        return self.sqnr_split_db - self.sqnr_base_db

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        d["cluster_counts"] = list(self.cluster_counts)
        d["sqnr_gain_db"] = self.sqnr_gain_db
        return d


@dataclasses.dataclass
class QuantReport:
    """Whole-model per-layer quant quality, ranked worst-first."""

    bits: int
    split: bool
    packed: bool
    k: int
    layers: list[LayerQuantStats]

    def ranked(self) -> list[LayerQuantStats]:
        """Worst layer first: lowest post-split SQNR carries the most
        quantization noise into the forward pass."""
        return sorted(self.layers, key=lambda r: r.sqnr_split_db)

    def worst(self, n: int = 5) -> list[LayerQuantStats]:
        return self.ranked()[:n]

    def summary(self) -> dict:
        if not self.layers:
            return {"layers": 0}
        gains = [r.sqnr_gain_db for r in self.layers]
        worst = self.ranked()[0]
        return {
            "layers": len(self.layers),
            "bits": self.bits,
            "split": self.split,
            "packed": self.packed,
            "mean_sqnr_base_db": float(
                np.mean([r.sqnr_base_db for r in self.layers])),
            "mean_sqnr_split_db": float(
                np.mean([r.sqnr_split_db for r in self.layers])),
            "mean_sqnr_gain_db": float(np.mean(gains)),
            "min_sqnr_gain_db": float(np.min(gains)),
            "worst_layer": worst.layer,
            "worst_layer_sqnr_split_db": worst.sqnr_split_db,
        }

    def to_json(self) -> dict:
        return {
            "schema": 1,
            "bits": self.bits,
            "split": self.split,
            "packed": self.packed,
            "k": self.k,
            "summary": self.summary(),
            "layers": [r.to_dict() for r in self.ranked()],
        }

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")

    def record(self, registry) -> None:
        """File the report into a metrics registry.

        Gauges labeled ``layer``/``bits``/``split`` (the ISSUE contract):
        ``split="1"`` series carry the SplitQuantV2 numbers, ``split="0"``
        the linear baseline on the same tensor, so one PromQL diff shows
        the per-layer split win."""
        bits = str(self.bits)
        sqnr = registry.gauge(
            "quant_layer_sqnr_db",
            "per-layer SQNR after quantization (dB)")
        mse = registry.gauge(
            "quant_layer_mse", "per-layer quantization MSE")
        clip = registry.gauge(
            "quant_layer_clip_frac",
            "fraction of weights the baseline quantizer saturates")
        outl = registry.gauge(
            "quant_layer_outlier_frac",
            "weight mass in the outer k-means clusters")
        gain = registry.gauge(
            "quant_layer_range_gain",
            "middle-cluster scale vs full-tensor scale")
        size = registry.gauge(
            "quant_layer_size_params", "per-layer parameter count")
        for r in self.layers:
            lbl = {"layer": r.layer, "bits": bits}
            sqnr.set(r.sqnr_base_db, split="0", **lbl)
            sqnr.set(r.sqnr_split_db, split="1", **lbl)
            mse.set(r.mse_base, split="0", **lbl)
            mse.set(r.mse_split, split="1", **lbl)
            clip.set(r.clip_frac_base, split="0", **lbl)
            outl.set(r.outlier_frac, split="1", **lbl)
            gain.set(r.range_gain, split="1", **lbl)
            size.set(r.size, **lbl)
        registry.counter(
            "quant_layers_total", "layers processed by the quantizer"
        ).inc(len(self.layers), bits=bits,
              split="1" if self.split else "0")


def build_quant_report(
    params: Any,
    policy: QuantPolicy | None = None,
    *,
    stacked_axis_paths: Callable[[str], bool] | None = None,
) -> QuantReport:
    """Compute per-layer quant stats over the leaves ``policy`` selects.

    Mirrors ``restructure``'s walk (same selection, same stacked-axis
    detection) without building any quantized storage: stats come from
    one vmapped :func:`tensor_quant_stats` per leaf, so a stacked
    ``(L, K, N)`` scan leaf costs one compiled pass and expands to L
    report rows."""
    from repro.core.apply import _path_str  # shared path formatting

    policy = policy or QuantPolicy()
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    rows: list[LayerQuantStats] = []
    for path, leaf in flat:
        p = _path_str(path)
        arr = jax.numpy.asarray(leaf)
        if not policy.wants(p, arr.ndim, arr.size):
            continue
        if stacked_axis_paths is not None:
            stacked = stacked_axis_paths(p) and arr.ndim >= 3
        else:
            stacked = arr.ndim >= 3 and "layers" in p.lower()
        if stacked:
            stats = jax.vmap(
                lambda t: tensor_quant_stats(t, policy.bits, k=policy.k)
            )(arr)
            stats = {k: np.asarray(v) for k, v in stats.items()}
            shape = tuple(arr.shape[1:])
            for i in range(arr.shape[0]):
                rows.append(_row(f"{p}/L{i}", shape, policy,
                                 {k: v[i] for k, v in stats.items()}))
        else:
            stats = {k: np.asarray(v)
                     for k, v in tensor_quant_stats(
                         arr, policy.bits, k=policy.k).items()}
            rows.append(_row(p, tuple(arr.shape), policy, stats))
    return QuantReport(bits=policy.bits, split=policy.split,
                       packed=policy.packed, k=policy.k, layers=rows)


def _row(layer: str, shape: tuple[int, ...], policy: QuantPolicy,
         stats: dict) -> LayerQuantStats:
    return LayerQuantStats(
        layer=layer, shape=shape, size=int(np.prod(shape)),
        bits=policy.bits, split=policy.split, k=policy.k,
        sqnr_base_db=float(stats["sqnr_base_db"]),
        sqnr_split_db=float(stats["sqnr_split_db"]),
        mse_base=float(stats["mse_base"]),
        mse_split=float(stats["mse_split"]),
        clip_frac_base=float(stats["clip_frac_base"]),
        outlier_frac=float(stats["outlier_frac"]),
        range_gain=float(stats["range_gain"]),
        cluster_counts=tuple(int(c) for c in stats["cluster_counts"]),
    )
