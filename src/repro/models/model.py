"""Public model API: build a :class:`Model` from an ArchConfig.

A Model bundles pure functions (init / train_loss / prefill / decode_step /
init_cache) plus ``input_specs(shape)`` returning ShapeDtypeStruct stand-ins
for every input of the step being lowered — the dry-run contract.

Batch conventions per family:
  LM (dense/moe/ssm/hybrid):  {"tokens": (B,S) i32, "labels": (B,S) i32}
  VLM (qwen2-vl):             + "vis_embeds": (B,S_vis,D), "pos3": (B,S,3);
                              tokens cover the text tail (S_txt = S - S_vis)
  audio (whisper):            {"enc_embeds": (B,S_enc,D), "tokens": (B,S),
                               "labels": (B,S)}   (frontend stubbed)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm

VLM_VIS_FRACTION = 4  # 1/4 of the sequence is vision tokens (stub embeds)
WHISPER_ENC_LEN = 1500  # fixed stub encoder length for decode shapes


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# Cache leaves carrying recurrent state, all laid out (L, B, ...): unlike
# positional KV (which per-slot ``len`` masks for free), stale recurrent
# state would leak a recycled slot's previous request into the new one.
_RECURRENT_KEYS = ("ssm", "conv", "wkv", "shift_t", "shift_c")


def reset_slots(cache: dict, refill: jax.Array,
                start_len: jax.Array | None = None) -> dict:
    """Reset the batch rows selected by ``refill`` (B,) bool for reuse.

    Zeroes per-row ``len`` and recurrent-state rows. Positional KV rows are
    deliberately NOT zeroed: writes restart at position 0 and attention
    masks keys at ``>= len``, so stale entries are unreachable — skipping
    the rewrite keeps slot recycling O(state), not O(cache).

    ``start_len`` (B,) int32, when given, is each fresh row's STARTING fill
    length instead of 0: a prefix-cache hit admits the request with its
    shared pages already holding ``start_len`` tokens of KV, so prefill
    positions, write offsets and attention masks all begin past the shared
    prefix (the same per-row ``len`` contract that makes chunked prefill
    exact). Rows not selected by ``refill`` ignore it.

    This contract is also what makes PREEMPTION RESTORE exact (see
    ``runtime.resilience``): a preempted request re-enters through an
    ordinary ``reset_slots`` + ``prefill`` of prompt + emitted tokens —
    positions, masks and recurrent state are all recomputed from ``len``
    alone, so the rebuilt cache is indistinguishable from one that never
    lost its pages, and the serving tests pin the resumed greedy stream
    bit-identical."""
    out = dict(cache)
    start = 0 if start_len is None else start_len.astype(jnp.int32)
    out["len"] = jnp.where(refill, start, cache["len"]).astype(jnp.int32)
    for key in _RECURRENT_KEYS:
        if key in cache:
            leaf = cache[key]
            sel = refill.reshape((1, -1) + (1,) * (leaf.ndim - 2))
            out[key] = jnp.where(sel, jnp.zeros((), leaf.dtype), leaf)
    return out


def _lm_positions(b, s, offset=0):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)) + offset


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[..., tuple[jax.Array, dict]]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    verify_step: Callable[..., tuple[jax.Array, Any]]
    init_cache: Callable[..., Any]
    init_paged_cache: Callable[..., Any]
    input_specs: Callable[[ShapeConfig], dict]
    cache_specs: Callable[[ShapeConfig], Any]


def build_model(cfg: ArchConfig) -> Model:
    dt = _dtype(cfg)

    # -- embedding of a batch into the residual stream ----------------------
    def embed_batch(params, batch, offset=0):
        if cfg.family == "vlm":
            vis = batch["vis_embeds"].astype(dt)
            txt = tfm.embed_tokens(cfg, params, batch["tokens"])
            x = jnp.concatenate([vis, txt], axis=1)
            pos = batch["pos3"]
        else:
            x = tfm.embed_tokens(cfg, params, batch["tokens"])
            b, s = batch["tokens"].shape
            pos = _lm_positions(b, s, offset)
        return x, pos

    # -- chunked cross-entropy: the full (tokens, vocab) logits tensor is
    # 4+ GB/device fp32 at nemotron/gemma train_4k scale, and its gradient
    # doubles that. Scanning over sequence chunks with remat keeps only one
    # (B, ck, V) tile live; backward recomputes the lm_head matmul per
    # chunk (the classic memory/recompute trade at big-vocab scale).
    def _loss_from_hidden(params, hidden, labels, ck=1024):
        b, s, d = hidden.shape
        ck = min(ck, s)
        pad = (-s) % ck
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        nc = (s + pad) // ck
        hs = hidden.reshape(b, nc, ck, d).swapaxes(0, 1)  # (nc, B, ck, D)
        ls = labels.reshape(b, nc, ck).swapaxes(0, 1)

        def body(carry, xs):
            h, l = xs
            logits = tfm.logits_fn(cfg, params, h)  # (B, ck, V) fp32
            valid = (l >= 0).astype(jnp.float32)
            safe = jnp.maximum(l, 0)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            picked = jnp.take_along_axis(
                logits, safe[..., None], axis=-1
            )[..., 0]
            nll = (lse - picked) * valid
            return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
            (hs, ls),
        )
        return tot / jnp.maximum(cnt, 1.0), cnt

    # -- training loss -------------------------------------------------------
    def train_loss(params, batch, remat=True):
        if cfg.encdec:
            enc_out = tfm.encoder_forward(cfg, params, batch["enc_embeds"].astype(dt),
                                          remat=remat)
            cross = tfm.build_cross_kv(cfg, params, enc_out)
            x = tfm.embed_tokens(cfg, params, batch["tokens"])
            b, s = batch["tokens"].shape
            pos = _lm_positions(b, s)
            hidden, _, aux = tfm.decoder_forward(
                cfg, params, x, pos, cross_kv=cross, remat=remat
            )
        else:
            x, pos = embed_batch(params, batch)
            hidden, _, aux = tfm.decoder_forward(cfg, params, x, pos, remat=remat)
        labels = batch["labels"]
        if cfg.family == "vlm":  # loss only over the text tail
            hidden = hidden[:, -labels.shape[1]:]
        loss, tokens = _loss_from_hidden(params, hidden, labels)
        loss = loss + 0.01 * aux
        return loss, {"loss": loss, "aux": aux, "tokens": tokens}

    # -- caches ---------------------------------------------------------------
    # Cache contract: ``len`` is PER-SLOT, shape (B,). Every batch row is an
    # independent request slot with its own fill position — decode RoPE
    # positions, KV write offsets and attention key masks all come from its
    # row, which is what makes slot-swap continuous batching correct.
    def init_cache(batch_size: int, max_len: int):
        L, d = cfg.n_layers, cfg.d_model
        cache: dict = {"len": jnp.zeros((batch_size,), jnp.int32)}
        if cfg.family == "ssm":
            h, n = ssm_mod.rwkv6_dims(cfg)
            p = n
            cache["wkv"] = jnp.zeros((L, batch_size, h, n, p), jnp.float32)
            cache["shift_t"] = jnp.zeros((L, batch_size, d), dt)
            cache["shift_c"] = jnp.zeros((L, batch_size, d), dt)
            return cache
        if cfg.family == "hybrid":
            di, nh, conv_dim = ssm_mod.mamba2_dims(cfg)
            s = cfg.ssm
            cache["ssm"] = jnp.zeros(
                (L, batch_size, nh, s.d_state, s.head_dim), jnp.float32
            )
            cache["conv"] = jnp.zeros(
                (L, batch_size, s.d_conv - 1, conv_dim), dt
            )
            if cfg.shared_attn_every:
                napps = cfg.n_layers // cfg.shared_attn_every
                cache["shared_kv"] = jnp.zeros(
                    (napps, 2, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt
                )
            return cache
        cache["kv"] = jnp.zeros(
            (L, 2, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt
        )
        return cache

    # Paged cache contract: attention KV lives in a shared pool of
    # ``num_pages`` fixed-size pages per layer; each slot addresses its
    # logical positions through ``page_table`` (B, ceil(max_len/page_size))
    # rows of physical page ids (managed host-side by kvcache.PageAllocator).
    # ``max_len`` becomes a PER-REQUEST logical cap (the table width), not a
    # reservation: memory actually committed per request is its page count.
    # Recurrent leaves (ssm/conv/wkv/shift_*) stay dense — per-slot ``len``
    # masking already makes positional KV the only leaf that scales with
    # sequence length.
    def init_paged_cache(batch_size: int, max_len: int, *, page_size: int,
                         num_pages: int):
        L = cfg.n_layers
        if cfg.family == "ssm":
            raise ValueError(
                f"{cfg.name}: pure-SSM family has no attention KV to page"
            )
        n_pages_row = -(-max_len // page_size)
        cache: dict = {
            "len": jnp.zeros((batch_size,), jnp.int32),
            "page_table": jnp.zeros((batch_size, n_pages_row), jnp.int32),
        }
        if cfg.family == "hybrid":
            di, nh, conv_dim = ssm_mod.mamba2_dims(cfg)
            s = cfg.ssm
            cache["ssm"] = jnp.zeros(
                (L, batch_size, nh, s.d_state, s.head_dim), jnp.float32
            )
            cache["conv"] = jnp.zeros(
                (L, batch_size, s.d_conv - 1, conv_dim), dt
            )
            if cfg.shared_attn_every:
                napps = cfg.n_layers // cfg.shared_attn_every
                cache["shared_pages"] = jnp.zeros(
                    (napps, 2, num_pages, page_size, cfg.n_kv_heads, cfg.hd),
                    dt,
                )
            return cache
        cache["pages"] = jnp.zeros(
            (L, 2, num_pages, page_size, cfg.n_kv_heads, cfg.hd), dt
        )
        return cache

    # -- serving -------------------------------------------------------------
    def prefill(params, batch, cache):
        """Process the full prompt; returns (last-position logits, cache).

        ``batch["lengths"]`` (B,), when present, enables batched in-place
        prefill of right-padded heterogeneous prompts: each row writes only
        its true prefix into the cache (rows with length 0 are untouched —
        they keep serving their live request), ``cache["len"]`` advances
        per row, and the returned logits are taken at each row's own last
        real token.

        Positions are offset by each row's ``cache["len"]``, so CHUNKED
        prefill falls out of the same contract: feeding a prompt in waves
        (rows mid-prompt keep their fill position; the next wave continues
        at it) is position-exact for attention KV, and recurrent state
        simply carries across waves. Fresh rows have ``len == 0`` — whole-
        prompt prefill is the one-wave special case."""
        lengths = batch.get("lengths")
        row_off = cache["len"].astype(jnp.int32)[:, None]
        if cfg.encdec:
            enc_out = tfm.encoder_forward(
                cfg, params, batch["enc_embeds"].astype(dt)
            )
            cross = tfm.build_cross_kv(cfg, params, enc_out)
            x = tfm.embed_tokens(cfg, params, batch["tokens"])
            b, s = batch["tokens"].shape
            pos = _lm_positions(b, s) + row_off
            hidden, cache, _ = tfm.decoder_forward(
                cfg, params, x, pos, cache=cache, cross_kv=cross,
                seq_lens=lengths,
            )
            cache = dict(cache)
            cache["cross_k"], cache["cross_v"] = cross
        else:
            x, pos = embed_batch(params, batch)
            if pos.ndim == 2:  # M-RoPE (vlm) positions come from the batch
                pos = pos + row_off
            hidden, cache, _ = tfm.decoder_forward(
                cfg, params, x, pos, cache=cache, seq_lens=lengths
            )
        if lengths is None:
            hidden = hidden[:, -1:]
        else:
            idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0,
                           hidden.shape[1] - 1)
            hidden = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        logits = tfm.logits_fn(cfg, params, hidden)
        return logits, cache

    def verify_step(params, tokens, lengths, cache):
        """Score a multi-token chunk at EVERY position. tokens: (B, S).

        The speculative-decoding verifier: row ``b`` feeds its
        ``lengths[b]`` drafted tokens as a prefill-style chunk continuing
        at its own ``cache["len"]`` (positions, KV write offsets and
        attention masks all ride the per-row contract that makes chunked
        prefill exact), and the returned logits ``(B, S, V)`` hold the
        target distribution after the context, after draft 1, ... —
        everything acceptance needs from ONE forward. Rows with
        ``lengths == 0`` are frozen (no write, no length advance), same as
        inactive decode slots. Unlike :func:`prefill` there is no slot
        reset and no last-position gather; the caller rewinds
        ``cache["len"]`` past any rejected suffix (``kvcache.rewind``)."""
        if cfg.encdec or cfg.family == "vlm":
            raise NotImplementedError(
                f"{cfg.name}: verify_step covers token-only LM families "
                "(enc-dec / VLM speculative decoding is a follow-on)"
            )
        x = tfm.embed_tokens(cfg, params, tokens)
        b, s = tokens.shape
        pos = _lm_positions(b, s) + cache["len"].astype(jnp.int32)[:, None]
        hidden, new_cache, _ = tfm.decoder_forward(
            cfg, params, x, pos, cache=cache, seq_lens=lengths
        )
        logits = tfm.logits_fn(cfg, params, hidden)
        return logits, new_cache

    def decode_step(params, tokens, cache, pos3=None, active=None):
        """One new token per sequence. tokens: (B, 1).

        ``active`` (B,) bool masks request slots: inactive rows get no KV
        or recurrent-state write and their ``len`` does not advance —
        finished/empty slots ride along in the fixed-shape batch without
        corrupting the cache."""
        x = tfm.embed_tokens(cfg, params, tokens)
        b = tokens.shape[0]
        if cfg.family == "vlm":
            pos = pos3 if pos3 is not None else jnp.broadcast_to(
                cache["len"].astype(jnp.int32)[:, None, None], (b, 1, 3)
            )
        else:
            pos = jnp.broadcast_to(cache["len"][:, None], (b, 1)).astype(
                jnp.int32
            )
        cross = None
        if cfg.encdec:
            cross = (cache["cross_k"], cache["cross_v"])
            dec_cache = {k: v for k, v in cache.items()
                         if k not in ("cross_k", "cross_v")}
        else:
            dec_cache = cache
        seq_lens = None if active is None else active.astype(jnp.int32)
        hidden, new_cache, _ = tfm.decoder_forward(
            cfg, params, x, pos, cache=dec_cache, cross_kv=cross,
            seq_lens=seq_lens,
        )
        if cfg.encdec:
            new_cache = dict(new_cache)
            new_cache["cross_k"], new_cache["cross_v"] = cross
        logits = tfm.logits_fn(cfg, params, hidden)
        return logits, new_cache

    # -- specs (dry-run) ------------------------------------------------------
    def input_specs(shape: ShapeConfig) -> dict:
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if cfg.encdec:
            if shape.kind == "train" or shape.kind == "prefill":
                return {
                    "enc_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "tokens": jax.ShapeDtypeStruct((b, min(s, 448)), i32),
                    "labels": jax.ShapeDtypeStruct((b, min(s, 448)), i32),
                }
            return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.family == "vlm":
            s_vis = s // VLM_VIS_FRACTION
            s_txt = s - s_vis
            if shape.kind in ("train", "prefill"):
                d: dict = {
                    "vis_embeds": jax.ShapeDtypeStruct((b, s_vis, cfg.d_model), dt),
                    "tokens": jax.ShapeDtypeStruct((b, s_txt), i32),
                    "pos3": jax.ShapeDtypeStruct((b, s, 3), i32),
                }
                if shape.kind == "train":
                    d["labels"] = jax.ShapeDtypeStruct((b, s_txt), i32)
                return d
            return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if shape.kind in ("train", "prefill"):
            d = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if shape.kind == "train":
                d["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            return d
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    def cache_specs(shape: ShapeConfig):
        spec = jax.eval_shape(
            lambda: init_cache(shape.global_batch, shape.seq_len)
        )
        if cfg.encdec:
            b = shape.global_batch
            kv = jax.ShapeDtypeStruct(
                (cfg.n_layers, b, WHISPER_ENC_LEN, cfg.n_kv_heads, cfg.hd), dt
            )
            spec = dict(spec)
            spec["cross_k"] = kv
            spec["cross_v"] = kv
        return spec

    return Model(
        cfg=cfg, init=lambda rng: tfm.init_params(rng, cfg),
        train_loss=train_loss, prefill=prefill, decode_step=decode_step,
        verify_step=verify_step,
        init_cache=init_cache, init_paged_cache=init_paged_cache,
        input_specs=input_specs, cache_specs=cache_specs,
    )
