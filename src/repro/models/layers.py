"""Shared model layers: norms, RoPE / M-RoPE, MLP variants.

Pure functions over explicit param dicts (pytrees of arrays). Initializers
return the same tree structure so ``jax.eval_shape`` gives the abstract
trees for the dry-run with no allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.qmm import gate_up_proj, qdot


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    # The mean-square reduction runs in fp32 (fuses into the reduce — no
    # full fp32 copy of x is materialized); the normalized output stays in
    # the compute dtype. Keeping x itself out of fp32 avoids XLA pinning a
    # 2x-sized residual-stream buffer per layer (3 GiB/device at
    # nemotron train_4k scale).
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    rs = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * rs * (1.0 + scale).astype(x.dtype)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "sqrelu":  # nemotron-4
        r = jax.nn.relu(x)
        return r * r
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_inv_freq(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); pos: (B, S) int32."""
    hd = x.shape[-1]
    inv = rope_inv_freq(hd, theta)  # (hd//2,)
    ang = pos.astype(jnp.float32)[..., None] * inv  # (B, S, hd//2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, pos3: jax.Array, theta: float, sections: tuple[int, int, int]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. x: (B, S, H, hd); pos3: (B, S, 3) (t, h, w).

    The hd//2 rotary frequencies are partitioned into 3 contiguous groups
    (ratio ``sections``); group g rotates by pos3[..., g]. For text tokens
    (t == h == w) this reduces exactly to standard RoPE — tested.
    """
    hd = x.shape[-1]
    half = hd // 2
    tot = sum(sections)
    n_t = half * sections[0] // tot
    n_h = half * sections[1] // tot
    n_w = half - n_t - n_h
    inv = rope_inv_freq(hd, theta)  # (half,)
    group = jnp.concatenate(
        [jnp.zeros(n_t, jnp.int32), jnp.ones(n_h, jnp.int32),
         jnp.full((n_w,), 2, jnp.int32)]
    )  # (half,) -> which of (t, h, w) drives this freq
    p = jnp.take_along_axis(
        pos3.astype(jnp.float32),  # (B, S, 3)
        jnp.broadcast_to(group[None, None, :], pos3.shape[:2] + (half,)).astype(
            jnp.int32
        ),
        axis=-1,
    )  # (B, S, half)
    ang = p * inv  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, d_model: int, d_ff: int, glu: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out,
    }
    if glu:
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


def mlp(p: dict, x: jax.Array, act: str, glu: bool) -> jax.Array:
    if glu:
        gate, up = gate_up_proj(p, x)  # one fused launch when quantized
        h = activation(gate, act) * up
    else:
        h = activation(qdot(x, p["w_up"]), act)
    return qdot(h, p["w_down"])


def init_sinusoid(max_len: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal positions for the (stub) encoder."""
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000 ** (jnp.arange(0, d_model, 2, dtype=jnp.float32) / d_model))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
