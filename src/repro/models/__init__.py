"""Architecture zoo: layers, attention, MoE, SSM, assembly, public Model API."""
from repro.models.model import Model, build_model  # noqa: F401
