"""Architecture zoo: layers, attention, MoE, SSM, assembly, public Model API."""
from repro.models.model import Model, build_model, reset_slots  # noqa: F401
