"""State-space / linear-attention blocks: Mamba2 (SSD) and RWKV6 (Finch).

Both use the same chunked-scan strategy: the sequence is cut into chunks of
``Q``; all within-chunk (quadratic in Q) terms are computed with pairwise
log-decay differences — every exponent is a *difference* ``L_i - L_j`` with
``j <= i`` and log-decays are negative, so exponents are always <= 0 and the
math is overflow-free without clamping tricks. Cross-chunk terms ride a
``lax.scan`` carry (the recurrent state), giving O(S·Q) memory instead of
O(S^2) while staying fully parallel within chunks (MXU-friendly einsums).

Decode is the exact single-step recurrence on the carried state — O(1) in
context length, which is why these archs keep the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.qmm import qdot
from repro.models.layers import activation, rms_norm

# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def init_mamba2(rng, cfg, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di, nh, conv_dim = mamba2_dims(cfg)
    ks = jax.random.split(rng, 6)
    in_dim = 2 * di + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": jax.random.normal(ks[0], (d, in_dim), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (s.d_conv, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * di ** -0.5,
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None,
                 seq_lens: jax.Array | None = None):
    """Depthwise causal conv along S. x: (B, S, C); w: (K, C).

    Returns (y, new_state) where state is the trailing K-1 inputs. With
    per-row ``seq_lens`` the carried window ends at each row's own last
    valid token (``seq_lens == 0`` passes the old state through), so
    right-padded batched prefill leaves the decode state exact."""
    kk = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(kk)) + b
    if seq_lens is None:
        new_state = xp[:, -(kk - 1):, :]
    else:
        # x[j] lives at xp[K-1 + j]: the window ending at x[len-1] starts
        # at xp[len]; len == 0 selects xp[0:K-1] == the incoming state
        new_state = jax.vmap(
            lambda row, l: jax.lax.dynamic_slice(
                row, (l, 0), (kk - 1, row.shape[1]))
        )(xp, seq_lens.astype(jnp.int32))
    return y, new_state


def _ssd_chunked(u, dA, Bm, Cm, chunk, init_state=None):
    """Chunked SSD scan.

    u:  (B, S, H, P) inputs (already dt-scaled)
    dA: (B, S, H) log-decays (<= 0)
    Bm, Cm: (B, S, G, N)
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    b, s, h, p = u.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # decay-neutral padding: dA=0 (decay 1), B/u zero -> state intact
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // q
    rep = h // g

    def r4(t):  # (B,S,...) -> (B,nc,Q,...)
        return t.reshape(b, nc, q, *t.shape[2:])

    u_, dA_, B_, C_ = r4(u), r4(dA.astype(jnp.float32)), r4(Bm), r4(Cm)
    L = jnp.cumsum(dA_, axis=2)  # (B,nc,Q,H) within-chunk cumulative log decay

    # intra-chunk: scores_ij = (C_i . B_j) * exp(L_i - L_j), j <= i
    cb = jnp.einsum("bcign,bcjgn->bcijg", C_.astype(jnp.float32),
                    B_.astype(jnp.float32))
    cb = jnp.repeat(cb, rep, axis=-1)  # (B,nc,Q,Q,H)
    diff = L[:, :, :, None, :] - L[:, :, None, :, :]  # (B,nc,Q,Q,H) i-j
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # mask the exponent BEFORE exp: exp(+big) in the dead triangle would be
    # inf, and `where(mask, inf*0, 0)` poisons the backward pass with NaNs.
    scores = cb * jnp.exp(jnp.where(mask, diff, -jnp.inf))
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, u_.astype(jnp.float32))

    # chunk summary state: sum_j exp(L_last - L_j) B_j u_j^T  -> (B,nc,H,N,P)
    to_end = jnp.exp(L[:, :, -1:, :] - L)  # (B,nc,Q,H)
    chunk_state = jnp.einsum(
        "bcqh,bcqgn,bcqhp->bchnp",
        to_end,
        B_.astype(jnp.float32),
        u_.astype(jnp.float32),
    ) if g == 1 else jnp.einsum(
        "bcqh,bcqhn,bcqhp->bchnp",
        to_end,
        jnp.repeat(B_.astype(jnp.float32), rep, axis=3),
        u_.astype(jnp.float32),
    )
    chunk_decay = jnp.exp(L[:, :, -1, :])  # (B,nc,H) total chunk decay

    def body(state, inp):
        cs, cd, c_c, l_c = inp  # per-chunk tensors (leading axis nc scanned)
        # inter contribution uses the INCOMING state
        if g == 1:
            y_int = jnp.einsum(
                "bqgn,bqh,bhnp->bqhp", c_c.astype(jnp.float32),
                jnp.exp(l_c), state,
            )
        else:
            y_int = jnp.einsum(
                "bqhn,bqh,bhnp->bqhp",
                jnp.repeat(c_c.astype(jnp.float32), rep, axis=2),
                jnp.exp(l_c), state,
            )
        new_state = state * cd[:, :, None, None] + cs
        return new_state, y_int

    state0 = (
        jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
        else init_state.astype(jnp.float32)
    )
    xs = (
        chunk_state.swapaxes(0, 1),          # (nc,B,H,N,P)
        chunk_decay.swapaxes(0, 1),          # (nc,B,H)
        C_.swapaxes(0, 1),                   # (nc,B,Q,G,N)
        L.swapaxes(0, 1),                    # (nc,B,Q,H)
    )
    final_state, y_inter = jax.lax.scan(jax.checkpoint(body), state0, xs)
    y = y_intra + y_inter.swapaxes(0, 1)
    return y.reshape(b, s, h, p)[:, :s_orig], final_state


def _mamba2_pre(p, cfg, x, conv_state=None, seq_lens=None):
    """in_proj + conv + splits shared by train and decode paths."""
    s = cfg.ssm
    di, nh, conv_dim = mamba2_dims(cfg)
    zxbcdt = qdot(x, p["in_proj"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + conv_dim]
    dt = zxbcdt[..., di + conv_dim :]  # (B,S,H)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state,
                                 seq_lens)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di]
    bm = xbc[..., di : di + s.n_groups * s.d_state]
    cm = xbc[..., di + s.n_groups * s.d_state :]
    b, sl = x.shape[:2]
    bm = bm.reshape(b, sl, s.n_groups, s.d_state)
    cm = cm.reshape(b, sl, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    da = dt * a  # log decay <= 0
    u = xs.reshape(b, sl, nh, s.head_dim)
    return z, u, dt, da, bm, cm, new_conv


def mamba2_block(p, cfg, x, cache=None, seq_lens=None):
    """x: (B,S,D). cache: None (train/prefill from scratch) or dict with
    "ssm" (B,H,N,P) and "conv" (B,K-1,conv_dim). Returns (y, new_cache).

    ``seq_lens`` (B,) marks each row's valid prefix: pad positions get
    decay-neutral inputs (dA=0, u=0) so the carried SSD state is exactly
    the state after the row's last real token."""
    s = cfg.ssm
    di, nh, _ = mamba2_dims(cfg)
    conv_state = cache["conv"] if cache is not None else None
    z, u, dt, da, bm, cm, new_conv = _mamba2_pre(p, cfg, x, conv_state,
                                                 seq_lens)
    if seq_lens is not None:
        valid = (jnp.arange(x.shape[1])[None] <
                 seq_lens[:, None]).astype(jnp.float32)  # (B,S)
        u = u * valid[..., None, None].astype(u.dtype)
        da = da * valid[..., None]
    init_state = cache["ssm"] if cache is not None else None
    y, st = _ssd_chunked(u * dt[..., None], da, bm, cm, s.chunk, init_state)
    y = y + p["d_skip"][:, None] * u
    b, sl = x.shape[:2]
    y = y.reshape(b, sl, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = qdot(y, p["out_proj"])
    new_cache = {"ssm": st, "conv": new_conv} if cache is not None else None
    return out, new_cache


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------


def rwkv6_dims(cfg):
    n = cfg.ssm.head_dim
    h = cfg.d_model // n
    return h, n


def init_rwkv6(rng, cfg, dtype) -> dict:
    d = cfg.d_model
    h, n = rwkv6_dims(cfg)
    lora = 64
    ks = jax.random.split(rng, 10)
    s = d ** -0.5
    return {
        "time_mix_r": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_k": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_v": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_g": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_w": jnp.full((d,), 0.5, jnp.float32),
        "time_decay_base": jnp.full((d,), -2.0, jnp.float32),
        "time_decay_w1": jax.random.normal(ks[0], (d, lora), jnp.float32) * s,
        "time_decay_w2": jax.random.normal(ks[1], (lora, d), jnp.float32) * lora ** -0.5,
        "time_bonus_u": jnp.zeros((h, n), jnp.float32),
        "wr": jax.random.normal(ks[2], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[3], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[4], (d, d), dtype) * s,
        "wg": jax.random.normal(ks[5], (d, d), dtype) * s,
        "wo": jax.random.normal(ks[6], (d, d), dtype) * s,
        "ln_x_scale": jnp.zeros((d,), jnp.float32),
        # channel mix
        "time_mix_ck": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_cr": jnp.full((d,), 0.5, jnp.float32),
        "cm_wk": jax.random.normal(ks[7], (d, cfg.d_ff), dtype) * s,
        "cm_wv": jax.random.normal(ks[8], (cfg.d_ff, d), dtype) * cfg.d_ff ** -0.5,
        "cm_wr": jax.random.normal(ks[9], (d, d), dtype) * s,
    }


def _token_shift(x: jax.Array, last: jax.Array | None):
    """(B,S,D) -> previous-token tensor; `last` is the carry for decode."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    return prev


def _wkv_chunked(r, k, v, lw, u, chunk, init_state=None):
    """RWKV6 linear attention, chunked.

    r,k: (B,S,H,N); v: (B,S,H,P); lw: (B,S,H,N) per-channel log-decay (<=0)
    u: (H,N) current-token bonus. Returns (y (B,S,H,P), state (B,H,N,P)).

    Recurrence: y_t = r_t·(S_{t-1} + u ⊙ k_t v_t^T);  S_t = w_t ⊙ S_{t-1} + k_t v_t^T.
    """
    b, s, h, n = k.shape
    p = v.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # decay-neutral padding (lw=0, k=v=0): state passes through
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // q

    def r4(t):
        return t.reshape(b, nc, q, *t.shape[2:]).swapaxes(0, 1)  # (nc,B,Q,...)

    rs, ks_, vs, lws = r4(r.astype(jnp.float32)), r4(k.astype(jnp.float32)), \
        r4(v.astype(jnp.float32)), r4(lw.astype(jnp.float32))

    state0 = (
        jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
        else init_state.astype(jnp.float32)
    )
    tri_lower = jnp.tril(jnp.ones((q, q), bool), k=-1)  # strictly lower: j < i

    def body(state, inp):
        # This whole chunk body is the hand-written GLA Pallas kernel
        # (kernels/wkv.py, interpret-validated); under flash_fusion() the
        # roofline charges only its boundary traffic — the (B,Q,Q,H,N)
        # pairwise tensor lives in VMEM on TPU, never in HBM.
        from repro.models.attention import _flash_scope

        rc, kc, vc, lwc = inp  # (B,Q,H,N/P)
        with _flash_scope():
            lcum = jnp.cumsum(lwc, axis=1)  # (B,Q,H,N) L_t
            lprev = lcum - lwc              # L_{t-1} (decay before read)
            # intra: scores_ij = sum_n r_in k_jn exp(Lprev_i - L_j), j < i
            diff = lprev[:, :, None] - lcum[:, None, :]  # (B,Q,Q,H,N) i,j
            # exponent masked BEFORE exp (see _ssd_chunked for why)
            e = jnp.exp(
                jnp.where(tri_lower[None, :, :, None, None], diff, -jnp.inf)
            )
            scores = jnp.einsum("bihn,bjhn,bijhn->bijh", rc, kc, e)
            y = jnp.einsum("bijh,bjhp->bihp", scores, vc)
            # current-token bonus (diagonal)
            y += jnp.einsum("bihn,bihp->bihp",
                            rc * kc * u[None, None], vc)
            # inter: r_i exp(Lprev_i) · state
            y += jnp.einsum("bihn,bhnp->bihp", rc * jnp.exp(lprev), state)
            # state: S' = exp(L_Q) ⊙ S + sum_j exp(L_Q - L_j) k_j v_j^T
            to_end = jnp.exp(lcum[:, -1:, :] - lcum)  # (B,Q,H,N)
            new_state = state * jnp.exp(lcum[:, -1])[..., None] + jnp.einsum(
                "bjhn,bjhp->bhnp", kc * to_end, vc
            )
        return new_state, y

    # remat: backward recomputes the in-VMEM pairwise terms (the Pallas
    # kernel's custom-vjp does the same on TPU) instead of saving a
    # (nc, B, Q, Q, H, N) stack to HBM
    final_state, ys = jax.lax.scan(
        jax.checkpoint(body), state0, (rs, ks_, vs, lws)
    )
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return y[:, :s_orig], final_state


def _last_valid(x: jax.Array, old: jax.Array | None,
                seq_lens: jax.Array | None):
    """Token-shift carry: x at each row's last valid position; rows with
    ``seq_lens == 0`` keep the previous carry."""
    if seq_lens is None:
        return x[:, -1, :]
    idx = jnp.clip(seq_lens.astype(jnp.int32) - 1, 0, x.shape[1] - 1)
    picked = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    if old is None:
        return picked
    return jnp.where((seq_lens > 0)[:, None], picked, old.astype(x.dtype))


def rwkv6_time_mix(p, cfg, x, cache=None, seq_lens=None):
    """x: (B,S,D); cache: None or {"wkv": (B,H,N,P), "shift_t": (B,D)}.

    ``seq_lens`` (B,): pad positions are decay-neutral (lw=0, k=0) so the
    carried WKV state stops at each row's last real token."""
    h, n = rwkv6_dims(cfg)
    b, s, d = x.shape
    last = cache["shift_t"] if cache is not None else None
    prev = _token_shift(x, last)

    def mix(mu):
        return x + (prev - x) * mu.astype(x.dtype)

    r = (mix(p["time_mix_r"]) @ p["wr"]).reshape(b, s, h, n)
    k = (mix(p["time_mix_k"]) @ p["wk"]).reshape(b, s, h, n)
    v = (mix(p["time_mix_v"]) @ p["wv"]).reshape(b, s, h, n)
    g = mix(p["time_mix_g"]) @ p["wg"]
    # data-dependent decay (the Finch signature): per-channel, per-token
    xw = mix(p["time_mix_w"]).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["time_decay_w1"]) @ p["time_decay_w2"]
    lw = -jnp.exp(p["time_decay_base"] + dd)  # (B,S,D) log-decay <= 0
    lw = jnp.clip(lw, -20.0, -1e-6).reshape(b, s, h, n)
    if seq_lens is not None:
        valid = (jnp.arange(s)[None] < seq_lens[:, None])[..., None, None]
        k = k * valid.astype(k.dtype)
        lw = jnp.where(valid, lw, 0.0)

    init = cache["wkv"] if cache is not None else None
    y, st = _wkv_chunked(r, k, v, lw, p["time_bonus_u"], cfg.ssm.chunk, init)
    y = y.reshape(b, s, d)
    # per-head group norm
    yh = y.reshape(b, s, h, n)
    yh = rms_norm(yh, jnp.zeros((n,), jnp.float32), cfg.norm_eps)
    y = yh.reshape(b, s, d) * (1.0 + p["ln_x_scale"].astype(jnp.float32))
    y = (y.astype(x.dtype) * jax.nn.silu(g)) @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"wkv": st, "shift_t": _last_valid(x, last, seq_lens)}
    return y, new_cache


def rwkv6_channel_mix(p, cfg, x, cache=None, seq_lens=None):
    last = cache["shift_c"] if cache is not None else None
    prev = _token_shift(x, last)
    xk = x + (prev - x) * p["time_mix_ck"].astype(x.dtype)
    xr = x + (prev - x) * p["time_mix_cr"].astype(x.dtype)
    kk = jax.nn.relu(xk @ p["cm_wk"])
    kk = kk * kk
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])
    new_cache = None
    if cache is not None:
        new_cache = {"shift_c": _last_valid(x, last, seq_lens)}
    return out, new_cache
