"""Model assembly: decoder-only LMs (dense / MoE / SSM / hybrid / VLM) and
the Whisper-style encoder-decoder, all as ``lax.scan`` over stacked layer
params.

Scan-over-layers keeps the HLO O(1) in depth (critical for the 48-layer
full-scale dry-run compiles) and gives the checkpoint/remat boundary; layer
heterogeneity (gemma3 local:global, llama4 chunked:global, zamba2 shared
block cadence) is expressed as *data* scanned alongside the params
(per-layer window/chunk scalars, layer indices), so one compiled body
serves every layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.qmm import kv_proj, qdot
from repro.models import ssm as ssm_mod
from repro.models.attention import attention_block, init_attention
from repro.models.layers import init_mlp, init_sinusoid, mlp, rms_norm
from repro.models.moe import init_moe, moe_block
from repro.runtime.sharding import act_constraint


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Parameter init (single layer -> vmapped stack)
# ---------------------------------------------------------------------------


def _init_block(rng, cfg):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    if cfg.family == "ssm":  # rwkv6
        return {
            "norm1_scale": jnp.zeros((d,), dt),
            "tmix": ssm_mod.init_rwkv6(ks[0], cfg, dt),
            "norm2_scale": jnp.zeros((d,), dt),
        }
    if cfg.family == "hybrid":  # zamba2 mamba backbone
        return {
            "norm1_scale": jnp.zeros((d,), dt),
            "mamba": ssm_mod.init_mamba2(ks[0], cfg, dt),
        }
    p = {
        "norm1_scale": jnp.zeros((d,), dt),
        "attn": init_attention(ks[0], cfg, dt),
        "norm2_scale": jnp.zeros((d,), dt),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(ks[1], cfg, dt)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.glu, dt)
    if cfg.encdec:
        p["norm_cross_scale"] = jnp.zeros((d,), dt)
        p["cross_attn"] = init_attention(ks[2], cfg, dt)
    return p


def _init_enc_block(rng, cfg):
    dt = _dtype(cfg)
    d = cfg.d_model
    k1, k2 = jax.random.split(rng)
    return {
        "norm1_scale": jnp.zeros((d,), dt),
        "attn": init_attention(k1, cfg, dt),
        "norm2_scale": jnp.zeros((d,), dt),
        "mlp": init_mlp(k2, d, cfg.d_ff, cfg.glu, dt),
    }


def init_params(rng, cfg) -> dict:
    dt = _dtype(cfg)
    d, v = cfg.d_model, cfg.vocab_size
    k_embed, k_layers, k_extra, k_head, k_enc = jax.random.split(rng, 5)
    params: dict = {
        "embed": {"table": jax.random.normal(k_embed, (v, d), dt) * d ** -0.5},
        "layers": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(k_layers, cfg.n_layers)
        ),
        "final_norm_scale": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": jax.random.normal(k_head, (d, v), dt) * d ** -0.5
        }
    if cfg.shared_attn_every:  # zamba2 shared transformer block
        ka, km = jax.random.split(k_extra)
        params["shared_attn"] = {
            "norm1_scale": jnp.zeros((d,), dt),
            "attn": init_attention(ka, cfg, dt),
            "norm2_scale": jnp.zeros((d,), dt),
            "mlp": init_mlp(km, d, cfg.d_ff, cfg.glu, dt),
        }
    if cfg.encdec:
        params["enc"] = {
            "layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(
                jax.random.split(k_enc, cfg.n_enc_layers)
            ),
            "final_norm_scale": jnp.zeros((d,), dt),
        }
    return params


# ---------------------------------------------------------------------------
# Layer metadata scanned alongside params
# ---------------------------------------------------------------------------


def layer_meta(cfg):
    kinds = cfg.layer_kinds()
    windows = jnp.array(
        [cfg.window if k == "local" else 0 for k in kinds], jnp.int32
    )
    chunks = jnp.array(
        [cfg.attn_chunk if k == "chunked" else 0 for k in kinds], jnp.int32
    )
    return windows, chunks


# ---------------------------------------------------------------------------
# Shared zamba2 block
# ---------------------------------------------------------------------------


def _shared_block(sp, cfg, x, pos, kv_slot=None, cache_len=None,
                  seq_lens=None, page_table=None, paged=False):
    h, new_kv = attention_block(
        sp["attn"], cfg, rms_norm(x, sp["norm1_scale"], cfg.norm_eps), pos,
        kv_cache=None if paged else kv_slot,
        kv_pages=kv_slot if paged else None,
        page_table=page_table,
        cache_len=cache_len, seq_lens=seq_lens,
    )
    x = x + h
    x = x + mlp(sp["mlp"], rms_norm(x, sp["norm2_scale"], cfg.norm_eps),
                cfg.act, cfg.glu)
    return x, new_kv


# ---------------------------------------------------------------------------
# Decoder stack forward (training/prefill: cache optional; decode: S==1)
# ---------------------------------------------------------------------------


def decoder_forward(
    cfg,
    params,
    x: jax.Array,        # (B, S, D) embedded inputs
    pos: jax.Array,      # (B, S) or (B, S, 3)
    cache: dict | None = None,
    cross_kv: tuple | None = None,   # whisper decoder: (Ldec,B,Senc,KV,hd) x2
    remat: bool = False,
    remat_group: int = 0,
    seq_lens: jax.Array | None = None,  # (B,) valid new tokens per row
) -> tuple[jax.Array, dict | None, jax.Array]:
    """Returns (hidden (B,S,D), new_cache, aux_loss).

    ``cache["len"]`` is per-row ``(B,)``: each batch slot advances by its
    own ``seq_lens`` entry (default: the full input length S), so
    heterogeneous requests can share one cache without corrupting each
    other's positions. Rows with ``seq_lens == 0`` are frozen: no KV/state
    write, no length advance — the decode-time inactive-slot mask.

    Paged cache contract: when the cache carries ``pages`` (attention
    families) or ``shared_pages`` (zamba2 shared block) plus a
    ``page_table``, attention KV lives in a shared page pool addressed per
    row through the table; recurrent leaves (ssm/conv/wkv/shift) stay
    dense — only positional KV benefits from paging.
    """
    if not remat_group:
        remat_group = getattr(cfg, "remat_group", 1)
    windows, chunks = layer_meta(cfg)
    layers = params["layers"]
    n_layers = cfg.n_layers
    cache_len = cache["len"] if cache is not None else None
    page_table = cache.get("page_table") if cache is not None else None
    every = cfg.shared_attn_every

    def block(x, layer_params, window, chunk, layer_cache, layer_cross, idx,
              shared_kv):
        aux = jnp.float32(0.0)
        new_cache = layer_cache
        if cfg.family == "ssm":
            h, c1 = ssm_mod.rwkv6_time_mix(
                layer_params["tmix"],
                cfg,
                rms_norm(x, layer_params["norm1_scale"], cfg.norm_eps),
                layer_cache,
                seq_lens=seq_lens,
            )
            x = x + h
            h, c2 = ssm_mod.rwkv6_channel_mix(
                layer_params["tmix"],
                cfg,
                rms_norm(x, layer_params["norm2_scale"], cfg.norm_eps),
                layer_cache,
                seq_lens=seq_lens,
            )
            x = x + h
            if layer_cache is not None:
                new_cache = {**c1, **c2}
        elif cfg.family == "hybrid":
            h, c1 = ssm_mod.mamba2_block(
                layer_params["mamba"],
                cfg,
                rms_norm(x, layer_params["norm1_scale"], cfg.norm_eps),
                layer_cache,
                seq_lens=seq_lens,
            )
            x = x + h
            if layer_cache is not None:
                new_cache = {**layer_cache, **c1}
            if every:
                slot = idx // every

                def apply_shared(operands):
                    xx, skv = operands
                    if skv is None:
                        y, _ = _shared_block(params["shared_attn"], cfg, xx, pos)
                        return y, skv
                    kv_slot = jax.lax.dynamic_index_in_dim(
                        skv, slot, keepdims=False
                    )
                    y, new_slot = _shared_block(
                        params["shared_attn"], cfg, xx, pos, kv_slot,
                        cache_len, seq_lens, page_table, shared_paged,
                    )
                    skv = jax.lax.dynamic_update_index_in_dim(
                        skv, new_slot.astype(skv.dtype), slot, 0
                    )
                    return y, skv

                def skip(operands):
                    return operands

                x, shared_kv = jax.lax.cond(
                    (idx + 1) % every == 0, apply_shared, skip, (x, shared_kv)
                )
        else:  # attention families
            kv = pages = None
            if layer_cache is not None:
                kv = layer_cache.get("kv")
                pages = layer_cache.get("pages")
            h, new_kv = attention_block(
                layer_params["attn"], cfg,
                rms_norm(x, layer_params["norm1_scale"], cfg.norm_eps), pos,
                layer_window=window, layer_chunk=chunk,
                kv_cache=kv, kv_pages=pages, page_table=page_table,
                cache_len=cache_len, seq_lens=seq_lens,
            )
            x = x + h
            if layer_cross is not None:
                h, _ = attention_block(
                    layer_params["cross_attn"], cfg,
                    rms_norm(x, layer_params["norm_cross_scale"], cfg.norm_eps),
                    pos, cross_kv=layer_cross,
                )
                x = x + h
            h2 = rms_norm(x, layer_params["norm2_scale"], cfg.norm_eps)
            if cfg.moe is not None:
                h, aux = moe_block(layer_params["moe"], cfg, h2)
            else:
                h = mlp(layer_params["mlp"], h2, cfg.act, cfg.glu)
            x = x + h
            if layer_cache is not None:
                new_cache = ({"pages": new_kv} if pages is not None
                             else {"kv": new_kv})
        return x, new_cache, aux, shared_kv

    idxs = jnp.arange(n_layers, dtype=jnp.int32)
    per_layer_cache = None
    shared_kv0 = None
    shared_paged = cache is not None and "shared_pages" in cache
    if cache is not None:
        per_layer_cache = {
            k: v for k, v in cache.items()
            if k not in ("len", "shared_kv", "shared_pages", "page_table")
        }
        shared_kv0 = (cache["shared_pages"] if shared_paged
                      else cache.get("shared_kv"))
    cross = None
    if cross_kv is not None:
        cross = cross_kv  # (k, v) each (L, B, Senc, KV, hd)

    g = remat_group if (remat and cache is None
                        and n_layers % max(remat_group, 1) == 0) else 1

    if g <= 1:
        blk = jax.checkpoint(block) if remat else block

        def body(carry, scanned):
            x, aux_tot, shared_kv = carry
            layer_params, window, chunk, layer_cache, layer_cross, idx = scanned
            x, new_cache, aux, shared_kv = blk(
                x, layer_params, window, chunk, layer_cache, layer_cross,
                idx, shared_kv,
            )
            # SP: the scan-carried residual stream is the remat save point —
            # sequence-sharding it over `model` divides saved-activation
            # memory by the TP degree (no-op outside a mesh context).
            x = act_constraint(x, "residual")
            return (x, aux_tot + aux, shared_kv), new_cache

        (x, aux_tot, shared_kv), new_layer_cache = jax.lax.scan(
            body, (x, jnp.float32(0.0), shared_kv0),
            (layers, windows, chunks, per_layer_cache, cross, idxs),
        )
    else:
        # grouped activation checkpointing: save the residual every g
        # layers, recompute the inner g-1 in backward — stack memory /g
        # for ~(g-1)/g extra forward FLOPs in the backward pass.
        def rg(t):
            return jax.tree.map(
                lambda a: a.reshape(n_layers // g, g, *a.shape[1:]), t
            )

        def body(carry, scanned):
            x, aux_tot, shared_kv = carry
            lp, w, c, lcross, idx = scanned

            def group(x, shared_kv):
                aux_g = jnp.float32(0.0)
                for i in range(g):
                    lpi = jax.tree.map(lambda a: a[i], lp)
                    lci = None
                    if lcross is not None:
                        lci = jax.tree.map(lambda a: a[i], lcross)
                    x, _, aux, shared_kv = block(
                        x, lpi, w[i], c[i], None, lci, idx[i], shared_kv
                    )
                return x, aux_g + aux, shared_kv

            x, aux_g, shared_kv = jax.checkpoint(group)(x, shared_kv)
            x = act_constraint(x, "residual")
            return (x, aux_tot + aux_g, shared_kv), None

        (x, aux_tot, shared_kv), new_layer_cache = jax.lax.scan(
            body, (x, jnp.float32(0.0), shared_kv0),
            (rg(layers), windows.reshape(-1, g), chunks.reshape(-1, g),
             rg(cross), idxs.reshape(-1, g)),
        )

    new_cache = None
    if cache is not None:
        new_cache = dict(new_layer_cache)
        if seq_lens is not None:
            inc = seq_lens.astype(jnp.int32)
        else:
            inc = pos.shape[1] if pos.ndim >= 2 else 1
        new_cache["len"] = cache["len"] + inc
        if shared_kv is not None:
            new_cache["shared_pages" if shared_paged else "shared_kv"] = (
                shared_kv
            )
        if page_table is not None:
            new_cache["page_table"] = page_table
    return x, new_cache, aux_tot


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------


def encoder_forward(cfg, params, embeds: jax.Array, remat: bool = False):
    """embeds: (B, S_enc, D) stub frame embeddings -> (B, S_enc, D)."""
    b, s, d = embeds.shape
    x = embeds + init_sinusoid(s, d)[None].astype(embeds.dtype)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    # Bidirectional attention needs causal=False; attention_block is causal
    # for self-attn, so encode via the cross-attention path against itself.
    def enc_block(x, lp):
        xn = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
        kvh, hd = cfg.n_kv_heads, cfg.hd
        k2, v2 = kv_proj(lp["attn"], xn)
        k = k2.reshape(b, s, kvh, hd)
        v = v2.reshape(b, s, kvh, hd)
        h, _ = attention_block(lp["attn"], cfg, xn, pos, cross_kv=(k, v))
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(x, lp["norm2_scale"], cfg.norm_eps),
                    cfg.act, cfg.glu)
        return x

    if remat:
        enc_block = jax.checkpoint(enc_block)

    def body(x, lp):
        return enc_block(x, lp), None

    x, _ = jax.lax.scan(body, x, params["enc"]["layers"])
    return rms_norm(x, params["enc"]["final_norm_scale"], cfg.norm_eps)


def build_cross_kv(cfg, params, enc_out: jax.Array):
    """Per-decoder-layer cross K/V from encoder output (cached at prefill)."""
    b, s, _ = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.hd

    def per_layer(lp):
        k2, v2 = kv_proj(lp["cross_attn"], enc_out)
        k = k2.reshape(b, s, kvh, hd)
        v = v2.reshape(b, s, kvh, hd)
        return k, v

    return jax.vmap(per_layer)(params["layers"])  # (L,B,S,KV,hd) x2


# ---------------------------------------------------------------------------
# Heads
# ---------------------------------------------------------------------------


def logits_fn(cfg, params, hidden: jax.Array) -> jax.Array:
    h = rms_norm(hidden, params["final_norm_scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = (h @ params["embed"]["table"].T).astype(jnp.float32)
    else:
        logits = qdot(h, params["lm_head"]["w"]).astype(jnp.float32)
    return act_constraint(logits, "logits")


def embed_tokens(cfg, params, tokens: jax.Array) -> jax.Array:
    """Embedding lookup as a chunked one-hot matmul.

    A plain gather from a vocab-sharded table makes GSPMD replicate the
    table (and, in backward, a full fp32 scatter buffer — 4×5.9 GiB at
    nemotron scale). one_hot @ table is a dot: vocab-sharded, reduce-
    scatter backward, no replication. Seq-chunked so the one-hot tile
    stays ~256 MB/device."""
    table = params["embed"]["table"]
    v, d = table.shape
    b, s = tokens.shape
    if s <= 8:  # decode: tiny one-hot, no chunking machinery
        oh = jax.nn.one_hot(tokens, v, dtype=table.dtype)
        return oh @ table
    ck = 512 if s % 512 == 0 else s
    nc = s // ck
    tks = tokens.reshape(b, nc, ck).swapaxes(0, 1)

    def body(_, t):
        oh = jax.nn.one_hot(t, v, dtype=table.dtype)
        return None, oh @ table

    _, chunks = jax.lax.scan(jax.checkpoint(body), None, tks)
    return chunks.swapaxes(0, 1).reshape(b, s, d)
