"""Fine-grained Mixture-of-Experts with shared experts.

Capacity-based scatter dispatch (no (T, E, C) one-hot — that is O(T·E·C)
memory and dead at production scale):

  1. router logits → softmax → top-k (gates, expert ids),
  2. position-in-expert via masked cumsum over the flat assignment list,
  3. scatter selected tokens into the (E, C, D) expert buffer,
  4. batched expert FFN einsum (experts sharded over the `model` mesh axis
     — the scatter/gather pair becomes the all-to-all of classic EP),
  5. weighted scatter-add back to token order; dropped tokens (beyond
     capacity) fall through with zero contribution (standard token dropping),
  6. shared experts run densely on every token and are summed in.

Aux load-balancing loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation, init_mlp, mlp
from repro.runtime.sharding import act_constraint


def init_moe(rng, cfg, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    f = m.d_expert or cfg.d_ff
    ks = jax.random.split(rng, 5)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, m.n_experts), jnp.float32) * s_in},
        "experts": {
            "w_up": jax.random.normal(ks[1], (m.n_experts, d, f), dtype) * s_in,
            "w_down": jax.random.normal(ks[2], (m.n_experts, f, d), dtype) * s_out,
        },
    }
    if cfg.glu:
        p["experts"]["w_gate"] = (
            jax.random.normal(ks[3], (m.n_experts, d, f), dtype) * s_in
        )
    if m.n_shared:
        p["shared"] = init_mlp(ks[4], d, f * m.n_shared, cfg.glu, dtype)
    return p


def _capacity(tokens: int, m) -> int:
    return max(1, int(tokens * m.top_k / m.n_experts * m.capacity_factor))


# global tokens per dispatch chunk: bounds the (E, C, D) buffer + routing
# transients; real systems dispatch per-microbatch for the same reason
DISPATCH_CHUNK = 262_144


def moe_block(p: dict, cfg, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Token-chunked dispatch with remat:
    at train_4k scale an unchunked dispatch materializes multi-GiB routing
    buffers; chunks of DISPATCH_CHUNK tokens scan through with one chunk's
    buffers live."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    if t > DISPATCH_CHUNK and t % DISPATCH_CHUNK == 0:
        nc = t // DISPATCH_CHUNK

        def body(aux, xc):
            y, a = _moe_tokens(p, cfg, xc)
            return aux + a, y

        aux, ys = jax.lax.scan(
            jax.checkpoint(body), jnp.float32(0.0),
            xt.reshape(nc, DISPATCH_CHUNK, d),
        )
        return ys.reshape(b, s, d).astype(x.dtype), aux / nc
    y, aux = _moe_tokens(p, cfg, xt)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_tokens(p: dict, cfg, xt: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Capacity-based dispatch for a flat (T, D) token block."""
    m = cfg.moe
    t, d = xt.shape
    cap = _capacity(t, m)

    logits = (xt.astype(jnp.float32)) @ p["router"]["w"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)  # (E,)
    ce = jnp.zeros((m.n_experts,), jnp.float32).at[eids.reshape(-1)].add(
        1.0 / (t * m.top_k)
    )
    aux = m.n_experts * jnp.sum(me * ce)

    # position within expert for each (token, slot) assignment
    flat_e = eids.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T*k, E)
    pos = pos.sum(-1)  # (T*k,)
    keep = pos < cap

    tok_ids = jnp.repeat(jnp.arange(t), m.top_k)
    safe_pos = jnp.where(keep, pos, 0)
    xt = act_constraint(xt, "tokens2d")
    buf = jnp.zeros((m.n_experts, cap, d), xt.dtype)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], xt[tok_ids], 0).astype(xt.dtype)
    )
    buf = act_constraint(buf, "expert_buf")

    # batched expert FFN
    up = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_up"])
    if cfg.glu:
        gate = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_gate"])
        h = activation(gate, cfg.act) * up
    else:
        h = activation(up, cfg.act)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_down"])  # (E, C, D)

    # combine back to token order. The flat assignment list is token-major
    # (tok_ids == repeat(arange(t), k)), so "scatter-add by token id" is
    # exactly reshape(T, k, D).sum(axis=1) — removing the scatter keeps
    # GSPMD from all-reducing the whole (T, D) stream per layer (17.7 TB
    # per prefill step at llama4 scale; measured, see EXPERIMENTS §Perf).
    out_e = act_constraint(out_e, "expert_buf")
    picked = out_e[flat_e, safe_pos]  # (T*k, D)
    contrib = picked * (gates.reshape(-1)[:, None] * keep[:, None]).astype(
        picked.dtype
    )
    y = contrib.reshape(t, m.top_k, d).sum(axis=1)
    y = act_constraint(y, "tokens2d")

    if m.n_shared:
        y = y + mlp(p["shared"], xt, cfg.act, cfg.glu)
    return y, aux
