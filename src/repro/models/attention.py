"""GQA attention: global / sliding-window / chunked masks, qk-norm, RoPE and
M-RoPE, KV caches, cross-attention, and a memory-bounded query-chunked
softmax path for long sequences.

Memory plan: training/prefill attention scans over query chunks of
``Q_CHUNK`` so live score tensors are (B, q_chunk, H, Sk) instead of
(B, Sq, H, Sk) — at prefill_32k production scale that is the difference
between 0.8 GB and 26 GB per chip. Decode (Sq == 1) takes the direct path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.qmm import q_proj, qdot, qkv_proj
from repro.models.layers import apply_mrope, apply_rope, rms_norm
from repro.runtime.sharding import act_constraint

import contextlib
import threading

Q_CHUNK = 1024
NEG_INF = -1e30

_FLASH = threading.local()


@contextlib.contextmanager
def flash_fusion(enabled: bool = True):
    """Mark the attention core as the hand-written flash kernel for the
    roofline (jax.named_scope 'fused_kernel' — see roofline/hlocost.py).
    Numerics are identical; only the HLO byte accounting changes, modeling
    kernels/flash_attention.py which the CPU backend cannot lower."""
    prev = getattr(_FLASH, "on", False)
    _FLASH.on = enabled
    try:
        yield
    finally:
        _FLASH.on = prev


def _flash_scope():
    if getattr(_FLASH, "on", False):
        return jax.named_scope("fused_kernel_flash_attn")
    return contextlib.nullcontext()


def _use_paged_kernel() -> bool:
    """Route paged decode through the Pallas kernel. On TPU it runs
    compiled; tests monkeypatch this to exercise the dispatch glue in
    interpret mode on CPU (CI would otherwise never trace it)."""
    return jax.default_backend() == "tpu"


def init_attention(rng, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype) * (h * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.zeros((hd,), dtype)
        p["k_norm_scale"] = jnp.zeros((hd,), dtype)
    return p


def _mask(
    q_pos: jax.Array,  # (B, Sq)
    k_pos: jax.Array,  # (B, Sk)
    *,
    causal: bool,
    window: jax.Array | int,      # 0 => unlimited
    chunk: jax.Array | int,       # 0 => no chunking
    k_len: jax.Array | None,      # (B,) valid cache length (decode); None => all
) -> jax.Array:
    """Boolean (B, Sq, Sk) attention mask from absolute positions."""
    q = q_pos[:, :, None]
    k = k_pos[:, None, :]
    m = jnp.ones(q.shape[:2] + (k_pos.shape[-1],), bool)
    if causal:
        m &= k <= q
    w = jnp.asarray(window)
    m &= jnp.where(w > 0, (q - k) < w, True)
    c = jnp.asarray(chunk)
    m &= jnp.where(c > 0, (q // jnp.maximum(c, 1)) == (k // jnp.maximum(c, 1)), True)
    if k_len is not None:
        m &= k < k_len[:, None, None]
    return m


def _sdpa(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,  # (B, Sk, KV, hd)
    mask: jax.Array,  # (B, Sq, Sk)
) -> jax.Array:
    """GQA via explicit KV-head expansion.

    Fully-masked query rows (no valid key at all — an empty request slot)
    return EXACT zeros: softmax over an all-``NEG_INF`` row is uniform (the
    max subtraction turns every score into ``exp(0)``), which would silently
    average garbage keys. The explicit guard makes "attends nothing" mean
    "outputs nothing" instead of clamping in one fake key.

    Expanding K/V to H heads (instead of a (KV, G) split) keeps the score
    tensor shardable on the *head* dim even when KV doesn't divide the TP
    degree (kv=8 on a 16-wide model axis): with the (KV, G) formulation
    GSPMD contracts over the sharded head_dim and materializes UNSHARDED
    (B, KV, G, Sq, Sk) scores — 12.9 GB/device at nemotron prefill_32k.
    The expanded K/V is a broadcast XLA fuses into the matmul; the
    head-sharding constraint pins scores to P(batch, 'model', ...)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    any_valid = mask.any(axis=-1)  # (B, Sq)
    if g > 1 and sq == 1:
        # decode: grouped formulation — expanding K/V would re-materialize
        # the whole 32k cache x G per token (~600 GB/step at internlm2
        # scale). Scores are tiny at sq=1, so the (KV, G) split is free.
        qf = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
        scores = jnp.einsum(
            "bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)
        ) * (hd ** -0.5)
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
        out = jnp.where(any_valid[:, :, None, None, None], out, 0.0)
        return out.reshape(b, sq, h, hd).astype(q.dtype)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = act_constraint(q.astype(jnp.float32), "heads")
    kf = act_constraint(k.astype(jnp.float32), "heads")
    vf = act_constraint(v.astype(jnp.float32), "heads")
    scores = jnp.einsum("bqhd,bshd->bhqs", qf, kf) * (hd ** -0.5)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", w, vf)
    out = jnp.where(any_valid[:, :, None, None], out, 0.0)
    return out.astype(q.dtype)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool = True,
    window: jax.Array | int = 0,
    chunk: jax.Array | int = 0,
    k_len: jax.Array | None = None,
) -> jax.Array:
    """Query-chunked SDPA. Shapes as in :func:`_sdpa`."""
    sq = q.shape[1]
    if sq <= Q_CHUNK or sq % Q_CHUNK != 0:
        with _flash_scope():
            return _sdpa(q, k, v, _mask(q_pos, k_pos, causal=causal,
                                        window=window, chunk=chunk,
                                        k_len=k_len))

    n = sq // Q_CHUNK
    k = act_constraint(k, "heads")
    v = act_constraint(v, "heads")

    def body(_, qc):
        qi, qpi = qc
        with _flash_scope():
            m = _mask(qpi, k_pos, causal=causal, window=window, chunk=chunk,
                      k_len=k_len)
            out = _sdpa(qi, k, v, m)
        return None, out

    qs = q.reshape(q.shape[0], n, Q_CHUNK, *q.shape[2:])
    qs = act_constraint(qs, "heads5").swapaxes(0, 1)
    qps = q_pos.reshape(q_pos.shape[0], n, Q_CHUNK).swapaxes(0, 1)
    # remat per chunk: without it the scan saves EVERY chunk's score tensor
    # for backward — the full S^2 scores, exactly what chunking avoids
    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, qps))
    out = outs.swapaxes(0, 1).reshape(q.shape)
    return out


def attention_block(
    p: dict,
    cfg,
    x: jax.Array,            # (B, S, D)
    pos: jax.Array,          # (B, S) or (B, S, 3) for mrope
    *,
    layer_window: jax.Array | int = 0,
    layer_chunk: jax.Array | int = 0,
    kv_cache: jax.Array | None = None,   # (2, B, Smax, KV, hd)
    kv_pages: jax.Array | None = None,   # (2, P, page, KV, hd) paged pool
    page_table: jax.Array | None = None,  # (B, NP) with kv_pages
    cache_len: jax.Array | None = None,  # (B,) per-row fill (scalar ok)
    seq_lens: jax.Array | None = None,   # (B,) valid new tokens per row
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """Returns (output (B,S,D), updated kv_cache/kv_pages or None).

    Self-attention when ``cross_kv`` is None; cross-attention (no cache
    update, no RoPE on k) otherwise.

    Cache writes land at each row's own ``cache_len`` offset; when
    ``seq_lens`` is given, rows with ``seq_lens == 0`` are left untouched
    (no KV write, frozen valid length) and rows with ``seq_lens < S`` only
    expose their true prefix to attention — right-padded batched prefill
    and inactive-slot decode both reduce to this one contract.

    Paged layout (``kv_pages`` + ``page_table`` instead of ``kv_cache``):
    identical contract over a shared page pool — writes scatter into each
    row's physical pages and attention reads the row's logical view. Since
    logical position == absolute position, RoPE and every mask are shared
    with the contiguous path. Decode on TPU dispatches to the Pallas
    paged-attention kernel; elsewhere (and for prefill) the logical gather
    feeds the exact same ``attend`` math as the dense path, so paged and
    contiguous decoding are bit-identical on CPU CI.

    Prefix sharing rides on the same contract: several page-table rows may
    alias one physical page read-only, and a fresh row's ``cache_len`` can
    start PAST its shared prefix — writes then begin at that offset (the
    scatter never touches the shared pages) while reads cover the full
    logical strip, positions below ``cache_len`` included. The scheduler
    guarantees every page written here has refcount 1 (copy-on-write
    happens host-side before the wave — see ``kvcache.prefix``).

    Speculative verification is the MULTI-TOKEN decode case of the paged
    branch: ``S = k + 1`` drafted tokens scatter at each row's
    ``cache_len`` offset and attend causally over the row's logical strip
    with ``k_len = cache_len + seq_lens`` — exactly a prefill chunk, which
    is why verify logits match sequential decoding position for position.
    The Pallas paged-decode kernel stays on the ``S == 1`` fast path
    (scalar-prefetch page lookups assume one query row); multi-token
    verify takes the gather path on every backend. Rejected drafts are
    un-written by REWINDING the row's length afterwards
    (``kvcache.rewind``) — the scattered KV past the rewound length is
    unreachable here (``k < k_len`` masks it) and the next wave's scatter
    overwrites it, so no wipe pass is ever needed.
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    if cross_kv is not None:
        q = q_proj(p, x).reshape(b, s, h, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm_scale"], cfg.norm_eps)
        ck, cv = cross_kv
        out = attend(
            q, ck, cv,
            q_pos=jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
            k_pos=jnp.broadcast_to(jnp.arange(ck.shape[1])[None], (b, ck.shape[1])),
            causal=False,
        )
        return qdot(out.reshape(b, s, h * hd), p["wo"]), None

    # fused QKV: one quantized kernel launch (x read once) when grouped
    q2, k2, v2 = qkv_proj(p, x)
    q = q2.reshape(b, s, h, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm_scale"], cfg.norm_eps)
    k = k2.reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm_scale"], cfg.norm_eps)
    v = v2.reshape(b, s, kvh, hd)

    if pos.ndim == 3:  # M-RoPE
        q = apply_mrope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos, cfg.rope_theta, cfg.mrope_sections)
        pos1 = pos[..., 0]
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        pos1 = pos

    if kv_cache is None and kv_pages is None:
        out = attend(q, k, v, pos1, pos1, causal=True,
                     window=layer_window, chunk=layer_chunk)
        new_cache = None
    elif kv_pages is not None:
        from repro.kvcache.paged import logical_view, paged_write

        starts = jnp.broadcast_to(
            jnp.atleast_1d(cache_len), (b,)
        ).astype(jnp.int32)
        new_cache = paged_write(kv_pages, k, v, page_table, starts, seq_lens)
        inc = s if seq_lens is None else seq_lens.astype(jnp.int32)
        k_len = starts + inc
        if s == 1 and _use_paged_kernel():
            from repro.kernels.paged_attention import paged_attention_pallas

            # kv-major head split: h = (kvh, g), matching _sdpa's grouped
            # decode reshape and the kernel's (B, KV, G, hd) layout
            qg = q[:, 0].reshape(b, kvh, h // kvh, hd)
            og = paged_attention_pallas(
                qg, new_cache[0], new_cache[1], page_table, k_len,
                window=layer_window, chunk=layer_chunk,
                interpret=jax.default_backend() != "tpu",
            )
            out = og.reshape(b, 1, h, hd)
        else:
            kl, vl = logical_view(new_cache, page_table)
            s_log = kl.shape[1]
            k_pos = jnp.broadcast_to(jnp.arange(s_log)[None], (b, s_log))
            out = attend(
                q, kl.astype(q.dtype), vl.astype(q.dtype), pos1, k_pos,
                causal=True, window=layer_window, chunk=layer_chunk,
                k_len=k_len,
            )
    else:
        smax = kv_cache.shape[2]
        starts = jnp.broadcast_to(
            jnp.atleast_1d(cache_len), (b,)
        ).astype(jnp.int32)

        if seq_lens is None:

            def _write(row, new, s0):  # per-row offset into the cache
                return jax.lax.dynamic_update_slice(row, new, (s0, 0, 0))

            kc = jax.vmap(_write)(kv_cache[0], k.astype(kv_cache.dtype),
                                  starts)
            vc = jax.vmap(_write)(kv_cache[1], v.astype(kv_cache.dtype),
                                  starts)
            k_len = starts + s
        else:
            # per-position masked scatter, O(B*s) on the decode hot path:
            # frozen rows (seq_lens == 0), right-padding beyond each row's
            # length, and positions past the buffer all map to an
            # out-of-bounds index and are DROPPED. A dynamic_update_slice
            # of the padded tile would instead CLAMP its start when
            # ``starts + s > Smax`` (late chunked-prefill wave of a nearly
            # full row) and silently shift the tile onto live positions.
            t = starts[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
            valid = (jnp.arange(s, dtype=jnp.int32)[None]
                     < seq_lens.astype(jnp.int32)[:, None]) & (t < smax)
            rows_idx = jnp.arange(b, dtype=jnp.int32)[:, None] * smax
            flat_t = jnp.where(valid, rows_idx + t, b * smax)  # OOB => drop
            idx = flat_t.reshape(b * s)
            kvh_, hd_ = kv_cache.shape[-2:]

            def _scatter(buf, new):
                flat = buf.reshape(b * smax, kvh_, hd_)
                flat = flat.at[idx].set(
                    new.astype(kv_cache.dtype).reshape(b * s, kvh_, hd_),
                    mode="drop",
                )
                return flat.reshape(b, smax, kvh_, hd_)

            kc = _scatter(kv_cache[0], k)
            vc = _scatter(kv_cache[1], v)
            k_len = starts + seq_lens.astype(jnp.int32)
        # fully-masked rows (k_len == 0) come out as exact zeros via the
        # _sdpa guard — no clamp-in-one-garbage-key fallback needed
        new_cache = jnp.stack([kc, vc])
        k_pos = jnp.broadcast_to(jnp.arange(smax)[None], (b, smax))
        out = attend(
            q, kc.astype(q.dtype), vc.astype(q.dtype), pos1, k_pos,
            causal=True, window=layer_window, chunk=layer_chunk, k_len=k_len,
        )
    return qdot(out.reshape(b, s, h * hd), p["wo"]), new_cache
