"""GQA attention: global / sliding-window / chunked masks, qk-norm, RoPE and
M-RoPE, KV caches, cross-attention, and a memory-bounded query-chunked
softmax path for long sequences.

Memory plan: training/prefill attention scans over query chunks of
``Q_CHUNK`` so live score tensors are (B, q_chunk, H, Sk) instead of
(B, Sq, H, Sk) — at prefill_32k production scale that is the difference
between 0.8 GB and 26 GB per chip. Decode (Sq == 1) takes the direct path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.qmm import q_proj, qdot, qkv_proj
from repro.models.layers import apply_mrope, apply_rope, rms_norm
from repro.runtime.sharding import act_constraint

import contextlib
import threading

Q_CHUNK = 1024
NEG_INF = -1e30

_FLASH = threading.local()


@contextlib.contextmanager
def flash_fusion(enabled: bool = True):
    """Mark the attention core as the hand-written flash kernel for the
    roofline (jax.named_scope 'fused_kernel' — see roofline/hlocost.py).
    Numerics are identical; only the HLO byte accounting changes, modeling
    kernels/flash_attention.py which the CPU backend cannot lower."""
    prev = getattr(_FLASH, "on", False)
    _FLASH.on = enabled
    try:
        yield
    finally:
        _FLASH.on = prev


def _flash_scope():
    if getattr(_FLASH, "on", False):
        return jax.named_scope("fused_kernel_flash_attn")
    return contextlib.nullcontext()


def init_attention(rng, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype) * (h * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.zeros((hd,), dtype)
        p["k_norm_scale"] = jnp.zeros((hd,), dtype)
    return p


def _mask(
    q_pos: jax.Array,  # (B, Sq)
    k_pos: jax.Array,  # (B, Sk)
    *,
    causal: bool,
    window: jax.Array | int,      # 0 => unlimited
    chunk: jax.Array | int,       # 0 => no chunking
    k_len: jax.Array | None,      # (B,) valid cache length (decode); None => all
) -> jax.Array:
    """Boolean (B, Sq, Sk) attention mask from absolute positions."""
    q = q_pos[:, :, None]
    k = k_pos[:, None, :]
    m = jnp.ones(q.shape[:2] + (k_pos.shape[-1],), bool)
    if causal:
        m &= k <= q
    w = jnp.asarray(window)
    m &= jnp.where(w > 0, (q - k) < w, True)
    c = jnp.asarray(chunk)
    m &= jnp.where(c > 0, (q // jnp.maximum(c, 1)) == (k // jnp.maximum(c, 1)), True)
    if k_len is not None:
        m &= k < k_len[:, None, None]
    return m


def _sdpa(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,  # (B, Sk, KV, hd)
    mask: jax.Array,  # (B, Sq, Sk)
) -> jax.Array:
    """GQA via explicit KV-head expansion.

    Expanding K/V to H heads (instead of a (KV, G) split) keeps the score
    tensor shardable on the *head* dim even when KV doesn't divide the TP
    degree (kv=8 on a 16-wide model axis): with the (KV, G) formulation
    GSPMD contracts over the sharded head_dim and materializes UNSHARDED
    (B, KV, G, Sq, Sk) scores — 12.9 GB/device at nemotron prefill_32k.
    The expanded K/V is a broadcast XLA fuses into the matmul; the
    head-sharding constraint pins scores to P(batch, 'model', ...)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if g > 1 and sq == 1:
        # decode: grouped formulation — expanding K/V would re-materialize
        # the whole 32k cache x G per token (~600 GB/step at internlm2
        # scale). Scores are tiny at sq=1, so the (KV, G) split is free.
        qf = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
        scores = jnp.einsum(
            "bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)
        ) * (hd ** -0.5)
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
        return out.reshape(b, sq, h, hd).astype(q.dtype)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = act_constraint(q.astype(jnp.float32), "heads")
    kf = act_constraint(k.astype(jnp.float32), "heads")
    vf = act_constraint(v.astype(jnp.float32), "heads")
    scores = jnp.einsum("bqhd,bshd->bhqs", qf, kf) * (hd ** -0.5)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", w, vf)
    return out.astype(q.dtype)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool = True,
    window: jax.Array | int = 0,
    chunk: jax.Array | int = 0,
    k_len: jax.Array | None = None,
) -> jax.Array:
    """Query-chunked SDPA. Shapes as in :func:`_sdpa`."""
    sq = q.shape[1]
    if sq <= Q_CHUNK or sq % Q_CHUNK != 0:
        with _flash_scope():
            return _sdpa(q, k, v, _mask(q_pos, k_pos, causal=causal,
                                        window=window, chunk=chunk,
                                        k_len=k_len))

    n = sq // Q_CHUNK
    k = act_constraint(k, "heads")
    v = act_constraint(v, "heads")

    def body(_, qc):
        qi, qpi = qc
        with _flash_scope():
            m = _mask(qpi, k_pos, causal=causal, window=window, chunk=chunk,
                      k_len=k_len)
            out = _sdpa(qi, k, v, m)
        return None, out

    qs = q.reshape(q.shape[0], n, Q_CHUNK, *q.shape[2:])
    qs = act_constraint(qs, "heads5").swapaxes(0, 1)
    qps = q_pos.reshape(q_pos.shape[0], n, Q_CHUNK).swapaxes(0, 1)
    # remat per chunk: without it the scan saves EVERY chunk's score tensor
    # for backward — the full S^2 scores, exactly what chunking avoids
    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, qps))
    out = outs.swapaxes(0, 1).reshape(q.shape)
    return out


def attention_block(
    p: dict,
    cfg,
    x: jax.Array,            # (B, S, D)
    pos: jax.Array,          # (B, S) or (B, S, 3) for mrope
    *,
    layer_window: jax.Array | int = 0,
    layer_chunk: jax.Array | int = 0,
    kv_cache: jax.Array | None = None,   # (2, B, Smax, KV, hd)
    cache_len: jax.Array | None = None,  # (B,) per-row fill (scalar ok)
    seq_lens: jax.Array | None = None,   # (B,) valid new tokens per row
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """Returns (output (B,S,D), updated kv_cache or None).

    Self-attention when ``cross_kv`` is None; cross-attention (no cache
    update, no RoPE on k) otherwise.

    Cache writes land at each row's own ``cache_len`` offset; when
    ``seq_lens`` is given, rows with ``seq_lens == 0`` are left untouched
    (no KV write, frozen valid length) and rows with ``seq_lens < S`` only
    expose their true prefix to attention — right-padded batched prefill
    and inactive-slot decode both reduce to this one contract.
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    if cross_kv is not None:
        q = q_proj(p, x).reshape(b, s, h, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm_scale"], cfg.norm_eps)
        ck, cv = cross_kv
        out = attend(
            q, ck, cv,
            q_pos=jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
            k_pos=jnp.broadcast_to(jnp.arange(ck.shape[1])[None], (b, ck.shape[1])),
            causal=False,
        )
        return qdot(out.reshape(b, s, h * hd), p["wo"]), None

    # fused QKV: one quantized kernel launch (x read once) when grouped
    q2, k2, v2 = qkv_proj(p, x)
    q = q2.reshape(b, s, h, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm_scale"], cfg.norm_eps)
    k = k2.reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm_scale"], cfg.norm_eps)
    v = v2.reshape(b, s, kvh, hd)

    if pos.ndim == 3:  # M-RoPE
        q = apply_mrope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos, cfg.rope_theta, cfg.mrope_sections)
        pos1 = pos[..., 0]
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        pos1 = pos

    if kv_cache is None:
        out = attend(q, k, v, pos1, pos1, causal=True,
                     window=layer_window, chunk=layer_chunk)
        new_cache = None
    else:
        smax = kv_cache.shape[2]
        starts = jnp.broadcast_to(
            jnp.atleast_1d(cache_len), (b,)
        ).astype(jnp.int32)

        if seq_lens is None:

            def _write(row, new, s0):  # per-row offset into the cache
                return jax.lax.dynamic_update_slice(row, new, (s0, 0, 0))

            kc = jax.vmap(_write)(kv_cache[0], k.astype(kv_cache.dtype),
                                  starts)
            vc = jax.vmap(_write)(kv_cache[1], v.astype(kv_cache.dtype),
                                  starts)
            k_len = starts + s
        else:
            # frozen rows (seq_lens == 0) must keep their cache bytes: a
            # whole-buffer select would traverse O(B*Smax) every decode
            # step, so instead gather the s rows at each offset, select on
            # that tile, and write back — O(B*s) on the decode hot path
            keep = seq_lens > 0

            def _masked_write(row, new, s0, live):
                old = jax.lax.dynamic_slice(row, (s0, 0, 0), new.shape)
                return jax.lax.dynamic_update_slice(
                    row, jnp.where(live, new, old), (s0, 0, 0)
                )

            kc = jax.vmap(_masked_write)(
                kv_cache[0], k.astype(kv_cache.dtype), starts, keep
            )
            vc = jax.vmap(_masked_write)(
                kv_cache[1], v.astype(kv_cache.dtype), starts, keep
            )
            k_len = starts + seq_lens.astype(jnp.int32)
        # a fully-masked row (empty slot) would softmax over -inf -> NaN;
        # one zero-key is harmless and the row's output is discarded anyway
        k_len = jnp.maximum(k_len, 1)
        new_cache = jnp.stack([kc, vc])
        k_pos = jnp.broadcast_to(jnp.arange(smax)[None], (b, smax))
        out = attend(
            q, kc.astype(q.dtype), vc.astype(q.dtype), pos1, k_pos,
            causal=True, window=layer_window, chunk=layer_chunk, k_len=k_len,
        )
    return qdot(out.reshape(b, s, h * hd), p["wo"]), new_cache
