"""Byte-level tokenizer for the end-to-end examples (no external vocab).

ids 0..255 = raw bytes; 256 = BOS, 257 = EOS, 258 = PAD. Deterministic,
reversible, dependency-free — enough to train/evaluate the small LMs the
paper-reproduction pipeline quantizes.
"""
from __future__ import annotations

import numpy as np

BOS, EOS, PAD = 256, 257, 258
VOCAB = 259


def encode(text: str, add_special: bool = True) -> np.ndarray:
    b = np.frombuffer(text.encode("utf-8", errors="replace"), np.uint8)
    ids = b.astype(np.int32)
    if add_special:
        ids = np.concatenate([[BOS], ids, [EOS]]).astype(np.int32)
    return ids


def decode(ids) -> str:
    b = bytes(int(i) for i in ids if 0 <= int(i) < 256)
    return b.decode("utf-8", errors="replace")
