"""Data pipeline: deterministic synthetic streams + byte-LM corpora, with
shard-aware batching and background prefetch.

Production posture without external deps:
* ``SyntheticLM`` — seeded Zipf-ish token stream (structure: repeated
  n-grams so a real LM can actually learn something measurable — the
  examples' accuracy metric depends on it).
* ``ByteCorpus`` — byte-level windows over an in-memory text corpus.
* ``DataLoader`` — global-batch iterator, deterministic resume via
  (seed, step) — restores mid-epoch after checkpoint restart with zero
  state files; per-host sharding by (host_id, n_hosts) slicing.
* ``Prefetcher`` — background-thread double buffering.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Learnable synthetic language: a fixed random Markov chain with
    heavily skewed transitions, plus sprinkled copy patterns."""

    vocab_size: int
    seed: int = 0
    order_states: int = 512

    def _tables(self):
        rng = np.random.default_rng(self.seed)
        # sparse transition table: each state strongly prefers 4 tokens
        prefs = rng.integers(0, self.vocab_size, (self.order_states, 4))
        return prefs

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        prefs = self._tables()
        out = np.empty(length, np.int32)
        state = int(rng.integers(0, self.order_states))
        for i in range(length):
            if rng.random() < 0.85:
                tok = int(prefs[state, int(rng.integers(0, 4))])
            else:
                tok = int(rng.integers(0, self.vocab_size))
            out[i] = tok
            state = (state * 31 + tok) % self.order_states
        return out


@dataclasses.dataclass
class ByteCorpus:
    text: str

    def windows(self, rng: np.random.Generator, n: int, seq: int) -> np.ndarray:
        from repro.data.tokenizer import encode

        ids = encode(self.text, add_special=False)
        if len(ids) < seq + 1:
            ids = np.tile(ids, seq // max(len(ids), 1) + 2)
        starts = rng.integers(0, len(ids) - seq - 1, n)
        return np.stack([ids[s : s + seq + 1] for s in starts]).astype(np.int32)


@dataclasses.dataclass
class DataLoader:
    """Deterministic, resumable global-batch loader.

    Each step's batch is a pure function of (seed, step): restart-safe and
    identical across hosts; hosts slice [host_id::n_hosts] of the global
    batch for multi-host feeding.
    """

    source: object
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        if isinstance(self.source, ByteCorpus):
            w = self.source.windows(rng, self.global_batch, self.seq_len)
        else:
            w = np.stack([
                self.source.sample(rng, self.seq_len + 1)
                for _ in range(self.global_batch)
            ])
        w = w[self.host_id :: self.n_hosts]
        return {"tokens": w[:, :-1], "labels": w[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering around any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
            self._q.put(None)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
