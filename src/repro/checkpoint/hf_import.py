"""HF-style checkpoint import: safetensors/state-dict → the config zoo.

Real-weight evaluation needs released checkpoints, which ship as
safetensors state dicts under Hugging Face transformer names
(``model.layers.3.self_attn.q_proj.weight`` ...). This module maps that
naming onto this repo's stacked-scan parameter tree so the quality
evaluators (:mod:`repro.eval`) and the serving stack run on real weights
the moment a checkpoint file is present — no network, no transformers
dependency.

Three deliberate conventions bridged here (levanter's
``hf_checkpoints.py`` declarative-mapping idiom):

* **orientation** — HF ``nn.Linear`` stores ``(out, in)``; this repo's
  matmuls are ``x @ w`` with ``(in, out)`` leaves, so every projection
  transposes on the way in;
* **norm offset** — HF RMSNorm weight multiplies directly, this repo's
  ``rms_norm`` computes ``x * (1 + scale)`` (zero-init friendly), so
  norm weights import as ``w - 1``;
* **layer stacking** — per-layer HF tensors stack along a leading L axis
  (the scan layout every engine pass assumes).

The safetensors container itself is read/written by hand (8-byte LE
header length + JSON header + raw little-endian tensor bytes) — the
format is simple enough that depending on the ``safetensors`` package
offline would be all cost and no benefit, and the writer gives tests a
synthetic checkpoint to import without any downloads.
"""
from __future__ import annotations

import json
import pathlib
import struct

import numpy as np

# safetensors dtype tags <-> numpy. BF16 is covered via ml_dtypes (a jax
# dependency), so real bf16 checkpoints load without torch.
_DTYPES: dict[str, np.dtype] = {
    "F64": np.dtype(np.float64),
    "F32": np.dtype(np.float32),
    "F16": np.dtype(np.float16),
    "I64": np.dtype(np.int64),
    "I32": np.dtype(np.int32),
    "I16": np.dtype(np.int16),
    "I8": np.dtype(np.int8),
    "U8": np.dtype(np.uint8),
    "BOOL": np.dtype(bool),
}
try:  # pragma: no cover - ml_dtypes ships with jax
    import ml_dtypes

    _DTYPES["BF16"] = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    pass


def read_safetensors(path: str | pathlib.Path) -> dict[str, np.ndarray]:
    """Parse one ``.safetensors`` file into ``{name: array}``."""
    raw = pathlib.Path(path).read_bytes()
    if len(raw) < 8:
        raise ValueError(f"{path}: not a safetensors file (too short)")
    (hlen,) = struct.unpack("<Q", raw[:8])
    header = json.loads(raw[8 : 8 + hlen].decode("utf-8"))
    data = raw[8 + hlen :]
    out: dict[str, np.ndarray] = {}
    for name, meta in header.items():
        if name == "__metadata__":
            continue
        dt = _DTYPES.get(meta["dtype"])
        if dt is None:
            raise ValueError(f"{name}: unsupported dtype {meta['dtype']}")
        begin, end = meta["data_offsets"]
        arr = np.frombuffer(data[begin:end], dtype=dt)
        out[name] = arr.reshape(meta["shape"])
    return out


def write_safetensors(path: str | pathlib.Path,
                      tensors: dict[str, np.ndarray],
                      metadata: dict[str, str] | None = None) -> None:
    """Write ``{name: array}`` as a ``.safetensors`` file (the synthetic
    checkpoints the offline tests import)."""
    rev = {v: k for k, v in _DTYPES.items()}
    header: dict = {}
    if metadata:
        header["__metadata__"] = metadata
    blobs = []
    offset = 0
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        tag = rev.get(arr.dtype)
        if tag is None:
            raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
        b = arr.tobytes()
        header[name] = {"dtype": tag, "shape": list(arr.shape),
                        "data_offsets": [offset, offset + len(b)]}
        offset += len(b)
        blobs.append(b)
    hjson = json.dumps(header, separators=(",", ":")).encode("utf-8")
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)


# -- name mapping ------------------------------------------------------------

# per-layer HF suffix -> (repo subpath, transpose, norm_offset)
_LAYER_MAP = {
    "input_layernorm.weight": (("norm1_scale",), False, True),
    "self_attn.q_proj.weight": (("attn", "wq"), True, False),
    "self_attn.k_proj.weight": (("attn", "wk"), True, False),
    "self_attn.v_proj.weight": (("attn", "wv"), True, False),
    "self_attn.o_proj.weight": (("attn", "wo"), True, False),
    "post_attention_layernorm.weight": (("norm2_scale",), False, True),
    "mlp.gate_proj.weight": (("mlp", "w_gate"), True, False),
    "mlp.up_proj.weight": (("mlp", "w_up"), True, False),
    "mlp.down_proj.weight": (("mlp", "w_down"), True, False),
}
# qwen3/gemma3-style per-head RMSNorm on q/k, present iff cfg.qk_norm
_QK_NORM_MAP = {
    "self_attn.q_norm.weight": (("attn", "q_norm_scale"), False, True),
    "self_attn.k_norm.weight": (("attn", "k_norm_scale"), False, True),
}
# harmless HF extras a real checkpoint may carry
_IGNORED_SUFFIXES = ("rotary_emb.inv_freq",)


def _convert(arr: np.ndarray, transpose: bool, norm_offset: bool,
             dtype) -> np.ndarray:
    out = np.asarray(arr, dtype=np.float32)
    if transpose:
        out = out.T
    if norm_offset:
        out = out - 1.0  # HF multiplies by w; repro multiplies by 1+scale
    return np.ascontiguousarray(out.astype(dtype))


def import_hf_state(state: dict[str, np.ndarray], cfg, *,
                    dtype=np.float32, strict: bool = True) -> dict:
    """Map an HF-named state dict onto this repo's parameter tree.

    Covers the dense decoder families (llama-style blocks: RMSNorm +
    attention + gated MLP). Recurrent/MoE/enc-dec families need their own
    per-family maps — refused loudly rather than silently mis-mapped.
    Returns a params tree shaped exactly like ``build_model(cfg).init``.
    """
    if cfg.family not in ("dense",):
        raise NotImplementedError(
            f"{cfg.name}: HF import covers the dense llama-family tree "
            f"(family={cfg.family!r} needs its own name map)")
    if not cfg.glu or cfg.moe is not None or cfg.encdec:
        raise NotImplementedError(
            f"{cfg.name}: HF import expects the gated-MLP dense block")

    used: set[str] = set()

    def take(name: str) -> np.ndarray:
        if name not in state:
            raise KeyError(f"checkpoint is missing {name!r}")
        used.add(name)
        return state[name]

    params: dict = {
        "embed": {"table": _convert(take("model.embed_tokens.weight"),
                                    False, False, dtype)},
        "final_norm_scale": _convert(take("model.norm.weight"),
                                     False, True, dtype),
    }
    layer_map = dict(_LAYER_MAP)
    if cfg.qk_norm:
        layer_map.update(_QK_NORM_MAP)
    layers: dict = {}
    for suffix, (subpath, transpose, norm_offset) in layer_map.items():
        stack = np.stack([
            _convert(take(f"model.layers.{i}.{suffix}"), transpose,
                     norm_offset, dtype)
            for i in range(cfg.n_layers)
        ])
        node = layers
        for key in subpath[:-1]:
            node = node.setdefault(key, {})
        node[subpath[-1]] = stack
    params["layers"] = layers
    if not cfg.tie_embeddings:
        if "lm_head.weight" in state:
            head = take("lm_head.weight")
        else:  # HF ties by omission; untie by copying the embedding
            head = state["model.embed_tokens.weight"]
        params["lm_head"] = {"w": _convert(head, True, False, dtype)}

    unused = [k for k in state if k not in used
              and not k.endswith(_IGNORED_SUFFIXES)]
    if strict and unused:
        raise ValueError(
            f"checkpoint has {len(unused)} unmapped tensor(s), e.g. "
            f"{sorted(unused)[:4]} — pass strict=False to ignore")

    # shape validation against the config zoo: a mis-sized checkpoint
    # fails HERE, not as a shape error deep inside the first forward
    d, v, hd = cfg.d_model, cfg.vocab_size, cfg.hd
    expect = {
        "embed/table": (v, d),
        "layers/attn/wq": (cfg.n_layers, d, cfg.n_heads * hd),
        "layers/attn/wk": (cfg.n_layers, d, cfg.n_kv_heads * hd),
        "layers/attn/wv": (cfg.n_layers, d, cfg.n_kv_heads * hd),
        "layers/attn/wo": (cfg.n_layers, cfg.n_heads * hd, d),
        "layers/mlp/w_gate": (cfg.n_layers, d, cfg.d_ff),
        "layers/mlp/w_up": (cfg.n_layers, d, cfg.d_ff),
        "layers/mlp/w_down": (cfg.n_layers, cfg.d_ff, d),
        "final_norm_scale": (d,),
    }
    if cfg.qk_norm:
        expect["layers/attn/q_norm_scale"] = (cfg.n_layers, hd)
        expect["layers/attn/k_norm_scale"] = (cfg.n_layers, hd)
    if not cfg.tie_embeddings:
        expect["lm_head/w"] = (d, v)
    for path, shape in expect.items():
        node = params
        for key in path.split("/"):
            node = node[key]
        if tuple(node.shape) != shape:
            raise ValueError(
                f"{path}: checkpoint shape {tuple(node.shape)} != "
                f"{cfg.name} config shape {shape}")
    return params


def export_hf_state(params: dict, cfg, *,
                    dtype=np.float32) -> dict[str, np.ndarray]:
    """Inverse of :func:`import_hf_state`: a repo tree as an HF-named
    state dict. Exists so tests can fabricate a faithful synthetic
    checkpoint (and so weights round-trip for external tooling)."""
    out: dict[str, np.ndarray] = {
        "model.embed_tokens.weight": np.asarray(
            params["embed"]["table"], dtype),
        "model.norm.weight": np.asarray(
            params["final_norm_scale"], np.float32).astype(dtype) + 1.0,
    }
    layer_map = dict(_LAYER_MAP)
    if cfg.qk_norm:
        layer_map.update(_QK_NORM_MAP)
    for suffix, (subpath, transpose, norm_offset) in layer_map.items():
        node = params["layers"]
        for key in subpath:
            node = node[key]
        for i in range(cfg.n_layers):
            arr = np.asarray(node[i], np.float32)
            if transpose:
                arr = arr.T
            if norm_offset:
                arr = arr + 1.0
            out[f"model.layers.{i}.{suffix}"] = np.ascontiguousarray(
                arr.astype(dtype))
    if not cfg.tie_embeddings:
        out["lm_head.weight"] = np.ascontiguousarray(
            np.asarray(params["lm_head"]["w"], dtype).T)
    return out


def import_hf_checkpoint(path: str | pathlib.Path, cfg, *,
                         dtype=np.float32, strict: bool = True) -> dict:
    """``read_safetensors`` + :func:`import_hf_state` in one call."""
    return import_hf_state(read_safetensors(path), cfg, dtype=dtype,
                           strict=strict)
