"""Fault-tolerant checkpointing: async, atomic, sharded, reshard-on-restore.

Design (orbax-shaped, zero external deps):

* one directory per step: ``<root>/step_<n>.tmp`` → atomic rename to
  ``step_<n>`` only after every shard file + manifest is fsync'd — a crash
  mid-save never corrupts the latest durable checkpoint;
* per-leaf ``.npy`` files named by pytree path hash, plus a JSON manifest
  (tree structure, shapes, dtypes, step, mesh descriptor);
* async: ``save()`` snapshots device arrays to host (blocking only for the
  device→host copy) and writes in a background thread; ``wait()`` joins.
* elastic restore: ``restore()`` rebuilds the pytree on ANY mesh — leaves
  are loaded as numpy then device_put with the *target* sharding, so a
  512-chip checkpoint restores onto 256 chips (pod loss) or 1 CPU (tests);
* retention: keep the newest ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _leaf_file(path_str: str) -> str:
    h = hashlib.sha1(path_str.encode()).hexdigest()[:16]
    safe = path_str.replace("/", "__")[:80]
    return f"{safe}.{h}.npy"


class CheckpointManager:
    def __init__(self, root: str | pathlib.Path, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False,
             extra: dict | None = None) -> None:
        """Snapshot to host, then write asynchronously (atomic rename)."""
        self.wait()  # one in-flight save at a time
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        host = [(_path_str(p), np.asarray(jax.device_get(l))) for p, l in flat]
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "leaves": [
                {"path": p, "file": _leaf_file(p), "shape": list(a.shape),
                 "dtype": str(a.dtype)}
                for p, a in host
            ],
        }

        def write():
            try:
                tmp = self.root / f"step_{step:08d}.tmp"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                for p, a in host:
                    np.save(tmp / _leaf_file(p), a)
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                final = self.root / f"step_{step:08d}"
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                self._gc()
            except Exception as e:  # noqa: BLE001 — surfaced via wait()
                self._error = e

        if blocking:
            write()
            if self._error:
                raise self._error
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, like: Any,
                shardings: Any | None = None) -> tuple[int, Any]:
        """Rebuild ``like``-structured tree. ``shardings``: optional matching
        tree of NamedShardings for the TARGET mesh (elastic restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        files = {m["path"]: m for m in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shd_flat = None
        if shardings is not None:
            shd_flat = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )[0]
        leaves = []
        for i, (p, l) in enumerate(flat):
            ps = _path_str(p)
            if ps not in files:
                raise KeyError(f"checkpoint {step} missing leaf {ps}")
            arr = np.load(d / files[ps]["file"])
            want_dtype = l.dtype if hasattr(l, "dtype") else arr.dtype
            arr = arr.astype(want_dtype)
            if shd_flat is not None:
                leaves.append(jax.device_put(arr, shd_flat[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
