"""Evaluation tasks: ARC-style 4-way MCQ and perplexity.

Task construction is separated from scoring so the bare-model evaluators
here and the serving-path evaluators (:mod:`repro.eval.serving`) score
the IDENTICAL problem sets — the packed-engine-through-the-server number
is comparable to the fake-quant number because both saw the same
contexts, options and held-out sequences.

Determinism contract: problem sets depend only on ``(vocab_size, seed,
n_problems, ctx_len)``. ``mcq_problems`` reproduces the original
``benchmarks/table1_accuracy.py`` RNG consumption order exactly, so
accuracies are bit-for-bit comparable across the refactor.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticLM
from repro.eval.train import DATA_SEED


@dataclasses.dataclass(frozen=True)
class MCQProblem:
    """One 4-way next-token problem: option index 0 is the truth."""

    context: np.ndarray         # (ctx_len,) int32 prompt tokens
    options: tuple[int, ...]    # 4 candidate next tokens, truth first


def mcq_problems(vocab_size: int, n_problems: int = 200, seed: int = 123,
                 ctx_len: int = 32,
                 data_seed: int = DATA_SEED) -> list[MCQProblem]:
    """Held-out 4-way MCQ set: which continuation token is most likely
    after a context sampled from the training distribution? Distractors
    are random tokens."""
    src = SyntheticLM(vocab_size, seed=data_seed)
    rng = np.random.default_rng(seed)
    problems = []
    for i in range(n_problems):
        s = src.sample(np.random.default_rng((seed, i)), ctx_len + 1)
        truth = int(s[-1])
        options = (truth,
                   *(int(o) for o in rng.choice(vocab_size, 3,
                                                replace=False)))
        problems.append(MCQProblem(np.asarray(s[:-1], np.int32), options))
    return problems


def score_mcq(logits_row: np.ndarray, problem: MCQProblem) -> bool:
    """True when the model ranks the truth above all distractors."""
    scores = [float(logits_row[o]) for o in problem.options]
    return int(np.argmax(scores)) == 0


def eval_sequences(source, n: int, seq_len: int,
                   seed: int = 1234) -> np.ndarray:
    """(n, seq_len + 1) held-out token sequences for perplexity, from
    either corpus type: ``ByteCorpus`` slices windows, ``SyntheticLM``
    (or anything with ``sample``) draws per-sequence streams."""
    rng = np.random.default_rng(seed)
    if hasattr(source, "windows"):
        return source.windows(rng, n, seq_len)
    return np.stack([
        source.sample(np.random.default_rng((seed, i)), seq_len + 1)
        for i in range(n)
    ]).astype(np.int32)


def _last_logits_fn(cfg):
    """Jitted bare-model forward returning last-position logits (B, V)."""
    from repro.models import transformer as tfm

    @jax.jit
    def last_logits(params, tokens):
        x = tfm.embed_tokens(cfg, params, tokens)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                               tokens.shape).astype(jnp.int32)
        h, _, _ = tfm.decoder_forward(cfg, params, x, pos)
        return tfm.logits_fn(cfg, params, h[:, -1:])

    return last_logits


def mcq_eval(cfg, model, params, n_problems: int = 200,
             seed: int = 123, ctx_len: int = 32) -> float:
    """Bare-model MCQ accuracy (one batched forward, no serving stack) —
    the fake-quant evaluation the paper's Table 1 reports."""
    problems = mcq_problems(cfg.vocab_size, n_problems, seed=seed,
                            ctx_len=ctx_len)
    contexts = np.stack([p.context for p in problems])
    logits = np.asarray(
        _last_logits_fn(cfg)(params, jnp.asarray(contexts)))[:, 0]
    correct = sum(score_mcq(logits[i], p) for i, p in enumerate(problems))
    return correct / n_problems


def perplexity_eval(cfg, model, params, seqs: np.ndarray,
                    ctx_len: int = 8) -> dict:
    """Bare-model perplexity of ``seqs[:, ctx_len:]`` given the first
    ``ctx_len`` tokens: one full forward per batch, log-softmax scored at
    every continuation position. Returns ``{"ppl", "nll", "tokens"}``."""
    from repro.models import transformer as tfm

    @jax.jit
    def all_logits(params, tokens):
        x = tfm.embed_tokens(cfg, params, tokens)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                               tokens.shape).astype(jnp.int32)
        h, _, _ = tfm.decoder_forward(cfg, params, x, pos)
        return tfm.logits_fn(cfg, params, h)

    tokens = jnp.asarray(seqs[:, :-1])
    logits = np.asarray(all_logits(params, tokens), np.float64)
    nll, count = 0.0, 0
    for b in range(seqs.shape[0]):
        for j in range(ctx_len - 1, seqs.shape[1] - 1):
            row = logits[b, j]
            m = row.max()
            lse = m + np.log(np.sum(np.exp(row - m)))
            nll += -(row[seqs[b, j + 1]] - lse)
            count += 1
    return {"ppl": float(np.exp(nll / max(count, 1))),
            "nll": nll / max(count, 1), "tokens": count}
