"""Serving-path evaluators: the same tasks, through the real engine.

``benchmarks/table1_accuracy.py`` scores fake-quant forwards; these run
the IDENTICAL problem sets through :class:`repro.launch.serve.
BatchedServer` — packed Pallas kernels, continuous batching, optionally
paged KV — so the accuracy number covers the deployment path, not a
proxy of it. Two hooks on the server make that possible without touching
its jitted functions: ``capture_logits=True`` keeps the host logits row
behind every emitted token, and ``Request.force`` teacher-forces the
emission (perplexity scores the model's distribution over a HELD-OUT
continuation, so the served tokens must be the corpus's, not the
model's).

Engine/quality invariant: for any params tree, serving-path MCQ accuracy
equals bare-model MCQ accuracy on the same problems — pinned by
tests/test_eval.py, which is exactly the gate that catches a packed
kernel or scheduler change silently perturbing logits.
"""
from __future__ import annotations

import numpy as np

from repro.eval.tasks import MCQProblem, score_mcq


def _run_in_batches(model, params, all_reqs, *, slots: int, max_len: int,
                    server_kw: dict):
    """One server, every request: the scheduler streams the full request
    list through ``slots`` batch slots (that's the continuous-batching
    point), so one BatchedServer instance — and one compile per bucket —
    serves the whole evaluation."""
    from repro.launch.serve import BatchedServer

    server = BatchedServer(model, params, slots, max_len,
                           capture_logits=True, **server_kw)
    stats = server.run(all_reqs)
    if stats["requests"] != len(all_reqs):
        raise RuntimeError(
            f"eval server retired {stats['requests']}/{len(all_reqs)} "
            "requests")
    return stats


def serve_mcq_accuracy(model, params, problems: list[MCQProblem], *,
                       slots: int = 8, **server_kw) -> float:
    """MCQ accuracy through the serving path: one request per problem,
    ``max_new=1``, scored on the captured last-context-position logits
    row (the same quantity the bare evaluator reads)."""
    from repro.launch.serve import Request

    ctx_max = max(len(p.context) for p in problems)
    max_len = ctx_max + 1 + 8
    reqs = [Request(i, np.asarray(p.context, np.int32), 1)
            for i, p in enumerate(problems)]
    _run_in_batches(model, params, reqs, slots=slots, max_len=max_len,
                    server_kw=server_kw)
    correct = 0
    for r in reqs:
        assert r.logits is not None and len(r.logits) == 1, r.rid
        correct += score_mcq(r.logits[0], problems[r.rid])
    return correct / len(problems)


def serve_perplexity(model, params, seqs: np.ndarray, *, ctx_len: int = 8,
                     slots: int = 8, **server_kw) -> dict:
    """Perplexity of ``seqs[:, ctx_len:]`` given the first ``ctx_len``
    tokens, through the serving path: the continuation is teacher-forced
    (``Request.force``) while ``capture_logits`` keeps the distribution
    the model held before each forced token. Returns ``{"ppl", "nll",
    "tokens"}`` — same contract as the bare
    :func:`repro.eval.tasks.perplexity_eval`."""
    from repro.launch.serve import Request

    if ctx_len < 1 or ctx_len >= seqs.shape[1]:
        raise ValueError(f"ctx_len={ctx_len} must be in [1, "
                         f"{seqs.shape[1] - 1})")
    gen = seqs.shape[1] - ctx_len
    max_len = seqs.shape[1] + 8
    reqs = [
        Request(i, np.asarray(s[:ctx_len], np.int32), gen,
                force=np.asarray(s[ctx_len:], np.int32))
        for i, s in enumerate(seqs)
    ]
    _run_in_batches(model, params, reqs, slots=slots, max_len=max_len,
                    server_kw=server_kw)
    nll, count = 0.0, 0
    for r in reqs:
        assert r.logits is not None and len(r.logits) == gen, r.rid
        for j, row in enumerate(r.logits):
            row = np.asarray(row, np.float64)
            m = row.max()
            lse = m + np.log(np.sum(np.exp(row - m)))
            nll += -(row[int(r.force[j])] - lse)
            count += 1
    return {"ppl": float(np.exp(nll / max(count, 1))),
            "nll": nll / max(count, 1), "tokens": count}
