"""Quality evaluation: the paper's accuracy loop as a library.

The paper's headline result is a QUALITY claim (INT4 SplitQuantV2
recovering fp accuracy on ARC), so quality measurement lives next to the
serving stack, not in a bench script: ``train`` pretrains the tiny
offline LM, ``tasks`` builds the ARC-style MCQ problems and perplexity
sequences and scores bare-model forwards, and ``serving`` runs the SAME
tasks through the real :class:`repro.launch.serve.BatchedServer` path —
packed engine, paged KV, continuous batching — so every engine, kernel
or sharding change is inside the measured loop. ``sweep`` is the
accuracy-vs-bits CLI that appends ``quality/*`` rows to the persistent
bench trajectory (``BENCH_quant_engine.json``).
"""
from repro.eval.serving import serve_mcq_accuracy, serve_perplexity
from repro.eval.tasks import (
    MCQProblem,
    eval_sequences,
    mcq_eval,
    mcq_problems,
    perplexity_eval,
)
from repro.eval.train import train_small_lm

__all__ = [
    "MCQProblem", "eval_sequences", "mcq_eval", "mcq_problems",
    "perplexity_eval", "serve_mcq_accuracy", "serve_perplexity",
    "train_small_lm",
]
