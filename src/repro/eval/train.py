"""Offline pretrain of the tiny evaluation LM.

The paper evaluates released checkpoints; offline (no weights, no
downloads) the substitute is a reduced-config model of the same family
trained a few hundred steps on the synthetic Markov language until it
beats chance on the held-out MCQ task. Quantization quality measured on
THIS model reproduces the paper's Table-1 *signature* (INT8 flat, INT4
recovered by the split, INT2 dead) even though the absolute numbers are
synthetic-task accuracies, not ARC.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataLoader, SyntheticLM
from repro.models import build_model
from repro.optim import adamw

# the synthetic-language seed every evaluator shares: train, MCQ and
# perplexity must draw from the SAME Markov chain for accuracy to mean
# anything
DATA_SEED = 7


def train_small_lm(steps: int = 260, batch: int = 16, seq: int = 64,
                   seed: int = 0, arch: str = "llama32-1b"):
    """Train the reduced-config LM; returns ``(cfg, model, params, loss)``.

    The defaults are pinned: benchmarks/table1_accuracy.py and the CI
    quality gate both rely on this exact (steps, batch, seq, seed,
    data-seed) recipe producing a model whose Table-1 signature holds.
    """
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw.init_opt_state(params)
    opt_cfg = adamw.AdamWConfig(peak_lr=2e-3, warmup=20, total_steps=steps)
    loader = DataLoader(SyntheticLM(cfg.vocab_size, seed=DATA_SEED),
                        batch, seq, seed=seed)

    @jax.jit
    def step(params, opt, batch):
        (loss, m), g = jax.value_and_grad(model.train_loss, has_aux=True)(
            params, batch
        )
        params, opt, _ = adamw.apply_updates(opt_cfg, params, g, opt)
        return params, opt, loss

    loss = jnp.zeros(())
    for s in range(steps):
        b = loader.batch_at(s)
        params, opt, loss = step(params, opt,
                                 {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, model, params, float(loss)
